"""Multi-device sharding tests.  jax locks the device count at first init, so
these run in subprocesses with --xla_force_host_platform_device_count and a
small (2x2 / 2x2x2) mesh; numerics are compared against the 1-device run."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import make_fed_round_step
from repro.core.lora import AdapterSet, init_lora
from repro.core.scaling import scaling_factor
from repro.models.api import build_model
from repro.sharding import rules
from repro.sharding.specs import use_mesh

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
model = build_model(cfg)
n = 4
gamma = scaling_factor("sfedlora", 8.0, 8, n)
step = make_fed_round_step(model, strategy="fedsa",
                           opt_cfg=OptimizerConfig(name="sgd", lr=0.05),
                           jit=False)
from repro.optim.optimizers import make_optimizer
params = model.init(jax.random.key(0))
lora1 = init_lora(params, jax.random.key(1), LoRAConfig(rank=8))
lora = AdapterSet(
    lora=jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), lora1),
    gamma=gamma)
opt1 = make_optimizer(OptimizerConfig(name="sgd", lr=0.05))[0](lora1)
opt = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), opt1)
toks = jax.random.randint(jax.random.key(2), (n, 2, 2, 32), 0, 256)
batch = {"tokens": toks}

# ---- 1-device reference
ref_lora, _, ref_m = jax.jit(step)(params, lora, opt, batch, jnp.asarray(0))
ref_lora = ref_lora.lora
ref_loss = float(ref_m["loss"])

# ---- 4x2 mesh (data=clients, model=tp)
mesh = jax.make_mesh((4, 2), ("data", "model"))
in_shard = (rules.params_sharding(params, mesh),
            rules.lora_sharding(lora, mesh),
            rules.lora_sharding(opt, mesh),
            rules.inputs_sharding(batch, mesh, client_dim=True),
            jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
with use_mesh(mesh):
    f = jax.jit(step, in_shardings=in_shard)
    out_aset, _, m = f(params, lora, opt, batch, jnp.asarray(0))
out_lora = out_aset.lora
loss = float(m["loss"])

# ---- 2x2x2 multi-pod style mesh
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
in_shard3 = (rules.params_sharding(params, mesh3),
             rules.lora_sharding(lora, mesh3),
             rules.lora_sharding(opt, mesh3),
             rules.inputs_sharding(batch, mesh3, client_dim=True),
             jax.NamedSharding(mesh3, jax.sharding.PartitionSpec()))
with use_mesh(mesh3):
    f3 = jax.jit(step, in_shardings=in_shard3)
    _, _, m3 = f3(params, lora, opt, batch, jnp.asarray(0))
loss3 = float(m3["loss"])

# numerics agree across meshes
ok_a = None
qa = out_lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"]
ra = ref_lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"]
err = float(jnp.max(jnp.abs(qa - ra)))
print(json.dumps({"ref_loss": ref_loss, "mesh_loss": loss,
                  "mesh3_loss": loss3, "lora_err": err,
                  "devices": len(jax.devices())}))
"""


@pytest.mark.slow
def test_fed_round_step_sharded_matches_single_device(tmp_path):
    script = tmp_path / "sharded.py"
    script.write_text(SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert abs(rec["ref_loss"] - rec["mesh_loss"]) < 1e-3
    assert abs(rec["ref_loss"] - rec["mesh3_loss"]) < 1e-3
    assert rec["lora_err"] < 1e-4

"""Device-resident generation engine: prefill parity with the token-by-token
path, compiled generate vs the host-loop oracle (bit-identical tokens, one
host dispatch), every block family's cache fill, sampling semantics, and the
serve jit-cache lifetime regression."""
import dataclasses
import gc
import weakref

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig, ModelConfig
from repro.core.lora import AdapterBank, init_adapter_set
from repro.kernels import dispatch
from repro.launch import serve
from repro.models.api import build_model


def _cfg(use_pallas=False, num_layers=3, **kw):
    base = dict(name="eng", family="dense", num_layers=num_layers,
                d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                d_ff=64, vocab_size=64, use_pallas=use_pallas)
    base.update(kw)
    return ModelConfig(**base)


def _nonzero(aset, seed=9, scale=0.03):
    return dataclasses.replace(aset, lora=jax.tree.map(
        lambda x: x + scale * jax.random.normal(jax.random.key(seed), x.shape),
        aset.lora))


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    yield
    dispatch.force_mode(None)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sets = [_nonzero(init_adapter_set(params, jax.random.key(10 + i),
                                      LoRAConfig(rank=r)), seed=20 + i)
            for i, r in enumerate((2, 8, 4))]
    bank = AdapterBank.from_sets(sets)
    prompt = jax.random.randint(jax.random.key(3), (3, 5), 0, 64)
    return model, params, sets[1], bank, prompt


# ------------------------------------------------------------ prefill parity

def test_prefill_logits_match_forward(served):
    model, params, aset, _, prompt = served
    full, _ = model.forward(params, {"tokens": prompt}, adapters=aset)
    pre, _ = model.prefill(params, model.init_cache(3, 9), prompt, aset)
    np.testing.assert_allclose(np.asarray(full), np.asarray(pre),
                               rtol=1e-5, atol=1e-5)


def test_prefill_cache_matches_token_by_token(served):
    """The cache prefill returns equals what p sequential decode_step calls
    produce — and decoding continues identically from either."""
    model, params, aset, _, prompt = served
    b, p = prompt.shape
    _, pre_cache = model.prefill(params, model.init_cache(b, p + 3), prompt,
                                 aset)
    loop_cache = model.init_cache(b, p + 3)
    step = jax.jit(model.decode_step)
    loop_logits = []
    for t in range(p):
        lg, loop_cache = step(params, loop_cache, prompt[:, t:t + 1],
                              jnp.full((b,), t), aset)
        loop_logits.append(lg)
    for (path, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(pre_cache)[0],
            jax.tree_util.tree_flatten_with_path(loop_cache)[0]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5, err_msg=str(path))
    tok = jnp.full((b, 1), 7, jnp.int32)
    pos = jnp.full((b,), p)
    l1, _ = step(params, pre_cache, tok, pos, aset)
    l2, _ = step(params, loop_cache, tok, pos, aset)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_prefill_logits_match_stepwise_logits(served):
    """Satellite: prefill-then-decode logits parity with the old token-by-
    token path, position by position."""
    model, params, aset, _, prompt = served
    b, p = prompt.shape
    pre, _ = model.prefill(params, model.init_cache(b, p), prompt, aset)
    cache = model.init_cache(b, p)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(p):
        lg, cache = step(params, cache, prompt[:, t:t + 1],
                         jnp.full((b,), t), aset)
        outs.append(lg)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(stepped),
                               rtol=1e-5, atol=1e-5)


def test_prefill_sliding_window_overflow():
    """A prompt longer than a sliding-window cache keeps exactly the ring-
    buffer survivors the sequential decode would have kept."""
    cfg = _cfg(attn_window=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(5), (2, 7), 0, 64)
    b, p = prompt.shape
    _, pre_cache = model.prefill(params, model.init_cache(b, p + 2), prompt)
    cache = model.init_cache(b, p + 2)
    step = jax.jit(model.decode_step)
    for t in range(p):
        _, cache = step(params, cache, prompt[:, t:t + 1], jnp.full((b,), t))
    for (path, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(pre_cache)[0],
            jax.tree_util.tree_flatten_with_path(cache)[0]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5, err_msg=str(path))


# --------------------------------------------- compiled engine vs host loop

@pytest.mark.parametrize("variant", ["base", "adapter1", "bank"])
def test_compiled_generate_bit_identical_to_hostloop(served, variant):
    """Acceptance: compiled generation (prefill + scan decode) emits tokens
    BIT-IDENTICAL to the token-by-token host loop, for every serving
    signature, in one host dispatch."""
    model, params, aset, bank, prompt = served
    ids = jnp.asarray([2, 0, 1], jnp.int32)
    steps, max_len = 6, 11
    if variant == "base":
        comp = lambda: serve.generate(model, params, prompt, steps, max_len)
        host = lambda: serve.generate_hostloop(model, params, prompt, steps,
                                               max_len)
    elif variant == "adapter1":
        comp = lambda: serve.generate(model, params, prompt, steps, max_len,
                                      aset)
        host = lambda: serve.generate_hostloop(model, params, prompt, steps,
                                               max_len, aset)
    else:
        comp = lambda: serve.generate_banked(model, params, bank, ids,
                                             prompt, steps, max_len)
        host = lambda: serve.generate_banked_hostloop(model, params, bank,
                                                      ids, prompt, steps,
                                                      max_len)
    serve.reset_dispatch_meter()
    got = comp()
    assert serve.host_dispatches == 1
    want = host()
    assert serve.host_dispatches == 1 + prompt.shape[1] + steps - 1
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compiled_generate_interpret_tier():
    """The engine survives the fused kernel tiers: with use_pallas +
    interpret mode, compiled banked generation still matches the host-loop
    oracle token for token (CI serve-perf smoke runs this)."""
    cfg = _cfg(use_pallas=True, num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sets = [_nonzero(init_adapter_set(params, jax.random.key(30 + i),
                                      LoRAConfig(rank=r)), seed=40 + i)
            for i, r in enumerate((2, 4))]
    bank = AdapterBank.from_sets(sets)
    prompt = jax.random.randint(jax.random.key(6), (2, 4), 0, 64)
    ids = jnp.asarray([1, 0], jnp.int32)
    dispatch.force_mode("interpret")
    dispatch.reset_stats()
    got = serve.generate_banked(model, params, bank, ids, prompt, 4, 8)
    assert dispatch.stats["bgmv"] > 0          # kernel tier actually ran
    want = serve.generate_banked_hostloop(model, params, bank, ids, prompt,
                                          4, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pattern,extra", [
    (("rglru",), dict(rglru_d_state=32)),
    (("mlstm",), {}),
    (("attn", "rglru"), dict(rglru_d_state=32)),   # hybrid + tail block
    (("slstm",), {}),
])
def test_compiled_generate_recurrent_families(pattern, extra):
    """Prefill fills every cache kind (KV ring buffer, RG-LRU state + conv
    tail, mLSTM matrix memory, sLSTM scalar state): compiled generation
    matches the host loop for recurrent and hybrid stacks too."""
    cfg = _cfg(num_layers=3, block_pattern=pattern, **extra)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    prompt = jax.random.randint(jax.random.key(7), (2, 5), 0, 64)
    got = serve.generate(model, params, prompt, 5, 10)
    want = serve.generate_hostloop(model, params, prompt, 5, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------- sampling

def test_temperature_sampling_semantics(served):
    model, params, aset, _, prompt = served
    greedy = serve.generate(model, params, prompt, 6, 11, aset)
    t0 = serve.generate(model, params, prompt, 6, 11, aset, temperature=0.0,
                        key=jax.random.key(5))
    np.testing.assert_array_equal(np.asarray(greedy), np.asarray(t0))
    s1 = serve.generate(model, params, prompt, 6, 11, aset, temperature=0.7,
                        key=jax.random.key(5))
    s2 = serve.generate(model, params, prompt, 6, 11, aset, temperature=0.7,
                        key=jax.random.key(5))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert s1.shape == greedy.shape
    np.testing.assert_array_equal(np.asarray(s1[:, :prompt.shape[1]]),
                                  np.asarray(prompt))


def test_generated_tokens_stay_in_vocab():
    """Neither greedy nor sampling may emit a padded-vocab id: the lm head
    projects to vocab_padded (multiple of 256) and the padding rows carry
    untrained nonzero logits — both engines slice to the real vocab."""
    cfg = _cfg(num_layers=1, vocab_size=64)       # vocab_padded == 256
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(8), (4, 3), 0, 64)
    for temp, key in ((2.5, jax.random.key(11)), (0.0, None)):
        seq = serve.generate(model, params, prompt, 12, 15,
                             temperature=temp, key=key)
        assert int(jnp.max(seq)) < cfg.vocab_size, f"temperature={temp}"
    host = serve.generate_hostloop(model, params, prompt, 12, 15)
    assert int(jnp.max(host)) < cfg.vocab_size
    np.testing.assert_array_equal(
        np.asarray(serve.generate(model, params, prompt, 12, 15)),
        np.asarray(host))


def test_compiled_generate_audio_family():
    """Encoder-decoder (xattn) stacks generate through the compiled engine
    too: prefill without an encoder output keeps the cache's cross K/V —
    the token-by-token path's semantics — instead of crashing."""
    cfg = _cfg(num_layers=2, family="audio", block_pattern=("xattn",),
               encoder_layers=1, encoder_frames=4, encoder_d_model=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    prompt = jax.random.randint(jax.random.key(9), (2, 4), 0, 64)
    got = serve.generate(model, params, prompt, 4, 8)
    want = serve.generate_hostloop(model, params, prompt, 4, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_generate_rejects_zero_steps(served):
    model, params, *_ , prompt = served
    with pytest.raises(ValueError, match="steps"):
        serve.generate(model, params, prompt, 0, 8)


# ------------------------------------------------------- jit-cache lifetime

def test_serve_jit_cache_does_not_pin_models():
    """Satellite regression: the serve-layer jit caches must not keep dead
    models (and their compiled executables) alive for process lifetime, as
    the old ``lru_cache(maxsize=None)`` did.  The cache lives on the model,
    so the model+executables become collectable garbage together."""
    cfg = _cfg(num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jnp.zeros((1, 2), jnp.int32)
    serve.generate(model, params, prompt, 2, 4)
    serve.generate_hostloop(model, params, prompt, 2, 4)
    assert "_serve_jit_cache" in model.__dict__     # caches exist...
    ref = weakref.ref(model)
    del model
    gc.collect()
    assert ref() is None                            # ...and die with it


def test_serve_jit_cache_reuses_executables(served):
    """Re-entering generate must reuse the per-model jitted program (the
    whole point of the cache): no new entry, same function object."""
    model, params, _, _, prompt = served
    serve.generate(model, params, prompt, 2, 7)
    fn1 = model.__dict__["_serve_jit_cache"]["generate"]
    serve.generate(model, params, prompt, 2, 7)
    assert model.__dict__["_serve_jit_cache"]["generate"] is fn1

"""First-class adapter API: AdapterSet/AdapterBank units, LoRA-aware
KV-cache decode, multi-tenant banked serving, and train-vs-serve checkpoint
parity."""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (load_adapter_state, save_federated_state)
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import (FederatedTrainer, make_fed_round_step,
                                  make_run_chunk)
from repro.core.lora import (AdapterBank, AdapterSet, adapter_rank,
                             init_adapter_set, init_lora, pad_rank_tree)
from repro.data.synthetic import FederatedDataset
from repro.kernels import dispatch
from repro.models.api import build_model
from repro.optim.optimizers import make_optimizer


def _cfg(use_pallas=False, num_layers=2):
    return ModelConfig(name="aset", family="dense", num_layers=num_layers,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=64, use_pallas=use_pallas)


def _nonzero(aset, seed=9, scale=0.03):
    """Give B (zero-init) real values so adapter effects are visible."""
    return dataclasses.replace(aset, lora=jax.tree.map(
        lambda x: x + scale * jax.random.normal(jax.random.key(seed), x.shape),
        aset.lora))


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


# ------------------------------------------------------------- AdapterSet

def test_adapter_set_pytree_roundtrip(tiny):
    _, model, params = tiny
    aset = init_adapter_set(params, jax.random.key(1), LoRAConfig(rank=4),
                            n_clients=3)
    leaves, td = jax.tree.flatten(aset)
    back = jax.tree.unflatten(td, leaves)
    assert back.gamma == aset.gamma and back.rank == 4
    assert back.alpha == aset.alpha
    # static gamma lives in the treedef: different gammas, different treedefs
    other = dataclasses.replace(aset, gamma=1.0)
    assert jax.tree.structure(other) != td


def test_adapter_set_uniform_collapse(tiny):
    _, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    uniform = AdapterSet(lora=lora, gamma=(2.0, 2.0, 2.0))
    assert isinstance(uniform.gamma, float) and uniform.gamma == 2.0
    mixed = AdapterSet(lora=lora, gamma=(1.0, 2.0))
    assert not isinstance(mixed.gamma, float)
    # an all-ones rank mask masks nothing -> canonicalized away entirely
    assert AdapterSet(lora=lora, rank_mask=jnp.ones((3, 4))).rank_mask is None
    assert AdapterSet(lora=lora,
                      rank_mask=jnp.asarray([[1., 1., 0., 0.]])
                      ).rank_mask is not None


def test_fold_gamma_static_and_traced(tiny):
    _, model, params = tiny
    aset = _nonzero(init_adapter_set(params, jax.random.key(1),
                                     LoRAConfig(rank=4)))
    folded = dataclasses.replace(aset, gamma=2.5).fold_gamma()
    assert folded.gamma == 1.0
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(aset.lora)[0],
            jax.tree_util.tree_flatten_with_path(folded.lora)[0]):
        name = pa[-1].key
        ref = np.asarray(a) * (2.5 if name == "b" else 1.0)
        np.testing.assert_array_equal(np.asarray(b), ref)
    # traced gamma folds under jit and the model still sees a static scale
    out = jax.jit(lambda s, g: dataclasses.replace(
        s, gamma=g).fold_gamma().gamma)(aset, jnp.float32(3.0))
    assert float(out) == 1.0


def test_stack_unstack_roundtrip(tiny):
    _, model, params = tiny
    s1 = _nonzero(init_adapter_set(params, jax.random.key(1),
                                   LoRAConfig(rank=4)), seed=1)
    s2 = _nonzero(init_adapter_set(params, jax.random.key(2),
                                   LoRAConfig(rank=4, alpha=4.0)), seed=2)
    stacked = AdapterSet.stack([s1, s2])
    assert jax.tree.leaves(stacked.lora)[0].shape[0] == 2
    u1, u2 = stacked.unstack()
    for a, b in zip(jax.tree.leaves(u1.lora), jax.tree.leaves(s1.lora)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # mixed ranks refuse to stack raw — the bank handles padding
    s8 = init_adapter_set(params, jax.random.key(3), LoRAConfig(rank=8))
    with pytest.raises(ValueError, match="uniform ranks"):
        AdapterSet.stack([s1, s8])
    bank = AdapterBank.from_sets([s1, s8])
    assert bank.ranks == (4, 8) and adapter_rank(bank.lora) == 8


def test_pad_rank_tree_exact(tiny):
    """Zero rank padding is exact: padded forward == unpadded forward."""
    _, model, params = tiny
    aset = _nonzero(init_adapter_set(params, jax.random.key(1),
                                     LoRAConfig(rank=4), n_clients=2))
    toks = jax.random.randint(jax.random.key(5), (2, 8), 0, 64)
    ref, _ = model.forward(params, {"tokens": toks}, adapters=aset)
    padded = dataclasses.replace(aset, lora=pad_rank_tree(aset.lora, 16),
                                 rank=16)
    out, _ = model.forward(params, {"tokens": toks}, adapters=padded)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_merge_equals_runtime(tiny):
    _, model, params = tiny
    aset = _nonzero(init_adapter_set(params, jax.random.key(1),
                                     LoRAConfig(rank=4), n_clients=3))
    toks = jax.random.randint(jax.random.key(6), (2, 8), 0, 64)
    runtime, _ = model.forward(params, {"tokens": toks}, adapters=aset)
    merged, _ = model.forward(aset.merge(params), {"tokens": toks})
    np.testing.assert_allclose(np.asarray(runtime), np.asarray(merged),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------- LoRA-aware decode parity

def _greedy_positions(model, params, adapters, toks):
    """Per-position logits from the KV-cache decode loop over given tokens."""
    b, s = toks.shape
    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(s):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.full((b,), t), adapters)
        outs.append(logits)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("tier", ["reference", "interpret"])
def test_decode_step_matches_forward_with_adapters(tier):
    """KV-cache decode with an AdapterSet == full forward, position by
    position, on the reference AND interpret kernel tiers."""
    num_layers = 2 if tier == "reference" else 1
    cfg = _cfg(use_pallas=(tier == "interpret"), num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    aset = _nonzero(init_adapter_set(params, jax.random.key(1),
                                     LoRAConfig(rank=4), n_clients=2))
    toks = jax.random.randint(jax.random.key(3), (2, 6), 0, 64)
    dispatch.force_mode(tier if tier == "interpret" else None)
    try:
        full, _ = model.forward(params, {"tokens": toks}, adapters=aset)
        stepped = _greedy_positions(model, params, aset, toks)
    finally:
        dispatch.force_mode(None)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tier", ["reference", "interpret"])
def test_banked_decode_matches_per_adapter_loop(tier):
    """A mixed-rank AdapterBank batch decodes like a python loop over the
    same requests served one adapter at a time."""
    num_layers = 2 if tier == "reference" else 1
    cfg = _cfg(use_pallas=(tier == "interpret"), num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sets = [_nonzero(init_adapter_set(params, jax.random.key(10 + i),
                                      LoRAConfig(rank=r)), seed=20 + i)
            for i, r in enumerate((2, 8, 4))]
    bank = AdapterBank.from_sets(sets)
    toks = jax.random.randint(jax.random.key(4), (3, 5), 0, 64)
    ids = jnp.asarray([2, 0, 1])
    dispatch.force_mode(tier if tier == "interpret" else None)
    try:
        batched = _greedy_positions(model, params, bank.gather(ids), toks)
        rows = [
            _greedy_positions(model, params, bank.adapter(int(k)),
                              toks[i:i + 1])
            for i, k in enumerate(ids)]
    finally:
        dispatch.force_mode(None)
    loop = jnp.concatenate(rows, axis=0)
    np.testing.assert_allclose(np.asarray(batched), np.asarray(loop),
                               rtol=2e-4, atol=2e-4)


def test_bank_k8_mixed_rank_bit_identical_conformance():
    """Acceptance: a K=8 mixed-rank AdapterBank batched decode is
    bit-identical to K single-adapter decodes.

    The K reference decodes run at the SAME batch shape (every row served by
    adapter k) because XLA GEMM tiling is shape-dependent: equal shapes make
    the comparison exact and prove request isolation — row i's tokens depend
    only on its own adapter, never on what the other rows were served."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ranks = (2, 4, 8, 8, 16, 2, 4, 16)
    sets = [_nonzero(init_adapter_set(params, jax.random.key(30 + i),
                                      LoRAConfig(rank=r, alpha=float(2 + i))),
                     seed=40 + i)
            for i, r in enumerate(ranks)]
    bank = AdapterBank.from_sets(sets)
    assert bank.size == 8 and bank.ranks == ranks
    K = bank.size
    prompt = jax.random.randint(jax.random.key(5), (K, 2), 0, 64)

    step = jax.jit(lambda cache, tok, pos, ids: model.decode_step(
        params, cache, tok, pos, adapters=bank.gather(ids)))

    def decode(ids):
        cache = model.init_cache(K, 8)
        tok = prompt[:, :1]
        seq = [tok]
        for t in range(6):
            logits, cache = step(cache, tok, jnp.full((K,), t), ids)
            tok = (prompt[:, t + 1:t + 2] if t + 1 < prompt.shape[1]
                   else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
            seq.append(tok)
        return jnp.concatenate(seq, axis=1)

    mixed = decode(jnp.arange(K))
    for k in range(K):
        single = decode(jnp.full((K,), k))
        np.testing.assert_array_equal(np.asarray(mixed[k]),
                                      np.asarray(single[k]))


# --------------------------------------------- train-vs-serve checkpointing

def _tiny_trainer(model, ranks=None, n=2):
    ds = FederatedDataset(64, n, seq_len=16, batch_per_client=2, seed=3)
    return FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=4, ranks=ranks),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=1,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=3)


def test_train_vs_serve_logit_parity(tmp_path):
    """Satellite regression: --resume restores the TRAINED AdapterSet (gamma
    + rank mask included) and serves logits bit-identical to the trainer's
    own client adapters — serve.py can no longer decode random weights."""
    model = build_model(_cfg())
    tr = _tiny_trainer(model, ranks=(2, 4))
    tr.run(2)
    path = str(tmp_path / "ck.npz")
    tr.save(path)
    base, aset = load_adapter_state(path)
    assert aset.rank_mask is not None and aset.alpha == tr.lora_cfg.alpha
    toks = jnp.asarray(tr.dataset.eval_batch(4))
    for c in range(2):
        train_side, _ = model.forward(tr.base, {"tokens": toks},
                                      adapters=tr.client_adapters(c))
        serve_side, _ = model.forward(base, {"tokens": toks},
                                      adapters=aset.client(c))
        np.testing.assert_array_equal(np.asarray(train_side),
                                      np.asarray(serve_side))
    # and through the bank (gamma folded at registration)
    bank = AdapterBank.from_adapter_set(aset)
    assert bank.ranks == (2, 4)
    banked, _ = model.forward(
        base, {"tokens": jnp.broadcast_to(toks[:1], (2,) + toks.shape[1:])},
        adapters=bank.gather(jnp.asarray([0, 1])))
    per0, _ = model.forward(base, {"tokens": toks[:1]},
                            adapters=tr.client_adapters(0))
    np.testing.assert_allclose(np.asarray(banked[0]), np.asarray(per0[0]),
                               rtol=2e-5, atol=2e-6)


def test_legacy_checkpoint_upgrade(tmp_path):
    """Checkpoints written before adapter_meta upgrade via lora_cfg; without
    it they raise a clear error."""
    model = build_model(_cfg())
    tr = _tiny_trainer(model)
    tr.run(1)
    path = str(tmp_path / "legacy.npz")
    # simulate a pre-adapter-API checkpoint: no adapter_meta
    save_federated_state(path, tr.base, tr.lora, tr.opt_state, tr.round_idx)
    with pytest.raises(ValueError, match="adapter_meta"):
        load_adapter_state(path)
    lcfg = tr.lora_cfg
    with pytest.warns(UserWarning, match="legacy checkpoint"):
        base, aset = load_adapter_state(path, lora_cfg=lcfg)
    # the recomputed gamma matches what the trainer derived
    assert aset.gamma == pytest.approx(tr.gamma)
    toks = jnp.asarray(tr.dataset.eval_batch(2))
    a, _ = model.forward(base, {"tokens": toks},
                         adapters=aset.client(0))
    b, _ = model.forward(tr.base, {"tokens": toks},
                         adapters=tr.client_adapters(0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_adapters_surface(tiny):
    """FederatedTrainer exposes the state as AdapterSets."""
    _, model, params = tiny
    tr = _tiny_trainer(model, ranks=(2, 4))
    aset = tr.adapters
    assert aset.rank == 4 and aset.rank_mask is not None
    c0 = tr.client_adapters(0)
    assert c0.rank == 2 and float(np.asarray(c0.rank_mask).sum()) == 2.0
    assert c0.gamma == tr.client_gamma(0)
    tr.run_round()
    assert np.isfinite(tr.eval_perplexity(batch=2))

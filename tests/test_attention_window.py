"""Sliding-window decode: the ring-buffer cache must reproduce full-sequence
windowed attention even after wrapping (pos > window) — the mechanism behind
the long_500k shapes for mistral-nemo/gemma variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.api import build_model


@pytest.mark.parametrize("window,seq", [(4, 14), (6, 13), (8, 8)])
def test_ring_buffer_wraparound(window, seq):
    cfg = ModelConfig(name="w", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, attn_window=window)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, seq), 0, 64)
    full, _ = model.forward(params, {"tokens": toks})
    # decode with a cache allocated at EXACTLY the window size: forces wrap
    cache = model.init_cache(2, window)
    outs = []
    for t in range(seq):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.full((2,), t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-4,
                               atol=2e-4)


def test_long_position_decode_is_finite():
    """Decode at position ~500k with a small ring cache (the long_500k
    semantics: state size independent of absolute position)."""
    cfg = ModelConfig(name="w", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, attn_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(1, 524_288)
    assert cache["repeat"]["p0"]["k"].shape[2] == 8  # capped at window
    pos = jnp.array([524_287], jnp.int32)
    lg, cache2 = model.decode_step(params, cache, jnp.ones((1, 1), jnp.int32),
                                   pos)
    assert bool(jnp.isfinite(lg).all())

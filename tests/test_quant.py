"""Quantized frozen-base conformance (core/quant.py + the quant kernel tier).

Pins the contracts the quantized serving/training path promises:

  (a) round-trip bounds: dequant(quant(w)) error per bits/group-size,
  (b) kernel parity: the fused dequant-in-VMEM kernels (interpret mode)
      agree with dequantize-up-front through the SAME blocked fp kernels —
      the two tiers compute identical fp32 ops, so parity is essentially
      exact, and both sit within the usual kernel tolerance of the jnp ref,
  (c) dispatcher routing: quantized leaves take the quant kernels on fused
      tiers (stats["quant"]) and dequantize up front on the reference tier,
  (d) model-level logit error vs fp is pinned per mode, and the two tiers
      agree on the QUANTIZED model itself,
  (e) checkpoint round-trip: packed leaves restore bit-identical (logits
      too), and a mismatched --quant flag is a clear error,
  (f) federated convergence: training on a quantized frozen base tracks the
      fp loss trajectory within a pinned tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.core.quant import (QuantizedLinear, apply_quant_flag, dequantize,
                              dequantize_tree, quant_footprint, quantize,
                              quantize_tree, requantize_merged,
                              tree_quant_mode)
from repro.data.synthetic import FederatedDataset
from repro.kernels import dispatch, ref
from repro.kernels.bgmv import (bgmv_gemv, bgmv_gemv_quant, bgmv_matmul,
                                bgmv_matmul_quant)
from repro.kernels.lora_matmul import (lora_matmul_quant_vjp, lora_matmul_vjp,
                                       quant_matmul_vjp)
from repro.models.api import build_model

# pinned round-trip bounds: relative max-abs error of dequant(quant(w)) on
# N(0,1) weights — int8 per-channel lands ~4e-3, int4/G=64 ~7e-2; the pins
# leave ~50% headroom so a numerics change that halves precision trips them
RTRIP_REL = {"int8": 0.008, "int4": 0.11}
# pinned model-level logit error (max-abs, fp32 logits of the small model
# below): measured int8 ~0.13, int4 ~1.7 — pinned with ~2x headroom
LOGIT_MAX = {"int8": 0.35, "int4": 3.5}


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    dispatch.reset_stats()
    yield
    dispatch.force_mode(None)


def _w(k, n, seed=0):
    return jax.random.normal(jax.random.key(seed), (k, n),
                             jnp.float32) * k ** -0.5


# ------------------------------------------------------- (a) round-trip

@pytest.mark.parametrize("mode,bits", [("int8", 8), ("int4", 4)])
def test_roundtrip_bounds(mode, bits):
    w = _w(256, 128)
    q = quantize(w, bits=bits, group_size=64)
    back = np.asarray(dequantize(q))
    rel = np.abs(back - np.asarray(w)).max() / np.abs(np.asarray(w)).max()
    assert rel < RTRIP_REL[mode], f"{mode} round-trip error {rel:.4f}"
    assert q.shape == w.shape and q.dtype == w.dtype
    assert back.shape == w.shape


@pytest.mark.parametrize("gsize", [32, 64, 128])
def test_int4_group_sizes(gsize):
    w = _w(256, 64, seed=3)
    q = quantize(w, bits=4, group_size=gsize)
    rel = (np.abs(np.asarray(dequantize(q)) - np.asarray(w)).max()
           / np.abs(np.asarray(w)).max())
    assert rel < RTRIP_REL["int4"]
    # smaller groups can only help: scales adapt to finer amax structure
    if gsize < 128:
        q128 = quantize(w, bits=4, group_size=128)
        err = lambda qq: float(jnp.abs(dequantize(qq) - w).max())
        assert err(q) <= err(q128) * 1.05


def test_int8_smaller_error_than_int4():
    w = _w(512, 128, seed=5)
    e8 = float(jnp.abs(dequantize(quantize(w, bits=8)) - w).max())
    e4 = float(jnp.abs(dequantize(quantize(w, bits=4)) - w).max())
    assert e8 < e4


def test_footprint_reductions():
    """The acceptance floors: >= 2x (int8) / >= 3.5x (int4) on the eligible
    base leaves (here: one pure GEMM weight, the leaf class the tree walk
    packs)."""
    w = _w(512, 256)
    for mode, floor in (("int8", 2.0), ("int4", 3.5)):
        q = quantize(w, bits=8 if mode == "int8" else 4)
        assert np.asarray(w).nbytes / q.nbytes >= floor


# --------------------------------------------------- (b) kernel parity

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("m,k,n,r", [(64, 128, 64, 4), (64, 192, 128, 8)])
def test_quant_kernel_matches_dequant_upfront(bits, m, k, n, r):
    ks = jax.random.split(jax.random.key(1), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = _w(k, n, seed=2)
    a = jax.random.normal(ks[2], (r, k), jnp.float32) * 0.05
    b = jax.random.normal(ks[3], (n, r), jnp.float32) * 0.05
    q = quantize(w, bits=bits, group_size=64)
    kw = dict(bm=64, bn=64, bk=64, interpret=True)
    got = lora_matmul_quant_vjp(x, q.data, q.scales, a, b, 1.5, bits=bits,
                                **kw)
    want = lora_matmul_vjp(x, dequantize(q), a, b, 1.5, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # and within the usual kernel tolerance of the pure-jnp oracle
    oracle = ref.lora_matmul_ref(x, dequantize(q), a, b, 1.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_kernel_backward_parity(bits):
    m, k, n, r = 64, 128, 64, 4
    ks = jax.random.split(jax.random.key(2), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = _w(k, n, seed=4)
    a = jax.random.normal(ks[2], (r, k), jnp.float32) * 0.05
    b = jax.random.normal(ks[3], (n, r), jnp.float32) * 0.05
    q = quantize(w, bits=bits, group_size=64)
    cot = jax.random.normal(jax.random.key(9), (m, n))
    kw = dict(bm=64, bn=64, bk=64, interpret=True)

    def fused(x_, a_, b_):
        return (lora_matmul_quant_vjp(x_, q.data, q.scales, a_, b_, 2.0,
                                      bits=bits, **kw) * cot).sum()

    def upfront(x_, a_, b_):
        return (lora_matmul_vjp(x_, dequantize(q), a_, b_, 2.0, **kw)
                * cot).sum()

    got = jax.grad(fused, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(upfront, argnums=(0, 1, 2))(x, a, b)
    for g1, g2, name in zip(got, want, "xab"):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-6, atol=1e-6, err_msg=f"d{name}")


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_base_only_matmul(bits):
    x = jax.random.normal(jax.random.key(3), (64, 128), jnp.float32)
    w = _w(128, 64, seed=6)
    q = quantize(w, bits=bits, group_size=32)
    got = quant_matmul_vjp(x, q.data, q.scales, bits=bits, bm=64, bn=64,
                           bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(x @ dequantize(q)),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bits", [8, 4])
def test_bgmv_quant_parity(bits):
    B, s, k, n, r, K = 4, 8, 128, 64, 4, 3
    ks = jax.random.split(jax.random.key(4), 4)
    x = jax.random.normal(ks[0], (B, s, k), jnp.float32)
    w = _w(k, n, seed=7)
    ab = jax.random.normal(ks[1], (K, r, k), jnp.float32) * 0.05
    bb = jax.random.normal(ks[2], (K, n, r), jnp.float32) * 0.05
    ids = jnp.asarray([0, 1, 2, 1], jnp.int32)
    q = quantize(w, bits=bits, group_size=64)
    got = bgmv_matmul_quant(x, q.data, q.scales, ab, bb, ids, bits=bits,
                            interpret=True)
    want = bgmv_matmul(x, dequantize(q), ab, bb, ids, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    got1 = bgmv_gemv_quant(x[:, 0], q.data, q.scales, ab, bb, ids,
                           bits=bits, interpret=True)
    want1 = bgmv_gemv(x[:, 0], dequantize(q), ab, bb, ids, interpret=True)
    np.testing.assert_allclose(np.asarray(got1), np.asarray(want1),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------- (c) dispatcher routing

def test_lora_linear_quantized_reference_tier():
    x = jax.random.normal(jax.random.key(5), (8, 64), jnp.float32)
    w = _w(64, 32, seed=8)
    q = quantize(w, bits=8)
    got = dispatch.lora_linear(x, q, None, 1.0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(x @ dequantize(q)),
                               rtol=1e-6, atol=1e-6)
    assert dispatch.stats["reference"] > 0 and dispatch.stats["quant"] == 0


@pytest.mark.parametrize("bits", [8, 4])
def test_lora_linear_quantized_fused_tier(bits):
    x = jax.random.normal(jax.random.key(6), (8, 64), jnp.float32)
    w = _w(64, 32, seed=9)
    r = 4
    a = jax.random.normal(jax.random.key(7), (r, 64)) * 0.05
    b = jax.random.normal(jax.random.key(8), (32, r)) * 0.05
    q = quantize(w, bits=bits, group_size=32)
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        got = dispatch.lora_linear(x, q, {"a": a, "b": b}, 1.0)
        base_only = dispatch.lora_linear(x, q, None, 1.0)
    assert dispatch.stats["quant"] >= 2 and dispatch.stats["fused"] >= 1
    want = x @ dequantize(q) + (x @ a.T) @ b.T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(base_only),
                               np.asarray(x @ dequantize(q)),
                               rtol=2e-5, atol=2e-4)


# ------------------------------------------------- tree walk + flag logic

def _small_model(tier="reference"):
    cfg = ModelConfig(name=f"quant-{tier}", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=64,
                      use_pallas=(tier == "interpret"))
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def test_quantize_tree_eligibility_and_mode():
    model, params = _small_model()
    qt = quantize_tree(params, "int8")
    leaves = jax.tree.leaves(
        qt, is_leaf=lambda l: isinstance(l, QuantizedLinear))
    n_packed = sum(isinstance(l, QuantizedLinear) for l in leaves)
    assert n_packed > 0
    assert tree_quant_mode(qt) == "int8"
    assert tree_quant_mode(params) is None
    # embeddings / norms never pack
    assert not isinstance(qt["embed"], QuantizedLinear)
    with pytest.raises(ValueError):
        quantize_tree(qt, "int4")      # re-quantizing packed leaves
    # dequantize_tree restores plain arrays with the fp shapes
    back = dequantize_tree(qt)
    assert jax.tree.structure(back) == jax.tree.structure(params)


def test_model_footprint_floors():
    """Whole-model eligible-leaf accounting meets the acceptance floors."""
    _, params = _small_model()
    for mode, floor in (("int8", 2.0), ("int4", 3.5)):
        foot = quant_footprint(quantize_tree(params, mode))
        assert foot["base_fp_bytes"] / foot["base_bytes"] >= floor, mode


def test_apply_quant_flag():
    _, params = _small_model()
    q = apply_quant_flag(params, "int8")
    assert tree_quant_mode(q) == "int8"
    assert apply_quant_flag(q, "int8") is q          # matching: no-op
    assert apply_quant_flag(params, "none") is params
    with pytest.raises(ValueError, match="int8"):
        apply_quant_flag(q, "none")                  # packed, fp requested
    with pytest.raises(ValueError, match="int8"):
        apply_quant_flag(q, "int4")                  # packed, other mode


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_requantize_merged_roundtrip(mode):
    """--merge on a quantized base: merge_lora dequantizes packed leaves to
    fold the adapter in (by design), and requantize_merged must re-pack the
    result onto the checkpoint's grid — same mode, same group size, same
    footprint, logits within the quant error bound of the fp merge."""
    import dataclasses
    from repro.core.lora import AdapterBank, init_adapter_set
    model, params = _small_model()
    qt = quantize_tree(params, mode)
    aset = init_adapter_set(params, jax.random.key(3),
                            LoRAConfig(rank=4, alpha=8.0,
                                       targets=model.cfg.lora_targets))
    # B is zero-init: perturb so the merge actually moves the weights
    aset = dataclasses.replace(aset, lora=jax.tree.map(
        lambda x: x + 0.03 * jax.random.normal(jax.random.key(9), x.shape),
        aset.lora))
    bank = AdapterBank.from_sets([aset])
    def n_packed(tree):
        return sum(isinstance(l, QuantizedLinear) for l in jax.tree.leaves(
            tree, is_leaf=lambda l: isinstance(l, QuantizedLinear)))

    merged_fp = bank.adapter(0).merge(qt)
    # merge_lora dequantized the LoRA-targeted leaves (non-targets stay
    # packed) — the footprint regression --merge --quant used to ship
    assert 0 < n_packed(merged_fp) < n_packed(qt)
    back = requantize_merged(merged_fp, qt)
    assert n_packed(back) == n_packed(qt)
    # the repack restores mode, structure, and byte footprint exactly
    assert tree_quant_mode(back) == mode
    assert jax.tree.structure(back) == jax.tree.structure(qt)
    assert quant_footprint(back)["base_bytes"] == \
        quant_footprint(qt)["base_bytes"]
    for bl, ql in zip(
            jax.tree.leaves(back, is_leaf=lambda l: isinstance(
                l, QuantizedLinear)),
            jax.tree.leaves(qt, is_leaf=lambda l: isinstance(
                l, QuantizedLinear))):
        if isinstance(ql, QuantizedLinear):
            assert isinstance(bl, QuantizedLinear)
            assert (bl.bits, bl.group_size) == (ql.bits, ql.group_size)
    # unmerged leaves (embed, norms) pass through untouched
    np.testing.assert_array_equal(np.asarray(back["embed"]),
                                  np.asarray(merged_fp["embed"]))
    # serving the repacked merge stays within the quant error bound
    toks = jax.random.randint(jax.random.key(4), (2, 16), 0, 64)
    fp_logits = model.forward(merged_fp, {"tokens": toks})[0]
    q_logits = model.forward(back, {"tokens": toks})[0]
    err = float(jnp.abs(q_logits - fp_logits).max())
    assert 0.0 < err < LOGIT_MAX[mode], f"{mode} merged logit error {err:.3f}"


# ------------------------------------------- (d) model-level conformance

@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_model_logit_error_pinned(mode):
    model, params = _small_model()
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 64)
    fp = model.forward(params, {"tokens": toks})[0]
    qlog = model.forward(quantize_tree(params, mode), {"tokens": toks})[0]
    err = float(jnp.abs(qlog - fp).max())
    assert err < LOGIT_MAX[mode], f"{mode} logit error {err:.3f}"
    assert err > 0.0                                 # it IS quantized


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_quantized_model_tier_parity(mode):
    """The quantized model agrees across reference and interpret tiers —
    the fused in-VMEM dequant computes the same fp32 ops as dequantize-up-
    front, so the tiers stay within the kernel tolerance of each other."""
    model, params = _small_model("interpret")
    qt = quantize_tree(params, mode)
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, 64)
    dispatch.force_mode("reference")
    ref_logits = model.forward(qt, {"tokens": toks})[0]
    dispatch.reset_stats()
    dispatch.force_mode("interpret")
    fused_logits = model.forward(qt, {"tokens": toks})[0]
    assert dispatch.stats["quant"] > 0
    np.testing.assert_allclose(np.asarray(fused_logits),
                               np.asarray(ref_logits), rtol=2e-4, atol=5e-4)


# ------------------------------------------------ (e) checkpoint round-trip

@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_checkpoint_roundtrip_bit_identical(tmp_path, mode):
    model, params = _small_model()
    qt = quantize_tree(params, mode)
    path = str(tmp_path / "q.npz")
    save_pytree(path, {"base": qt})
    restored = load_pytree(path)["base"]
    for got, want in zip(
            jax.tree.leaves(restored,
                            is_leaf=lambda l: isinstance(l, QuantizedLinear)),
            jax.tree.leaves(qt,
                            is_leaf=lambda l: isinstance(l, QuantizedLinear))):
        if isinstance(want, QuantizedLinear):
            assert isinstance(got, QuantizedLinear)
            assert (got.bits, got.group_size, got.k, got.out_dtype) == \
                   (want.bits, want.group_size, want.k, want.out_dtype)
            np.testing.assert_array_equal(np.asarray(got.data),
                                          np.asarray(want.data))
            np.testing.assert_array_equal(np.asarray(got.scales),
                                          np.asarray(want.scales))
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    toks = jax.random.randint(jax.random.key(3), (2, 8), 0, 64)
    got = model.forward(restored, {"tokens": toks})[0]
    want = model.forward(qt, {"tokens": toks})[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_restore_with_mismatched_quant_flag_errors(tmp_path):
    """fp checkpoint -> quantize -> save/restore; restoring the packed
    checkpoint under a different --quant flag must fail loudly."""
    _, params = _small_model()
    path = str(tmp_path / "ck.npz")
    save_pytree(path, {"base": params})                       # fp checkpoint
    base = load_pytree(path)["base"]
    q = apply_quant_flag(base, "int8", source=path)           # one-shot pack
    qpath = str(tmp_path / "ck_q.npz")
    save_pytree(qpath, {"base": q})
    restored = load_pytree(qpath)["base"]
    assert tree_quant_mode(restored) == "int8"
    with pytest.raises(ValueError, match="int8"):
        apply_quant_flag(restored, "int4", source=qpath)


# --------------------------------------------- (f) federated convergence

def _trainer(model, base, n=2, seed=0):
    ds = FederatedDataset(64, n, seq_len=16, batch_per_client=2, seed=seed)
    return FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=4),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=2),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05),
        seed=seed, base_params=base)


def test_federated_convergence_with_quantized_base():
    """LoRA training over an int8 frozen base tracks the fp loss
    trajectory within a pinned band, and still makes progress."""
    model, params = _small_model()
    hist_fp = _trainer(model, params).run(4)
    hist_q = _trainer(model, quantize_tree(params, "int8")).run(4)
    for m_fp, m_q in zip(hist_fp, hist_q):
        assert abs(m_q["loss"] - m_fp["loss"]) < 0.05, (
            f"round {m_fp['round']}: quantized loss {m_q['loss']:.4f} vs "
            f"fp {m_fp['loss']:.4f}")


def test_federated_checkpoint_with_quantized_base(tmp_path):
    """save -> restore round-trips the packed base through the trainer."""
    model, params = _small_model()
    tr = _trainer(model, quantize_tree(params, "int4"))
    tr.run(2)
    path = str(tmp_path / "fed_q.npz")
    tr.save(path)
    tr2 = _trainer(model, quantize_tree(params, "int4"))
    tr2.restore(path)
    assert tree_quant_mode(tr2.base) == "int4"
    h1 = tr.run(1)[-1]["loss"]
    h2 = tr2.run(1)[-1]["loss"]
    assert h1 == h2

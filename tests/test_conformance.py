"""Cross-strategy x cross-tier conformance harness.

Every registered aggregation strategy must satisfy the same server-side
contract, on both kernel tiers (reference jnp and the Pallas interpreter):

  (a) post-aggregate agreement: every leaf a strategy aggregates is
      identical across clients afterwards,
  (b) idempotence: aggregating identical clients changes nothing (for the
      stacking aggregator: nothing about the B A product),
  (c) flora's stacked product equals the brute-force weighted sum of the
      per-client B_i A_i products,
  (d) the heterogeneous (padded-rank) engine with all ranks equal is
      BIT-identical to the homogeneous engine — chunked and per-round.

Plus the heterogeneous invariants the padded representation promises: a
mixed-rank federation runs under jit for every strategy while the masked
rank rows/cols stay exactly zero through training and aggregation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.aggregation import STRATEGIES, get_strategy
from repro.core.federated import FederatedTrainer
from repro.core.lora import AdapterSet, rank_mask, scale_lora_b
from repro.data.synthetic import FederatedDataset
from repro.kernels import dispatch
from repro.models.api import build_model

TIERS = ("reference", "interpret")

# the interpret tier emulates the Pallas kernels in Python — keep its model
# at the same (minimal) scale test_engine uses for its interpret parity test
_SCALE = {
    "reference": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, n=3, seq=16, batch=2,
                      local_steps=2, rounds=3, rank=4),
    "interpret": dict(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                      head_dim=16, d_ff=64, n=2, seq=8, batch=1,
                      local_steps=1, rounds=2, rank=4),
}


@pytest.fixture(scope="module")
def tier_models():
    out = {}
    for tier, s in _SCALE.items():
        cfg = ModelConfig(name=f"conf-{tier}", family="dense",
                          num_layers=s["num_layers"], d_model=s["d_model"],
                          num_heads=s["num_heads"],
                          num_kv_heads=s["num_kv_heads"],
                          head_dim=s["head_dim"], d_ff=s["d_ff"],
                          vocab_size=64, use_pallas=(tier == "interpret"))
        model = build_model(cfg)
        out[tier] = (model, model.init(jax.random.key(0)))
    return out


def make_trainer(model, base, tier, *, strategy, ranks=None,
                 chunk_rounds=0, participation=1.0, weight_by_size=False,
                 partition="iid", optimizer="sgd", seed=0,
                 buffer_size=None, faults=None):
    s = _SCALE[tier]
    ds = FederatedDataset(64, s["n"], seq_len=s["seq"],
                          batch_per_client=s["batch"], partition=partition,
                          seed=seed)
    return FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=s["rank"], ranks=ranks),
        fed_cfg=FederatedConfig(num_clients=s["n"],
                                local_steps=s["local_steps"],
                                aggregation=strategy,
                                participation=participation,
                                partition=partition,
                                weight_by_size=weight_by_size,
                                buffer_size=buffer_size,
                                faults=faults),
        opt_cfg=OptimizerConfig(name=optimizer, lr=0.05), seed=seed,
        base_params=base, chunk_rounds=chunk_rounds)


def assert_state_bitequal(tr_a, tr_b):
    for x, y in zip(jax.tree.leaves((tr_a.lora, tr_a.opt_state)),
                    jax.tree.leaves((tr_b.lora, tr_b.opt_state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _rand_lora(key, n, r, d=6, stack=()):
    ka, kb = jax.random.split(key)
    return {"x": {"attn": {"q": {
        "a": jax.random.normal(ka, (n,) + stack + (r, d)),
        "b": jax.random.normal(kb, (n,) + stack + (d, r))}}}}


def _leaves_ab(tree):
    node = tree["x"]["attn"]["q"]
    return np.asarray(node["a"]), np.asarray(node["b"])


# ------------------------- (d) homogeneous-rank het == homogeneous engine

@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_uniform_rank_het_bit_identical_to_homogeneous(tier_models, tier,
                                                       strategy):
    """The padded-rank path with ranks = (r,)*N (mask all ones, uniform
    gamma_i) must be BIT-identical to the homogeneous engine, chunked AND
    per-round, for every strategy, on both tiers."""
    model, base = tier_models[tier]
    s = _SCALE[tier]
    uniform = (s["rank"],) * s["n"]
    dispatch.force_mode(tier if tier == "interpret" else None)
    try:
        hom = make_trainer(model, base, tier, strategy=strategy,
                           chunk_rounds=s["rounds"])
        hom.run(s["rounds"])
        het_chunk = make_trainer(model, base, tier, strategy=strategy,
                                 ranks=uniform, chunk_rounds=s["rounds"])
        het_chunk.run(s["rounds"])
        het_seq = make_trainer(model, base, tier, strategy=strategy,
                               ranks=uniform, chunk_rounds=1)
        for _ in range(s["rounds"]):
            het_seq.run_round()
    finally:
        dispatch.force_mode(None)
    assert het_chunk.rank_mask is not None          # the masked path ran
    assert_state_bitequal(hom, het_chunk)
    assert_state_bitequal(het_chunk, het_seq)


def test_uniform_rank_het_bit_identical_with_participation(tier_models):
    """The rank-aware weighted mean composes with participation sampling
    without perturbing the homogeneous bits (same carried PRNG stream)."""
    model, base = tier_models["reference"]
    uniform = (4,) * _SCALE["reference"]["n"]
    hom = make_trainer(model, base, "reference", strategy="fedsa",
                       participation=0.5, chunk_rounds=2)
    hom.run(4)
    het = make_trainer(model, base, "reference", strategy="fedsa",
                       ranks=uniform, participation=0.5, chunk_rounds=2)
    het.run(4)
    assert_state_bitequal(hom, het)


# ------------- (e) buffered engine at staleness 0 degrades to synchronous

@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_buffered_staleness0_bit_identical_to_sync(tier_models, tier,
                                                   strategy):
    """The async buffered wrapper with zero faults and an uncapped buffer
    (M = N, every upload arrives with tau = 0) must be BIT-identical to
    the synchronous engine for every strategy, on both tiers — the
    conformance anchor the fault-tolerant engine's correctness argument
    rests on (ISSUE 10): staleness discounts at tau=0 are exactly 1,
    screening accepts every finite upload, and the weighted mean's
    reciprocal form reproduces the unweighted mean's lowering bitwise."""
    model, base = tier_models[tier]
    s = _SCALE[tier]
    dispatch.force_mode(tier if tier == "interpret" else None)
    try:
        sync = make_trainer(model, base, tier, strategy=strategy,
                            chunk_rounds=s["rounds"])
        sync.run(s["rounds"])
        buf = make_trainer(model, base, tier, strategy=strategy,
                           chunk_rounds=s["rounds"], buffer_size=0)
        buf.run(s["rounds"])
    finally:
        dispatch.force_mode(None)
    assert buf.async_mode and not sync.async_mode
    assert_state_bitequal(sync, buf)
    # the correction never engaged: every round delivered all N updates
    assert buf.gamma_eff == sync.adapters.gamma
    for h in buf.history:
        assert float(h["n_eff"]) == s["n"]
        assert float(h["gamma_scale"]) == 1.0
        assert float(h["stale"]) == 0.0 and float(h["rejected"]) == 0.0


def test_buffered_staleness0_composes_with_sampling_and_weights(tier_models):
    """Buffered bit-identity survives participation sampling (pending
    clients are 'in flight', not stale) and size-weighted aggregation
    (the staleness discount multiplies into the size weights)."""
    model, base = tier_models["reference"]
    for kw in (dict(participation=0.5),
               dict(partition="dirichlet", weight_by_size=True)):
        sync = make_trainer(model, base, "reference", strategy="fedsa",
                            chunk_rounds=2, **kw)
        sync.run(4)
        buf = make_trainer(model, base, "reference", strategy="fedsa",
                           chunk_rounds=2, buffer_size=0, **kw)
        buf.run(4)
        assert_state_bitequal(sync, buf)


# ----------------------------------- (a) post-aggregate client agreement

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("round_idx", (0, 1))
def test_post_aggregate_client_agreement(strategy, round_idx):
    """Every leaf the strategy aggregates is identical across clients after
    the server step (rolora alternates which leaf that is by round)."""
    strat = get_strategy(strategy)
    lora = _rand_lora(jax.random.key(round_idx), n=4, r=3)
    out = strat.aggregate(lora, round_idx)
    aa, ab = strat.agg_flags(round_idx)
    for flag, leaf in zip((aa, ab), _leaves_ab(out)):
        if bool(flag):
            for i in range(1, leaf.shape[0]):
                np.testing.assert_allclose(leaf[i], leaf[0], rtol=1e-6,
                                           atol=1e-7)


# --------------------------------- (b) idempotence on identical clients

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_aggregate_identical_clients_is_noop(strategy):
    """When every client already holds the same adapters, aggregation must
    not move them: flag strategies return the inputs; the stacking
    aggregator may refactor (SVD) but must preserve the B A product."""
    strat = get_strategy(strategy)
    one = _rand_lora(jax.random.key(3), n=1, r=3)
    lora = jax.tree.map(lambda x: jnp.broadcast_to(x, (4,) + x.shape[1:]),
                        one)
    out = strat.aggregate(lora, 0)
    a_in, b_in = _leaves_ab(lora)
    a_out, b_out = _leaves_ab(out)
    if strategy == "flora":
        np.testing.assert_allclose(b_out[0] @ a_out[0], b_in[0] @ a_in[0],
                                   rtol=1e-5, atol=1e-5)
        # and a second aggregate no longer moves the factors either
        out2 = strat.aggregate(out, 0)
        for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_allclose(a_out, a_in, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(b_out, b_in, rtol=1e-6, atol=1e-7)


# ------------------- (c) flora stacking == brute-force weighted product

def test_flora_stacked_product_equals_bruteforce_weighted_sum():
    n, r, d = 3, 4, 8
    key = jax.random.key(7)
    ka, kb, kw = jax.random.split(key, 3)
    a = jax.random.normal(ka, (n, r, d))
    # rank-1 per-client B so the weighted mean update has rank <= n <= r
    # and the rank-r SVD redistribution is exact
    b = jnp.zeros((n, d, r)).at[:, :, :1].set(
        jax.random.normal(kb, (n, d, 1)))
    w = jax.random.uniform(kw, (n,)) + 0.1
    lora = {"x": {"attn": {"q": {"a": a, "b": b}}}}
    out = get_strategy("flora").aggregate(lora, 0, weights=w)
    wn = np.asarray(w) / np.asarray(w).sum()
    want = sum(wn[i] * np.asarray(b[i] @ a[i]) for i in range(n))
    a_out, b_out = _leaves_ab(out)
    np.testing.assert_allclose(b_out[0] @ a_out[0], want, rtol=1e-5,
                               atol=1e-6)


def test_flora_heterogeneous_active_rank_stacking():
    """Padded representation: inactive rank rows are zero, so the stacked
    product is the sum of TRUE rank-r_i products; each client receives the
    redistribution truncated at its own rank (top-r_i SVD components)."""
    ranks = (2, 3, 4)
    n, r, d = len(ranks), max(ranks), 8
    mask = rank_mask(ranks)
    key = jax.random.key(11)
    ka, kb = jax.random.split(key)
    # rank-1 true content per client (within every client's active rows)
    a = jnp.zeros((n, r, d)).at[:, :1, :].set(
        jax.random.normal(ka, (n, 1, d)))
    b = jnp.zeros((n, d, r)).at[:, :, :1].set(
        jax.random.normal(kb, (n, d, 1)))
    lora = {"x": {"attn": {"q": {"a": a, "b": b}}}}
    out = get_strategy("flora").aggregate(lora, 0, rank_mask=mask)
    want = np.mean([np.asarray(b[i] @ a[i]) for i in range(n)], axis=0)
    u, s, vh = np.linalg.svd(want, full_matrices=False)
    a_out, b_out = _leaves_ab(out)
    for i, r_i in enumerate(ranks):
        # client i's inactive rows/cols are zero...
        assert np.all(a_out[i][r_i:, :] == 0)
        assert np.all(b_out[i][:, r_i:] == 0)
        # ...and its product is the best rank-r_i approximation of the
        # mean update: the top-r_i SVD truncation (exact for r_i >= 3,
        # the update's rank)
        trunc = (u[:, :r_i] * s[:r_i]) @ vh[:r_i, :]
        np.testing.assert_allclose(b_out[i] @ a_out[i], trunc, rtol=1e-5,
                                   atol=1e-6)


# -------------------------------------- mixed-rank engine invariants

def _masked_coords_zero(tr):
    q = tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]
    a, b = np.asarray(q["a"]), np.asarray(q["b"])
    for i, r_i in enumerate(tr.ranks):
        assert np.all(a[i][..., r_i:, :] == 0), ("a", i, r_i)
        assert np.all(b[i][..., :, r_i:] == 0), ("b", i, r_i)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_mixed_rank_runs_and_masked_rows_stay_zero(tier_models, strategy):
    """A mixed-rank federation completes under jit for every strategy and
    the inactive rank rows/cols stay EXACTLY zero across rounds, including
    under Dirichlet size-weighted aggregation."""
    model, base = tier_models["reference"]
    tr = make_trainer(model, base, "reference", strategy=strategy,
                      ranks=(2, 4, 4), partition="dirichlet",
                      weight_by_size=True, chunk_rounds=1)
    assert tr.gamma is None and len(set(tr.gammas)) > 1
    for _ in range(3):
        tr.run_round()
        _masked_coords_zero(tr)
    assert all(np.isfinite(h["loss"]) for h in tr.history)


def test_mixed_rank_adamw_masked_rows_stay_zero(tier_models):
    """AdamW's moment estimates and weight decay must not leak into the
    inactive rows (zero grads -> zero moments -> zero updates)."""
    model, base = tier_models["reference"]
    tr = make_trainer(model, base, "reference", strategy="fedit",
                      ranks=(2, 4, 4), optimizer="adamw", chunk_rounds=3)
    tr.run(3)
    _masked_coords_zero(tr)


def test_scale_lora_b_gamma_folding_matches_reference():
    """The mixed-gamma mechanism — fold gamma_i into B, call the model with
    static gamma=1 — matches the gamma * B A parametrization in value and
    gradients (it is how per-client gammas reach the fused kernel tier)."""
    cfg = ModelConfig(name="fold", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64)
    model = build_model(cfg)
    base = model.init(jax.random.key(0))
    from repro.core.lora import init_lora
    lora = init_lora(base, jax.random.key(1), LoRAConfig(rank=4))
    lora = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(jax.random.key(2), x.shape),
        lora)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 8)))
    gamma = 2.5

    def loss_direct(l):
        return model.loss(base, {"tokens": toks},
                          adapters=AdapterSet(lora=l, gamma=gamma))[0]

    def loss_folded(l):
        return model.loss(
            base, {"tokens": toks},
            adapters=AdapterSet(lora=scale_lora_b(l, jnp.float32(gamma))))[0]

    v1, g1 = jax.value_and_grad(loss_direct)(lora)
    v2, g2 = jax.value_and_grad(loss_folded)(lora)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
    for x, y in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                                   atol=1e-7)


# ------------------------------------------------ checkpoint round-trip

def test_heterogeneous_checkpoint_resume_mid_chunk_bit_exact(tier_models,
                                                             tmp_path):
    """Save/restore mid-run with chunk boundaries that do NOT line up with
    the uninterrupted run: the checkpoint carries the PRNG key, the
    per-client rank mask, and the data-partition state, so the resumed
    heterogeneous run is bit-exact."""
    model, base = tier_models["reference"]
    path = str(tmp_path / "het.npz")
    ranks = (2, 4, 4)
    kw = dict(strategy="fedsa", ranks=ranks, partition="dirichlet",
              weight_by_size=True, participation=0.5)

    full = make_trainer(model, base, "reference", chunk_rounds=3, **kw)
    full.run(6)

    half = make_trainer(model, base, "reference", chunk_rounds=2, **kw)
    half.run(2)
    half.save(path)
    payload = np.load(path)
    assert "rank_mask" in payload.files
    np.testing.assert_array_equal(payload["rank_mask"],
                                  np.asarray(rank_mask(ranks)))
    assert "partition_state" in payload.files

    res = make_trainer(model, base, "reference", chunk_rounds=2, **kw)
    res.restore(path)
    assert res.round_idx == 2
    res.run(4)
    assert_state_bitequal(full, res)
    _masked_coords_zero(res)


def test_restore_rebuilds_size_weights_from_checkpoint(tier_models,
                                                       tmp_path):
    """A restoring process may reconstruct the dataset with a different
    example pool; restore() must adopt the CHECKPOINTED partition (sizes +
    mixtures) and rebuild the engine so size-weighted aggregation resumes
    bit-exactly — not silently keep the construction-time weights."""
    model, base = tier_models["reference"]
    s = _SCALE["reference"]
    path = str(tmp_path / "sizes.npz")

    def trainer(total_examples):
        ds = FederatedDataset(64, s["n"], seq_len=s["seq"],
                              batch_per_client=s["batch"],
                              partition="dirichlet", seed=0,
                              total_examples=total_examples)
        return FederatedTrainer(
            model, ds, lora_cfg=LoRAConfig(rank=s["rank"]),
            fed_cfg=FederatedConfig(num_clients=s["n"],
                                    local_steps=s["local_steps"],
                                    aggregation="fedsa",
                                    partition="dirichlet",
                                    weight_by_size=True),
            opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=0,
            base_params=base, chunk_rounds=2)

    full = trainer(total_examples=0)
    full.run(4)
    half = trainer(total_examples=0)
    half.run(2)
    half.save(path)
    # same LM/topic seed, but a different example pool -> different
    # construction-time size weights
    res = trainer(total_examples=97 * s["n"])
    assert not np.array_equal(np.asarray(res.client_weights),
                              np.asarray(full.client_weights))
    res.restore(path)
    np.testing.assert_array_equal(np.asarray(res.client_weights),
                                  np.asarray(full.client_weights))
    res.run(2)
    assert_state_bitequal(full, res)


def test_partition_state_rejects_mismatched_lm_tables():
    """The partition (mixtures/sizes) restores from the checkpoint; the
    seed-derived LM transition tables cannot — restoring against a dataset
    built from a different seed must raise, not silently diverge."""
    a = FederatedDataset(64, 3, seq_len=8, batch_per_client=1, seed=0)
    b = FederatedDataset(64, 3, seq_len=8, batch_per_client=1, seed=1)
    state = a.partition_state()
    a.set_partition_state(state)            # same tables: round-trips
    with pytest.raises(ValueError, match="transition tables"):
        b.set_partition_state(state)


def test_het_trainer_lora_cfg_reflects_padded_rank(tier_models):
    model, base = tier_models["reference"]
    tr = make_trainer(model, base, "reference", strategy="fedsa",
                      ranks=(2, 4, 4))
    assert tr.lora_cfg.rank == 4
    q = tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]
    assert q["a"].shape[-2] == 4 and q["b"].shape[-1] == 4


def test_restore_rejects_mismatched_rank_mask(tier_models, tmp_path):
    model, base = tier_models["reference"]
    path = str(tmp_path / "mismatch.npz")
    het = make_trainer(model, base, "reference", strategy="fedsa",
                      ranks=(2, 4, 4), chunk_rounds=1)
    het.run(1)
    het.save(path)
    other = make_trainer(model, base, "reference", strategy="fedsa",
                         ranks=(4, 4, 4), chunk_rounds=1)
    with pytest.raises(ValueError, match="rank mask"):
        other.restore(path)
    hom = make_trainer(model, base, "reference", strategy="fedsa",
                       chunk_rounds=1)
    with pytest.raises(ValueError, match="rank mask"):
        hom.restore(path)


# ------------------------------------------------------- config errors

def test_ranks_length_mismatch_raises(tier_models):
    model, base = tier_models["reference"]
    with pytest.raises(ValueError, match="num_clients"):
        make_trainer(model, base, "reference", strategy="fedsa",
                     ranks=(4, 4))


def test_upload_bytes_per_client_matches_upload_bytes_when_uniform():
    lora = {"x": {"q": {"a": jnp.zeros((3, 4, 8)),
                        "b": jnp.zeros((3, 8, 4))}}}
    for name in STRATEGIES:
        strat = get_strategy(name)
        per = strat.upload_bytes_per_client(lora, 0, ranks=(4, 4, 4))
        assert per.shape == (3,)
        assert int(per[0]) == strat.upload_bytes(lora, 0)
        # active accounting scales linearly in the client's own rank
        half = strat.upload_bytes_per_client(lora, 0, ranks=(2, 4, 4))
        assert int(half[0]) * 2 == int(per[0])

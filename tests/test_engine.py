"""Compiled multi-round engine: scan/host parity, participation, strategy
registry, device-side data, checkpoint resume.

The load-bearing invariant: ``run_chunk`` over k rounds is BIT-identical to
k sequential ``run_round`` calls (same seed) — per-round and chunked
execution are the same compiled computation, for every strategy, under both
the reference and interpret kernel tiers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.aggregation import (REGISTRY, STRATEGIES, Strategy,
                                    get_strategy, negate_flag, strategy_flags)
from repro.core.federated import FederatedTrainer, participation_weights
from repro.data.synthetic import DeviceFederatedData, FederatedDataset
from repro.models.api import build_model


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="eng", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def make_trainer(model, base, *, strategy="fedsa", n=4, participation=1.0,
                 chunk_rounds=0, data_mode="host", seed=0, rank=4,
                 local_steps=2):
    ds = FederatedDataset(64, n, seq_len=32, batch_per_client=2, seed=seed)
    return FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=rank),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=local_steps,
                                aggregation=strategy,
                                participation=participation),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=seed,
        base_params=base, chunk_rounds=chunk_rounds, data_mode=data_mode)


def assert_trees_bitequal(t1, t2):
    for x, y in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def assert_state_bitequal(tr_a, tr_b):
    assert_trees_bitequal(tr_a.lora, tr_b.lora)
    assert_trees_bitequal(tr_a.opt_state, tr_b.opt_state)


# --------------------------------------------------------- chunk == rounds

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_run_chunk_bit_identical_to_sequential_rounds(tiny, strategy):
    """Satellite: run_chunk(k rounds) == k x run_round, bit-exact, for
    every registered strategy (k=5 is odd so rolora ends mid-alternation)."""
    cfg, model, base = tiny
    tr_seq = make_trainer(model, base, strategy=strategy, chunk_rounds=1)
    for _ in range(5):
        tr_seq.run_round()
    tr_chunk = make_trainer(model, base, strategy=strategy, chunk_rounds=5)
    tr_chunk.run(5)
    assert_state_bitequal(tr_seq, tr_chunk)
    np.testing.assert_array_equal([h["loss"] for h in tr_seq.history],
                                  [h["loss"] for h in tr_chunk.history])


def test_chunk_boundaries_do_not_matter(tiny):
    """6 rounds as 1+2+3 == one chunk of 6 (rolora: boundaries land on both
    parities, so the round-offset carry is exercised)."""
    cfg, model, base = tiny
    tr_a = make_trainer(model, base, strategy="rolora")
    tr_a.chunk_rounds = 1
    tr_a.run(1)
    tr_a.chunk_rounds = 2
    tr_a.run(2)
    tr_a.chunk_rounds = 3
    tr_a.run(3)
    tr_b = make_trainer(model, base, strategy="rolora", chunk_rounds=6)
    tr_b.run(6)
    assert tr_a.round_idx == tr_b.round_idx == 6
    assert_state_bitequal(tr_a, tr_b)


def test_device_data_mode_chunk_parity_and_training(tiny):
    """On-device batch synthesis inside the scan: same bit-exact chunk
    parity (randomness flows from the carried key), and the loss is finite
    and decreasing-ish over a short run."""
    cfg, model, base = tiny
    tr_seq = make_trainer(model, base, data_mode="device", chunk_rounds=1)
    for _ in range(4):
        tr_seq.run_round()
    tr_chunk = make_trainer(model, base, data_mode="device", chunk_rounds=4)
    tr_chunk.run(4)
    assert_state_bitequal(tr_seq, tr_chunk)
    assert all(np.isfinite(h["loss"]) for h in tr_chunk.history)


def test_device_sampler_shape_and_determinism(tiny):
    ds = FederatedDataset(64, 3, seq_len=16, batch_per_client=2, seed=0)
    dev = DeviceFederatedData.from_host(ds)
    toks = dev.sample_round(jax.random.key(7), 2)
    assert toks.shape == (3, 2, 2, 16) and toks.dtype == jnp.int32
    assert int(toks.min()) >= 0 and int(toks.max()) < 64
    toks2 = dev.sample_round(jax.random.key(7), 2)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
    assert not np.array_equal(
        np.asarray(toks), np.asarray(dev.sample_round(jax.random.key(8), 2)))


# ------------------------------------------------------ partial participation

def test_partial_participation_chunk_parity(tiny):
    """weights path: scan engine and per-round engine sample the SAME
    clients (randomness from the carried key, not a host RNG) and produce
    bit-identical state."""
    cfg, model, base = tiny
    tr_seq = make_trainer(model, base, participation=0.5, chunk_rounds=1)
    for _ in range(5):
        tr_seq.run_round()
    tr_chunk = make_trainer(model, base, participation=0.5, chunk_rounds=5)
    tr_chunk.run(5)
    assert_state_bitequal(tr_seq, tr_chunk)


def test_partial_participation_nonsampled_receive_aggregate(tiny):
    """Non-sampled clients keep their local state (B, opt) but receive the
    aggregated A — checked per-round via the optimizer step counters."""
    cfg, model, base = tiny
    tr = make_trainer(model, base, participation=0.5, chunk_rounds=1)
    prev_t = np.asarray(tr.opt_state["t"]).copy()
    for _ in range(4):
        tr.run_round()
        t = np.asarray(tr.opt_state["t"])
        stepped = t > prev_t
        # exactly k=2 of 4 clients train each round
        assert int(stepped.sum()) == 2
        # aggregated A identical across ALL clients (incl. non-sampled)
        a = np.asarray(tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"])
        for i in range(1, 4):
            np.testing.assert_allclose(a[0], a[i], rtol=1e-6, atol=1e-7)
        prev_t = t


def test_participation_weights_exact_count():
    w = participation_weights(jax.random.key(0), 10, 3)
    assert w.shape == (10,) and float(w.sum()) == 3.0
    assert set(np.unique(np.asarray(w))) <= {0.0, 1.0}


def _perturb_b(tr):
    """Give B a deterministic nonzero value: at the standard B=0 init, A's
    gradient is identically zero (dL/dA = B^T dY x), so an A-round would be
    a no-op and the alternation unobservable."""
    from repro.core.aggregation import _map_ab
    counter = [0]

    def pb(b):
        counter[0] += 1
        k = jax.random.fold_in(jax.random.key(99), counter[0])
        return 0.02 * jax.random.normal(k, b.shape, b.dtype)

    tr.lora = _map_ab(tr.lora, lambda a: a, pb)


def test_rolora_alternation_equivalence(tiny):
    """rolora round-alternation is identical between host-loop and scan
    engines: even rounds touch only A, odd rounds only B, across a chunk
    boundary that splits the parity."""
    cfg, model, base = tiny
    tr = make_trainer(model, base, strategy="rolora", chunk_rounds=1)
    _perturb_b(tr)
    q = lambda t: t.lora["stack"]["repeat"]["p0"]["attn"]["q"]
    a0, b0 = (np.asarray(q(tr)["a"]).copy(), np.asarray(q(tr)["b"]).copy())
    tr.run_round()                                   # round 0: A trains
    a1, b1 = np.asarray(q(tr)["a"]), np.asarray(q(tr)["b"])
    assert not np.array_equal(a0, a1)
    np.testing.assert_array_equal(b0, b1)
    tr.run_round()                                   # round 1: B trains
    a2, b2 = np.asarray(q(tr)["a"]), np.asarray(q(tr)["b"])
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(b1, b2)
    # the same two rounds as one scanned chunk
    tr2 = make_trainer(model, base, strategy="rolora", chunk_rounds=2)
    _perturb_b(tr2)
    tr2.run(2)
    assert_state_bitequal(tr, tr2)


# ----------------------------------------------------------------- registry

def test_registry_covers_and_roundtrips():
    assert set(REGISTRY) == set(STRATEGIES)
    for name in STRATEGIES:
        s = get_strategy(name)
        assert isinstance(s, Strategy) and s.name == name
        assert get_strategy(s) is s
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("nope")


def test_negate_flag_uniform():
    assert negate_flag(True) is False and negate_flag(False) is True
    traced = jax.jit(lambda r: negate_flag(r % 2 == 0))(jnp.asarray(1))
    assert bool(traced) is True


def test_strategy_flags_backcompat_matches_registry():
    for name in ("fedit", "ffa", "fedsa", "rolora"):
        s = get_strategy(name)
        for ridx in (0, 1):
            assert strategy_flags(name, ridx) == (s.train_flags(ridx),
                                                  s.agg_flags(ridx))


def test_strategy_flags_rejects_non_flag_strategies():
    """flora's stacking aggregate is not expressible as agg flags; the
    back-compat shim must refuse rather than describe plain means."""
    with pytest.raises(ValueError, match="not flag-expressible"):
        strategy_flags("flora", 0)


def test_upload_bytes_strategy_method():
    lora = {"x": {"q": {"a": jnp.zeros((2, 4, 8)), "b": jnp.zeros((2, 8, 4))}}}
    per = 4 * 8 * 4                       # one matrix, f32
    assert get_strategy("fedsa").upload_bytes(lora) == per
    assert get_strategy("fedit").upload_bytes(lora) == 2 * per
    assert get_strategy("flora").upload_bytes(lora) == 2 * per   # stacks A+B
    assert get_strategy("rolora").upload_bytes(lora, 0) == per
    assert get_strategy("rolora").upload_bytes(lora, 1) == per


def test_flora_stacking_exact_mean_product():
    """When the mean update fits in rank r, the redistributed factorization
    reproduces mean_i(B_i A_i) exactly and is identical across clients."""
    k1, k2 = jax.random.split(jax.random.key(0))
    n, r, d = 2, 4, 8
    a = jax.random.normal(k1, (n, r, d))
    b = jnp.zeros((n, d, r)).at[:, :, :1].set(
        jax.random.normal(k2, (n, d, 1)))            # rank-1 per client
    lora = {"x": {"q": {"a": a, "b": b}}}
    out = get_strategy("flora").aggregate(lora, 0)
    oa, ob = out["x"]["q"]["a"], out["x"]["q"]["b"]
    np.testing.assert_allclose(np.asarray(oa[0]), np.asarray(oa[1]))
    want = np.mean([np.asarray(b[i] @ a[i]) for i in range(n)], axis=0)
    got = np.asarray(ob[0] @ oa[0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_flora_trains(tiny):
    cfg, model, base = tiny
    tr = make_trainer(model, base, strategy="flora", chunk_rounds=3)
    tr.run(3)
    assert all(np.isfinite(h["loss"]) for h in tr.history)
    # redistribution synchronizes both matrices across clients
    q = tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]
    np.testing.assert_allclose(np.asarray(q["a"][0]), np.asarray(q["a"][1]))
    np.testing.assert_allclose(np.asarray(q["b"][0]), np.asarray(q["b"][1]))


# ----------------------------------------------------------- interpret tier

def test_engine_parity_interpret_tier():
    """The chunked scan is bit-identical to sequential rounds on the fused
    kernel path too (Pallas interpreter on CPU)."""
    from repro.kernels import dispatch
    cfg = ModelConfig(name="eng-pl", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, use_pallas=True)
    model = build_model(cfg)
    base = model.init(jax.random.key(0))
    dispatch.force_mode("interpret")
    try:
        def mk(chunk):
            ds = FederatedDataset(64, 2, seq_len=8, batch_per_client=1,
                                  seed=0)
            return FederatedTrainer(
                model, ds, lora_cfg=LoRAConfig(rank=4),
                fed_cfg=FederatedConfig(num_clients=2, local_steps=1),
                opt_cfg=OptimizerConfig(name="sgd", lr=0.05),
                base_params=base, chunk_rounds=chunk)
        tr_seq = mk(1)
        tr_seq.run_round()
        tr_seq.run_round()
        tr_chunk = mk(2)
        tr_chunk.run(2)
    finally:
        dispatch.force_mode(None)
    assert_state_bitequal(tr_seq, tr_chunk)


# --------------------------------------------------------------- checkpoint

def test_checkpoint_resume_bit_exact(tiny, tmp_path):
    """Satellite: checkpoints carry the PRNG key + round index (+ host data
    stream state), so save-at-k / restore / continue equals an uninterrupted
    run — including participation sampling randomness."""
    cfg, model, base = tiny
    path = str(tmp_path / "resume.npz")

    tr_full = make_trainer(model, base, participation=0.5, chunk_rounds=2)
    tr_full.run(6)

    tr_half = make_trainer(model, base, participation=0.5, chunk_rounds=2)
    tr_half.run(2)
    tr_half.save(path)

    tr_res = make_trainer(model, base, participation=0.5, chunk_rounds=2)
    tr_res.restore(path)
    assert tr_res.round_idx == 2
    tr_res.run(4)
    assert tr_res.round_idx == 6
    assert_state_bitequal(tr_full, tr_res)


def test_checkpoint_resume_device_data(tiny, tmp_path):
    cfg, model, base = tiny
    path = str(tmp_path / "resume_dev.npz")
    tr_full = make_trainer(model, base, data_mode="device", chunk_rounds=3)
    tr_full.run(6)
    tr_half = make_trainer(model, base, data_mode="device", chunk_rounds=3)
    tr_half.run(3)
    tr_half.save(path)
    tr_res = make_trainer(model, base, data_mode="device", chunk_rounds=3)
    tr_res.restore(path)
    tr_res.run(3)
    assert_state_bitequal(tr_full, tr_res)


# -------------------------------------------------------------------- mesh

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, numpy as np
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.launch.mesh import mesh_from_spec

cfg = ModelConfig(name="m", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64)
from repro.models.api import build_model
model = build_model(cfg)
base = model.init(jax.random.key(0))

def make(mesh, data_mode):
    ds = FederatedDataset(64, 4, seq_len=32, batch_per_client=2, seed=0)
    return FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=8),
        fed_cfg=FederatedConfig(num_clients=4, local_steps=2,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), base_params=base,
        chunk_rounds=3, mesh=mesh, data_mode=data_mode)

ref = make(None, "host"); ref.run(3)
mesh = mesh_from_spec("4x2")
tr = make(mesh, "host"); tr.run(3)          # client dim sharded over "data"
ok = all(np.allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-7)
         for x, y in zip(jax.tree.leaves(ref.lora), jax.tree.leaves(tr.lora)))
a_shard = str(jax.tree.leaves(tr.lora)[0].sharding.spec)
dev = make(mesh, "device"); dev.run(3)      # on-device data on the mesh
print(json.dumps({"match": bool(ok), "a_spec": a_shard,
                  "dev_loss_finite": bool(np.isfinite(
                      dev.history[-1]["loss"]))}))
"""


@pytest.mark.slow
def test_trainer_on_mesh_matches_single_device(tmp_path):
    """The real trainer with mesh=...: client dim sharded over 'data',
    numerics match the 1-device run, device-data mode runs on the mesh.
    Subprocess: jax locks the device count at first init."""
    import json
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["match"], rec
    assert "data" in rec["a_spec"], rec
    assert rec["dev_loss_finite"], rec


def test_engine_history_and_metrics_format(tiny):
    cfg, model, base = tiny
    tr = make_trainer(model, base, chunk_rounds=3)
    hist = tr.run(3)
    assert [h["round"] for h in hist] == [1, 2, 3]
    assert all(isinstance(h["loss"], float) and
               isinstance(h["grad_norm"], float) for h in hist)

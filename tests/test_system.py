"""End-to-end behaviour tests for the federated LoRA system (the paper's
protocol on a reduced model): training converges, FedSA invariants hold,
SFed-LoRA's stability advantages materialize, checkpoints round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="sys", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64)
    model = build_model(cfg)
    base = model.init(jax.random.key(0))
    return cfg, model, base


def make_trainer(model, base, vocab, *, scaling="sfedlora", rank=8, n=3,
                 strategy="fedsa", lr=0.05, partition="iid", seed=0):
    ds = FederatedDataset(vocab, n, seq_len=32, batch_per_client=4,
                          partition=partition, seed=seed)
    return FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=rank, alpha=8.0, scaling=scaling),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=2,
                                aggregation=strategy, partition=partition),
        opt_cfg=OptimizerConfig(name="sgd", lr=lr), seed=seed,
        base_params=base)


def test_training_reduces_loss(setup):
    cfg, model, base = setup
    tr = make_trainer(model, base, cfg.vocab_size, lr=0.3)
    hist = tr.run(20)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.05, (first, last)


def test_gamma_derived_from_client_count(setup):
    cfg, model, base = setup
    t4 = make_trainer(model, base, cfg.vocab_size, n=4, rank=16)
    t9 = make_trainer(model, base, cfg.vocab_size, n=9, rank=16)
    assert t9.gamma / t4.gamma == pytest.approx(np.sqrt(9 / 4))


def test_gradient_norm_rank_stability(setup):
    """The paper's core empirical claim at reduced scale: with alpha/r the
    mean gradient norm collapses with rank; with sqrt(N/r) it stays flat."""
    cfg, model, base = setup
    norms = {}
    for scaling in ("lora", "sfedlora"):
        for rank in (4, 256):
            tr = make_trainer(model, base, cfg.vocab_size, scaling=scaling,
                              rank=rank)
            tr.run(5)
            norms[(scaling, rank)] = np.mean(
                [h["grad_norm"] for h in tr.history])
    collapse_lora = norms[("lora", 4)] / norms[("lora", 256)]
    collapse_sfed = norms[("sfedlora", 4)] / norms[("sfedlora", 256)]
    assert collapse_lora > 4 * collapse_sfed, norms
    assert 0.2 < collapse_sfed < 5.0, norms


def test_fedsa_personalization(setup):
    """B must diverge across clients under non-IID data while A stays synced."""
    cfg, model, base = setup
    tr = make_trainer(model, base, cfg.vocab_size, partition="dirichlet")
    tr.run(3)
    q = tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]
    np.testing.assert_allclose(np.asarray(q["a"][0]), np.asarray(q["a"][1]),
                               rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(q["b"][0]), np.asarray(q["b"][1]))


def test_all_strategies_run(setup):
    cfg, model, base = setup
    for strategy in ("fedit", "ffa", "fedsa", "rolora"):
        tr = make_trainer(model, base, cfg.vocab_size, strategy=strategy)
        m = tr.run(2)[-1]
        assert np.isfinite(m["loss"]), strategy


def test_ffa_freezes_a(setup):
    cfg, model, base = setup
    tr = make_trainer(model, base, cfg.vocab_size, strategy="ffa")
    a0 = np.asarray(tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"]).copy()
    tr.run(3)
    a1 = np.asarray(tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"])
    np.testing.assert_allclose(a0, a1, rtol=1e-6, atol=1e-7)


def test_checkpoint_roundtrip(setup, tmp_path):
    cfg, model, base = setup
    from repro.checkpoint.io import (load_federated_state,
                                     save_federated_state)
    tr = make_trainer(model, base, cfg.vocab_size)
    tr.run(2)
    path = str(tmp_path / "state.npz")
    save_federated_state(path, tr.base, tr.lora, tr.opt_state, tr.round_idx)
    b2, l2, o2, r2 = load_federated_state(path)
    assert r2 == tr.round_idx
    for x, y in zip(jax.tree.leaves(tr.lora), jax.tree.leaves(l2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_adamw_optimizer_path(setup):
    cfg, model, base = setup
    ds = FederatedDataset(cfg.vocab_size, 2, seq_len=32, batch_per_client=2)
    tr = FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=8, scaling="sfedlora"),
        fed_cfg=FederatedConfig(num_clients=2, local_steps=1),
        opt_cfg=OptimizerConfig(name="adamw", lr=1e-3), base_params=base)
    hist = tr.run(3)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_data_partitions():
    from repro.data.synthetic import client_topic_mixtures
    iid = client_topic_mixtures(4, 8, partition="iid")
    np.testing.assert_allclose(iid, 1 / 8)
    nid = client_topic_mixtures(4, 8, partition="dirichlet",
                                dirichlet_alpha=0.5)
    np.testing.assert_allclose(nid.sum(1), 1.0, rtol=1e-6)
    assert nid.std() > iid.std()

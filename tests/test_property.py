"""Hypothesis property-based tests on the system's invariants.

``hypothesis`` is an optional test dependency (``pip install hypothesis`` or
the ``[test]`` extra in pyproject.toml); without it this module skips instead
of breaking collection for the whole suite.
"""
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (_map_ab_pairs, aggregate_clients,
                                    negate_flag, strategy_flags)
from repro.core.scaling import predicted_moment_scale, scaling_factor
from repro.kernels import ref
from repro.kernels.lora_matmul import lora_matmul
from repro.models.attention import make_mask
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    global_norm, sgd)

SET = dict(max_examples=25, deadline=None)


@given(r=st.integers(1, 4096), n=st.integers(1, 64),
       alpha=st.floats(0.5, 64, allow_nan=False))
@settings(**SET)
def test_sfed_moment_invariant(r, n, alpha):
    """gamma_z^2 * r / N == alpha^2 exactly, for all (N, r, alpha)."""
    g = scaling_factor("sfedlora", alpha, r, n)
    assert abs(predicted_moment_scale(g, r, n) - alpha ** 2) < 1e-6 * alpha**2


@given(r=st.integers(1, 2048), n=st.integers(1, 64))
@settings(**SET)
def test_scaling_ordering(r, n):
    """Paper App. B.3: za < sfedlora (for alpha>=1, N>=1) and zb >= sfedlora
    for N >= alpha^(2/3)... we check the literal claims: za <= rslora <=
    sfedlora at alpha=8 with N>=1, and zb > sfedlora for N >= 4."""
    a = 8.0
    za = scaling_factor("za", a, r, n)
    rs = scaling_factor("rslora", a, r, n)
    sf = scaling_factor("sfedlora", a, r, n)
    zb = scaling_factor("zb", a, r, n)
    assert za <= rs <= sf + 1e-12
    if n >= 4:
        assert zb >= sf


@given(n=st.integers(2, 5), seed=st.integers(0, 100))
@settings(**SET)
def test_aggregation_idempotent_and_mean_preserving(n, seed):
    """Aggregating twice == aggregating once; client mean preserved."""
    key = jax.random.key(seed)
    lora = {"x": {"attn": {"q": {
        "a": jax.random.normal(key, (n, 4, 8)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 8, 4))}}}}
    out = aggregate_clients(lora, True, False)
    out2 = aggregate_clients(out, True, False)
    a, a2 = out["x"]["attn"]["q"]["a"], out2["x"]["attn"]["q"]["a"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(a2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a.mean(0)),
                               np.asarray(lora["x"]["attn"]["q"]["a"].mean(0)),
                               rtol=1e-5, atol=1e-6)
    # b untouched
    np.testing.assert_array_equal(np.asarray(out["x"]["attn"]["q"]["b"]),
                                  np.asarray(lora["x"]["attn"]["q"]["b"]))


@given(n=st.integers(2, 6), seed=st.integers(0, 100),
       scale=st.floats(0.1, 100, allow_nan=False))
@settings(**SET)
def test_weight_normalization_arbitrary_nonnegative_weights(n, seed, scale):
    """The weighted aggregate is the convex combination sum w_i x_i / sum w
    for ARBITRARY non-negative weights (not just 0/1 participation masks) —
    and is invariant to rescaling the weight vector, which is what lets
    raw per-client example counts serve as size weights unnormalized."""
    key = jax.random.key(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n, 3, 5))
    w = jax.random.uniform(kw, (n,)) * jnp.arange(n)  # weight 0 included
    lora = {"x": {"attn": {"q": {"a": x, "b": jnp.zeros((n, 5, 3))}}}}
    out = aggregate_clients(lora, True, False, weights=w)["x"]["attn"]["q"]
    wn = np.asarray(w) / np.asarray(w).sum()
    want = np.einsum("n,nij->ij", wn, np.asarray(x))
    for i in range(n):
        np.testing.assert_allclose(np.asarray(out["a"][i]), want,
                                   rtol=1e-5, atol=1e-6)
    out2 = aggregate_clients(lora, True, False,
                             weights=w * scale)["x"]["attn"]["q"]
    np.testing.assert_allclose(np.asarray(out2["a"]), np.asarray(out["a"]),
                               rtol=1e-5, atol=1e-6)


@given(flag=st.booleans())
@settings(**SET)
def test_negate_flag_concrete_and_traced_agree(flag):
    """negate_flag is logical NOT on python bools (returning a bool) and on
    traced / 0-d device bools (where `not` would raise)."""
    out = negate_flag(flag)
    assert isinstance(out, bool) and out == (not flag)
    traced = jax.jit(negate_flag)(jnp.asarray(flag))
    assert bool(traced) == (not flag)
    assert bool(negate_flag(jnp.asarray(flag))) == (not flag)


@given(which=st.sampled_from(["a", "b"]), seed=st.integers(0, 20))
@settings(**SET)
def test_map_ab_pairs_rejects_partial_adapter_nodes(which, seed):
    """Pair-coupled aggregation over an a-only / b-only node must raise —
    silently skipping would leave that adapter unaggregated and let
    clients diverge."""
    node = {which: jax.random.normal(jax.random.key(seed), (2, 3, 4))}
    with pytest.raises(ValueError, match="needs both 'a' and 'b'"):
        _map_ab_pairs({"x": {"q": node}}, lambda n: n)
    # a complete sibling node does not mask the error
    full = {"a": jnp.zeros((2, 3, 4)), "b": jnp.zeros((2, 4, 3))}
    with pytest.raises(ValueError, match="needs both 'a' and 'b'"):
        _map_ab_pairs({"x": {"q": node, "k": full}}, lambda n: n)


@given(s=st.integers(1, 33), t=st.integers(1, 33),
       window=st.one_of(st.none(), st.integers(1, 40)))
@settings(**SET)
def test_mask_properties(s, t, window):
    pq = jnp.arange(s)[None]
    pk = jnp.arange(t)[None]
    m = make_mask(pq, pk, causal=True, window=window)
    m = np.asarray(m[0])
    # diagonal always visible (self-attention never fully masked)
    for i in range(min(s, t)):
        assert m[i, i]
    # strictly causal
    assert not m[np.triu_indices_from(m, k=1)].any()
    if window is not None:
        ii, jj = np.nonzero(m)
        assert ((ii - jj) < window).all()


@given(m=st.sampled_from([64, 128]), k=st.sampled_from([64, 128]),
       nn=st.sampled_from([64, 128]), r=st.sampled_from([2, 8, 16]),
       gamma=st.floats(0, 8, allow_nan=False), seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_lora_matmul_kernel_property(m, k, nn, r, gamma, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, nn)) * k ** -0.5
    a = jax.random.normal(ks[2], (r, k)) * 0.05
    b = jax.random.normal(ks[3], (nn, r)) * 0.05
    out = lora_matmul(x, w, a, b, gamma, bm=64, bn=64, bk=64, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


@given(seed=st.integers(0, 100), lr=st.floats(1e-4, 1e-1),
       momentum=st.floats(0, 0.95))
@settings(**SET)
def test_sgd_descends_quadratic(seed, lr, momentum):
    key = jax.random.key(seed)
    x = {"p": jax.random.normal(key, (8,))}
    init, update = sgd(lr, momentum)
    st_ = init(x)
    for _ in range(5):
        g = jax.tree.map(lambda v: 2 * v, x)     # d/dx ||x||^2
        upd, st_ = update(g, st_, x)
        x2 = apply_updates(x, upd)
        x = x2
    assert float(global_norm(x)) <= float(
        global_norm({"p": jax.random.normal(key, (8,))})) + 1e-6


@given(seed=st.integers(0, 100), max_norm=st.floats(0.01, 10))
@settings(**SET)
def test_clip_by_global_norm(seed, max_norm):
    g = {"a": jax.random.normal(jax.random.key(seed), (16,)) * 10}
    clipped = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * 1.001

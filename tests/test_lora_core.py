"""LoRA tree construction, merging, and aggregation-strategy semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LoRAConfig, ModelConfig
from repro.core.aggregation import (aggregate_clients, mask_grads,
                                    strategy_flags, upload_bytes)
from repro.core.lora import (AdapterSet, init_lora, merge_lora,
                             num_lora_params, split_ab)
from repro.models.api import build_model


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_lora_targets_qv_only(tiny):
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    attn = lora["stack"]["repeat"]["p0"]["attn"]
    assert set(attn) == {"q", "v"}
    assert attn["q"]["a"].shape == (3, 4, 64)       # stacked over layers
    assert attn["v"]["b"].shape == (3, 32, 4)       # kv_dim = 2*16
    assert float(jnp.abs(attn["q"]["b"]).max()) == 0.0   # B init zero


def test_lora_targets_extended(tiny):
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1),
                     LoRAConfig(rank=4, targets=("q", "k", "v", "o")))
    assert set(lora["stack"]["repeat"]["p0"]["attn"]) == {"q", "k", "v", "o"}


def test_merge_lora_equals_runtime_adapter(tiny):
    """W0 + gamma*BA merged == forward with runtime adapters (zero-latency
    deployment claim)."""
    cfg, model, params = tiny
    lcfg = LoRAConfig(rank=4)
    lora = init_lora(params, jax.random.key(1), lcfg)
    # make B nonzero so the test is nontrivial
    lora = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(jax.random.key(2), x.shape),
        lora)
    gamma = 1.7
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0, 128)
    aset = AdapterSet(lora=lora, gamma=gamma, rank=4)
    with_adapter, _ = model.forward(params, {"tokens": toks}, adapters=aset)
    merged = aset.merge(params)
    with_merged, _ = model.forward(merged, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(with_adapter),
                               np.asarray(with_merged), rtol=1e-4, atol=1e-4)


def test_split_ab(tiny):
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    a, b = split_ab(lora)
    assert num_lora_params(a) + num_lora_params(b) == num_lora_params(lora)


def test_split_ab_tolerates_partial_nodes(tiny):
    """Re-splitting an already-split tree (a-only / b-only nodes) works."""
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    a_tree, b_tree = split_ab(lora)
    a2, b2 = split_ab(a_tree)
    assert num_lora_params(a2) == num_lora_params(a_tree)
    assert num_lora_params(b2) == 0
    a3, b3 = split_ab(b_tree)
    assert num_lora_params(a3) == 0
    assert num_lora_params(b3) == num_lora_params(b_tree)


@pytest.mark.parametrize("strategy,agg_a,agg_b", [
    ("fedit", True, True), ("ffa", False, True),
    ("fedsa", True, False)])
def test_aggregation_selective(tiny, strategy, agg_a, agg_b):
    cfg, model, params = tiny
    lora1 = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    n = 3
    lora = jax.tree.map(
        lambda x: jax.random.normal(jax.random.key(5), (n,) + x.shape), lora1)
    (_, _), (fa, fb) = strategy_flags(strategy, 0)
    assert (bool(fa), bool(fb)) == (agg_a, agg_b)
    out = aggregate_clients(lora, fa, fb)
    q = out["stack"]["repeat"]["p0"]["attn"]["q"]
    a_equal = bool(jnp.allclose(q["a"][0], q["a"][1]))
    b_equal = bool(jnp.allclose(q["b"][0], q["b"][1]))
    assert a_equal == agg_a and b_equal == agg_b


def test_rolora_alternates(tiny):
    (ta0, tb0), (aa0, ab0) = strategy_flags("rolora", 0)
    (ta1, tb1), (aa1, ab1) = strategy_flags("rolora", 1)
    assert (ta0, tb0) == (True, False) and (ta1, tb1) == (False, True)


def test_mask_grads_freezes(tiny):
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    ones = jax.tree.map(jnp.ones_like, lora)
    masked = mask_grads(ones, True, False)
    q = masked["stack"]["repeat"]["p0"]["attn"]["q"]
    assert float(q["a"].min()) == 1.0 and float(jnp.abs(q["b"]).max()) == 0.0


def test_upload_bytes_fedsa_half_of_fedit():
    cfg = get_config("llama2-7b")
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    lora = init_lora(zeros, jax.random.key(1), LoRAConfig(rank=8))
    lora_n = jax.tree.map(lambda x: x[None], lora)
    fedit = upload_bytes(lora_n, True, True)
    fedsa = upload_bytes(lora_n, True, False)
    assert fedsa < fedit
    # q adapters: A (r,4096)+B(4096,r) symmetric; v same -> exactly half
    assert fedsa * 2 == fedit


def test_upload_bytes_accepts_concrete_rolora_flags(tiny):
    """Regression: rolora flags from a concrete round_idx (numpy bools /
    0-d jnp arrays) must not raise — only traced flags are rejected."""
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    lora_n = jax.tree.map(lambda x: x[None], lora)
    (_, _), (a0, b0) = strategy_flags("rolora", 0)
    (_, _), (a1, b1) = strategy_flags("rolora", 1)
    even = upload_bytes(lora_n, a0, b0)          # A rounds upload A only
    odd = upload_bytes(lora_n, a1, b1)           # B rounds upload B only
    assert even > 0 and odd > 0
    assert even + odd == upload_bytes(lora_n, True, True)
    # concrete ints and 0-d device arrays also work
    assert upload_bytes(lora_n, 1, 0) == even
    assert upload_bytes(lora_n, jnp.asarray(True), jnp.asarray(False)) == even


def test_upload_bytes_rejects_traced_flags(tiny):
    """Host-only: traced flags (rolora under jit) raise a clear TypeError
    instead of a TracerBoolConversionError deep inside."""
    cfg, model, params = tiny
    lora = init_lora(params, jax.random.key(1), LoRAConfig(rank=4))
    lora_n = jax.tree.map(lambda x: x[None], lora)

    def traced(round_idx):
        (_, _), (aa, ab) = strategy_flags("rolora", round_idx)
        return upload_bytes(lora_n, aa, ab)

    with pytest.raises(TypeError, match="host-only"):
        jax.jit(traced)(jnp.asarray(0))

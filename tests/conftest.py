"""Shared test harness options.

``pytest --recompile-guard`` wraps every jitted serving engine built
through ``serve._model_jit`` in a :class:`repro.analysis.sanitizers.
RecompileGuard` (wrap mode, fresh guard per test): a recompile on a
previously-served signature, or unbounded treedef churn at fixed avals,
fails the offending test at the offending call instead of showing up as
slowness.  Off by default — the guard adds a per-call signature hash.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--recompile-guard", action="store_true", default=False,
        help="run serve's jitted engines under a RecompileGuard "
             "(recompiles on served signatures become hard errors)")


@pytest.fixture(autouse=True)
def _recompile_guard(request, monkeypatch):
    if not request.config.getoption("--recompile-guard"):
        yield
        return
    from repro.analysis.sanitizers import RecompileGuard
    from repro.launch import serve

    guard = RecompileGuard(max_treedef_variants=8)
    orig = serve._model_jit

    def guarded_model_jit(model, name, builder):
        # the raw jitted fn stays in model._serve_jit_cache (tests probe
        # _cache_size there); only the handle serve dispatches through is
        # wrapped, so attribution lands on the engine name
        fn = orig(model, name, builder)
        return guard.wrap(name, fn, cache_probe=fn)

    monkeypatch.setattr(serve, "_model_jit", guarded_model_jit)
    yield guard

"""Dry-run machinery unit tests that need no multi-device compile: the
collective-bytes HLO parser, shape policy, input specs, and sharding rules."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED, INPUT_SHAPES, LONG_CONTEXT_OK,
                           config_for_shape, get_config, supports_shape)
from repro.models.api import build_model

# import the parser without triggering the XLA_FLAGS device split
import importlib.util
import os
import sys

_spec = importlib.util.spec_from_file_location(
    "_dryrun_parse", os.path.join(os.path.dirname(__file__), "..", "src",
                                  "repro", "launch", "dryrun.py"))


def _load_parser():
    # dryrun sets XLA_FLAGS at import; jax is already initialized in tests so
    # the flag has no effect here — safe to import for the pure functions.
    mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(mod)
    return mod


HLO = """
  %ag = bf16[4,1024]{1,0} all-gather(%p), replica_groups={}
  %ar = f32[512]{0} all-reduce(%q), to_apply=%sum
  %aa = (bf16[2,8]{1,0}, bf16[2,8]{1,0}) all-to-all(%a, %b)
  %cp = u32[16]{0} collective-permute(%c)
  %ags = bf16[64]{0} all-gather-start(%p)
  %dot = f32[4,4]{1,0} dot(%x, %y)
"""


def test_collective_bytes_parser():
    mod = _load_parser()
    out, counts = mod.collective_bytes(HLO)
    assert out["all-gather"] == 4 * 1024 * 2 + 64 * 2      # incl. -start
    assert counts["all-gather"] == 2
    assert out["all-reduce"] == 512 * 4
    assert out["all-to-all"] == 2 * (2 * 8 * 2)            # tuple result
    assert out["collective-permute"] == 16 * 4
    assert counts["reduce-scatter"] == 0


def test_long_context_policy():
    for arch in ASSIGNED:
        ok = supports_shape(arch, "long_500k")
        assert ok == (LONG_CONTEXT_OK[arch] is not None)
    assert not supports_shape("roberta-large", "decode_32k")
    assert supports_shape("roberta-large", "train_4k")


def test_sliding_window_variant_selected():
    cfg = config_for_shape("mistral-nemo-12b", "long_500k")
    assert cfg.attn_window == 4096
    cfg = config_for_shape("mistral-nemo-12b", "train_4k")
    assert cfg.attn_window is None
    # natively sub-quadratic archs keep their config
    cfg = config_for_shape("recurrentgemma-9b", "long_500k")
    assert cfg.block_pattern == ("rglru", "rglru", "attn")


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_input_specs_all_shapes(arch):
    """input_specs produce consistent ShapeDtypeStructs for every shape
    (no allocation — pure eval_shape)."""
    for name, shape in INPUT_SHAPES.items():
        if not supports_shape(arch, name):
            continue
        cfg = config_for_shape(arch, name)
        model = build_model(cfg)
        if shape.kind == "train":
            spec = model.input_specs(shape, n_clients=16)
            assert spec["tokens"].shape[0] == 16
            assert spec["tokens"].shape[1] == shape.global_batch // 16
        elif shape.kind == "prefill":
            spec = model.input_specs(shape)
            tok_s = spec["tokens"].shape[1]
            if cfg.family == "vlm":
                assert tok_s == shape.seq_len - cfg.num_patches
            else:
                assert tok_s == shape.seq_len
        else:
            spec = model.input_specs(shape)
            assert spec["token"].shape == (shape.global_batch, 1)
            assert "cache" in spec
            # window archs cap the cache at the window size
            leaves = jax.tree.leaves(spec["cache"])
            assert leaves, arch


def test_param_spec_rules():
    from repro.sharding.rules import param_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    assert param_spec(("embed",), (131072, 5120), m) == P("model", None)
    assert param_spec(("stack", "repeat", "p0", "attn", "q"),
                      (40, 5120, 4096), m) == P(None, None, "model")
    # kv dim not divisible -> replicated
    assert param_spec(("stack", "repeat", "p0", "attn", "k"),
                      (40, 5120, 8 * 128), m) == P(None, None, "model")
    assert param_spec(("stack", "repeat", "p0", "moe", "w_gate"),
                      (24, 64, 2048, 1408), m) == P(None, "model", None, None)
    assert param_spec(("stack", "tail", "t0", "mlp", "w_down"),
                      (14336, 5120), m) == P("model", None)
    assert param_spec(("final_scale",), (5120,), m) == P(None)

"""Pallas kernel validation: shape/dtype sweeps + assert_allclose against the
ref.py pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ops import flash_mha, fused_lora_matmul, rglru_scan_op
from repro.kernels.rglru_scan import rglru_scan_pallas


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -------------------------------------------------------------- lora_matmul

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r", [
    (128, 256, 128, 4),
    (256, 512, 512, 8),
    (512, 512, 256, 64),
    (256, 1024, 256, 128),
    (128, 128, 128, 512),   # paper's extreme rank
])
def test_lora_matmul_sweep(m, k, n, r, dtype):
    ks = jax.random.split(jax.random.key(m * 7 + r), 4)
    x = _rand(ks[0], (m, k), dtype)
    w = _rand(ks[1], (k, n), dtype, k ** -0.5)
    a = _rand(ks[2], (r, k), dtype, 0.02)
    b = _rand(ks[3], (n, r), dtype, 0.02)
    gamma = 8.0 / np.sqrt(r)
    out = lora_matmul(x, w, a, b, gamma, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, gamma)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_lora_matmul_gamma_zero_is_base_matmul():
    ks = jax.random.split(jax.random.key(0), 4)
    x = _rand(ks[0], (128, 256), jnp.float32)
    w = _rand(ks[1], (256, 128), jnp.float32)
    a = _rand(ks[2], (8, 256), jnp.float32)
    b = _rand(ks[3], (128, 8), jnp.float32)
    out = lora_matmul(x, w, a, b, 0.0, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=2e-5,
                               atol=2e-4)


def test_fused_lora_matmul_batched_wrapper():
    ks = jax.random.split(jax.random.key(3), 4)
    x = _rand(ks[0], (2, 4, 128, 256), jnp.float32)
    w = _rand(ks[1], (256, 128), jnp.float32)
    a = _rand(ks[2], (16, 256), jnp.float32, 0.02)
    b = _rand(ks[3], (128, 16), jnp.float32, 0.02)
    out = fused_lora_matmul(x, w, a, b, 2.0)
    want = ref.lora_matmul_ref(x.reshape(-1, 256), w, a, b, 2.0
                               ).reshape(2, 4, 128, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-4)


# ---------------------------------------------------------- flash_attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,t,d,causal,window", [
    (256, 256, 64, True, None),
    (256, 256, 128, False, None),
    (512, 512, 64, True, 128),    # sliding window
    (128, 512, 64, False, None),  # cross-attention shape
])
def test_flash_attention_sweep(s, t, d, causal, window, dtype):
    bh = 4
    ks = jax.random.split(jax.random.key(s + d), 3)
    q = _rand(ks[0], (bh, s, d), dtype)
    k = _rand(ks[1], (bh, t, d), dtype)
    v = _rand(ks[2], (bh, t, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=128,
                          bk=128, interpret=True)
    want = ref.flash_attention_ref(q[:, :, None], k[:, :, None],
                                   v[:, :, None], causal=causal,
                                   window=window)[:, :, 0]
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 5)


def test_flash_mha_gqa_expansion():
    b, s, h, kh, d = 2, 256, 8, 2, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, kh, d), jnp.float32)
    v = _rand(ks[2], (b, s, kh, d), jnp.float32)
    out = flash_mha(q, k, v, causal=True)
    kx = jnp.repeat(k, h // kh, axis=2)
    vx = jnp.repeat(v, h // kh, axis=2)
    want = ref.flash_attention_ref(q, kx, vx, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=1e-4)


# -------------------------------------------------------------- rglru_scan

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bt,s,d,bs", [
    (2, 64, 32, 16),
    (4, 128, 128, 128),
    (1, 256, 64, 64),
])
def test_rglru_scan_sweep(bt, s, d, bs, dtype):
    ks = jax.random.split(jax.random.key(s * 3 + d), 2)
    a = jax.random.uniform(ks[0], (bt, s, d), jnp.float32, 0.5,
                           0.999).astype(dtype)
    b = _rand(ks[1], (bt, s, d), dtype, 0.5)
    out = rglru_scan_pallas(a, b, block_seq=bs, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 5)


def test_rglru_matches_model_associative_scan():
    """The Pallas kernel and the model's associative_scan agree."""
    from repro.models.rglru import rglru_scan
    ks = jax.random.split(jax.random.key(5), 2)
    a = jax.random.uniform(ks[0], (2, 64, 32), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(ks[1], (2, 64, 32), jnp.float32)
    np.testing.assert_allclose(np.asarray(rglru_scan_op(a, b)),
                               np.asarray(rglru_scan(a, b)), rtol=1e-5,
                               atol=1e-5)

"""Scaling-factor unit tests + the paper's analytic stability claims."""
import math

import jax
import numpy as np
import pytest

from repro.core.scaling import (SCALINGS, per_client_gammas,
                                predicted_moment_scale, scaling_factor)
from repro.core.stability import aggregated_moment_sweep


def test_scaling_values():
    # paper formulas at alpha=8
    assert scaling_factor("lora", 8, 16, 4) == pytest.approx(0.5)
    assert scaling_factor("rslora", 8, 16, 4) == pytest.approx(2.0)
    assert scaling_factor("sfedlora", 8, 16, 4) == pytest.approx(4.0)
    assert scaling_factor("za", 8, 16, 4) == pytest.approx(1 / 8)
    assert scaling_factor("zb", 8, 16, 4) == pytest.approx(4.0)


def test_sfedlora_reduces_to_rslora_single_client():
    for r in (4, 64, 512):
        assert scaling_factor("sfedlora", 8, r, 1) == pytest.approx(
            scaling_factor("rslora", 8, r, 1))


def test_unknown_scaling_raises():
    with pytest.raises(ValueError):
        scaling_factor("bogus", 8, 16, 4)


@pytest.mark.parametrize("name", sorted(SCALINGS))
def test_degenerate_rank_and_client_count_raise(name):
    """r=0 / n_clients=0 used to flow straight into the formulas (division
    by zero, sqrt(0) gammas); every scheme must refuse with a clear
    message instead."""
    for bad_r in (0, -3):
        with pytest.raises(ValueError, match="rank r >= 1"):
            scaling_factor(name, 8.0, bad_r, 4)
    for bad_n in (0, -1):
        with pytest.raises(ValueError, match="n_clients >= 1"):
            scaling_factor(name, 8.0, 16, bad_n)
    # valid edge: a single client at rank 1 is fine for every scheme
    assert math.isfinite(scaling_factor(name, 8.0, 1, 1))


def test_per_client_gammas():
    """gamma_i = scaling(alpha, r_i, N): per-rank application of the
    homogeneous formula, collapsing to it under uniform ranks."""
    gs = per_client_gammas("sfedlora", 8.0, (4, 16, 16), 3)
    assert gs == tuple(scaling_factor("sfedlora", 8.0, r, 3)
                       for r in (4, 16, 16))
    assert gs[1] == gs[2] and gs[0] == 2 * gs[1]     # sqrt(16/4) = 2
    uniform = per_client_gammas("lora", 8.0, (8, 8), 2)
    assert set(uniform) == {scaling_factor("lora", 8.0, 8, 2)}
    with pytest.raises(ValueError, match="rank r >= 1"):
        per_client_gammas("sfedlora", 8.0, (4, 0), 2)


def test_moment_scale_invariance_theorem():
    """Theorem 4.2: gamma^2 * r / N is (N, r)-invariant iff gamma=a*sqrt(N/r)."""
    vals = {predicted_moment_scale(scaling_factor("sfedlora", 8, r, n), r, n)
            for r in (4, 64, 512) for n in (1, 5, 20)}
    assert max(vals) / min(vals) == pytest.approx(1.0, rel=1e-9)
    # and NOT invariant for the baselines
    for name in ("lora", "rslora"):
        vals = [predicted_moment_scale(scaling_factor(name, 8, r, n), r, n)
                for r in (4, 512) for n in (1, 20)]
        assert max(vals) / min(vals) > 10


def test_empirical_aggregated_moment_matches_theory():
    """App. A one-step simulation: measured adapter moment scales like
    gamma^2 r/N (up to constants): sfedlora flat, lora decaying in r."""
    sweep = aggregated_moment_sweep(jax.random.key(0), d=256,
                                    ranks=(8, 128), clients=(1, 8))
    s = sweep["sfedlora"]
    # rank-invariance within each client count (ratio near 1, loose tol)
    for n in (1, 8):
        ratio = s[(n, 8)] / s[(n, 128)]
        assert 0.3 < ratio < 3.0, (n, ratio)
    lo = sweep["lora"]
    assert lo[(8, 8)] / max(lo[(8, 128)], 1e-12) > 8  # ~ (128/8) decay

"""Scaling-factor unit tests + the paper's analytic stability claims."""
import math

import jax
import numpy as np
import pytest

from repro.core.scaling import (SCALINGS, predicted_moment_scale,
                                scaling_factor)
from repro.core.stability import aggregated_moment_sweep


def test_scaling_values():
    # paper formulas at alpha=8
    assert scaling_factor("lora", 8, 16, 4) == pytest.approx(0.5)
    assert scaling_factor("rslora", 8, 16, 4) == pytest.approx(2.0)
    assert scaling_factor("sfedlora", 8, 16, 4) == pytest.approx(4.0)
    assert scaling_factor("za", 8, 16, 4) == pytest.approx(1 / 8)
    assert scaling_factor("zb", 8, 16, 4) == pytest.approx(4.0)


def test_sfedlora_reduces_to_rslora_single_client():
    for r in (4, 64, 512):
        assert scaling_factor("sfedlora", 8, r, 1) == pytest.approx(
            scaling_factor("rslora", 8, r, 1))


def test_unknown_scaling_raises():
    with pytest.raises(ValueError):
        scaling_factor("bogus", 8, 16, 4)


def test_moment_scale_invariance_theorem():
    """Theorem 4.2: gamma^2 * r / N is (N, r)-invariant iff gamma=a*sqrt(N/r)."""
    vals = {predicted_moment_scale(scaling_factor("sfedlora", 8, r, n), r, n)
            for r in (4, 64, 512) for n in (1, 5, 20)}
    assert max(vals) / min(vals) == pytest.approx(1.0, rel=1e-9)
    # and NOT invariant for the baselines
    for name in ("lora", "rslora"):
        vals = [predicted_moment_scale(scaling_factor(name, 8, r, n), r, n)
                for r in (4, 512) for n in (1, 20)]
        assert max(vals) / min(vals) > 10


def test_empirical_aggregated_moment_matches_theory():
    """App. A one-step simulation: measured adapter moment scales like
    gamma^2 r/N (up to constants): sfedlora flat, lora decaying in r."""
    sweep = aggregated_moment_sweep(jax.random.key(0), d=256,
                                    ranks=(8, 128), clients=(1, 8))
    s = sweep["sfedlora"]
    # rank-invariance within each client count (ratio near 1, loose tol)
    for n in (1, 8):
        ratio = s[(n, 8)] / s[(n, 128)]
        assert 0.3 < ratio < 3.0, (n, ratio)
    lo = sweep["lora"]
    assert lo[(8, 8)] / max(lo[(8, 128)], 1e-12) > 8  # ~ (128/8) decay

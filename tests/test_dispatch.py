"""Kernel-dispatch subsystem: custom-VJP fwd+bwd parity vs the ref.py oracle
(interpret mode, including padded non-block-divisible shapes), tier selection,
and proof that the model forward/backward route through the dispatcher when
``use_pallas`` is enabled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig, ModelConfig
from repro.core.lora import AdapterSet, init_lora
from repro.kernels import dispatch, ref
from repro.kernels.lora_matmul import lora_matmul_vjp
from repro.models.api import build_model


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    dispatch.reset_stats()
    yield
    dispatch.force_mode(None)


def _operands(m, k, n, r, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * k ** -0.5
    a = jax.random.normal(ks[2], (r, k), jnp.float32) * 0.05
    b = jax.random.normal(ks[3], (n, r), jnp.float32) * 0.05
    return x, w, a, b


# ------------------------------------------------------- custom-VJP parity

@pytest.mark.parametrize("m,k,n,r", [(64, 64, 64, 4), (128, 256, 128, 16)])
def test_vjp_forward_parity(m, k, n, r):
    x, w, a, b = _operands(m, k, n, r)
    out = lora_matmul_vjp(x, w, a, b, 1.5, bm=64, bn=64, bk=64, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, 1.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("m,k,n,r", [(64, 64, 64, 8), (128, 128, 64, 4)])
def test_vjp_backward_parity(m, k, n, r):
    x, w, a, b = _operands(m, k, n, r, seed=7)
    gamma = 2.0
    cot = jax.random.normal(jax.random.key(99), (m, n))

    def fused(*t):
        return (lora_matmul_vjp(*t, gamma, bm=64, bn=64, bk=64,
                                interpret=True) * cot).sum()

    def reference(*t):
        return (ref.lora_matmul_ref(*t, gamma) * cot).sum()

    got = jax.grad(fused, argnums=(0, 1, 2, 3))(x, w, a, b)
    want = jax.grad(reference, argnums=(0, 1, 2, 3))(x, w, a, b)
    for g1, g2, name in zip(got, want, "xwab"):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4, err_msg=f"d{name}")


@pytest.mark.parametrize("m,k,n,r", [(50, 70, 30, 3), (100, 300, 130, 5)])
def test_dispatch_pads_non_divisible_shapes(m, k, n, r):
    """fused_lora_apply zero-pads to block multiples and slices back — fwd
    and bwd exact for shapes no block size divides."""
    x, w, a, b = _operands(m, k, n, r, seed=3)
    gamma = 1.3
    out = dispatch.fused_lora_apply(x, w, a, b, gamma, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    def fused(*t):
        return dispatch.fused_lora_apply(*t, gamma, interpret=True).sum()

    def reference(*t):
        return ref.lora_matmul_ref(*t, gamma).sum()

    got = jax.grad(fused, argnums=(0, 1, 2, 3))(x, w, a, b)
    want_g = jax.grad(reference, argnums=(0, 1, 2, 3))(x, w, a, b)
    for g1, g2, name in zip(got, want_g, "xwab"):
        assert g1.shape == g2.shape
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4, err_msg=f"d{name}")


# --------------------------------------------------------- tier selection

def test_mode_reference_without_use_pallas():
    assert dispatch.resolve_mode() == "reference"
    dispatch.force_mode("interpret")     # forced tier never overrides off
    assert dispatch.resolve_mode() == "reference"


def test_mode_forced_inside_scope():
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        assert dispatch.resolve_mode() == "interpret"
        dispatch.force_mode(None)
        # CPU backend without REPRO_KERNEL_INTERPRET falls back to reference
        if jax.default_backend() != "tpu":
            import os
            if os.environ.get("REPRO_KERNEL_INTERPRET") in (None, "0", "false"):
                assert dispatch.resolve_mode() == "reference"
    assert dispatch.resolve_mode() == "reference"


def test_force_mode_rejects_unknown():
    with pytest.raises(ValueError):
        dispatch.force_mode("cuda")


def test_fused_tier_handles_empty_operands():
    """Zero-sized dims (empty batch) return empty results on the fused tier
    instead of crashing — same behavior as the reference tier."""
    _, w, a, b = _operands(8, 32, 16, 4)
    empty = jnp.zeros((0, 32), jnp.float32)
    want = dispatch.lora_linear(empty, w, {"a": a, "b": b}, 1.5)
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        got = dispatch.lora_linear(empty, w, {"a": a, "b": b}, 1.5)
    assert got.shape == want.shape == (0, 16)


def test_interpret_env_truthiness(monkeypatch):
    """Only affirmative values enable the interpreter tier — 'False', 'off',
    or an empty value must not silently route training through emulation."""
    if jax.default_backend() == "tpu":
        pytest.skip("TPU selects the pallas tier before the interpret env")
    with dispatch.scope(True):
        for val, want in [("1", "interpret"), ("true", "interpret"),
                          ("ON", "interpret"), ("0", "reference"),
                          ("False", "reference"), ("off", "reference"),
                          ("", "reference")]:
            monkeypatch.setenv("REPRO_KERNEL_INTERPRET", val)
            assert dispatch.resolve_mode() == want, val


# ------------------------------------------------- model-stack integration

def _tiny_cfg(use_pallas):
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                       num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                       vocab_size=64, use_pallas=use_pallas)


def _tiny_setup(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lora = init_lora(params, jax.random.key(2), LoRAConfig(rank=4))
    lora = jax.tree.map(lambda x: x + 0.02, lora)       # make B nonzero
    return model, params, lora


def test_model_forward_routes_through_dispatch():
    """With use_pallas on (interpret tier), the forward provably runs the
    fused kernel — and matches the reference path numerically."""
    dispatch.force_mode("interpret")
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, 64)
    results = {}
    for flag in (False, True):
        model, params, lora = _tiny_setup(_tiny_cfg(flag))
        dispatch.reset_stats()
        logits, _ = model.forward(params, {"tokens": toks},
                                  adapters=AdapterSet(lora=lora, gamma=1.1))
        results[flag] = (np.asarray(logits), dict(dispatch.stats))
    assert results[False][1]["fused"] == 0
    assert results[True][1]["fused"] > 0
    np.testing.assert_allclose(results[True][0], results[False][0],
                               rtol=5e-4, atol=5e-4)


def test_training_grads_match_reference_path():
    """jax.grad of the model loss wrt LoRA params agrees between the fused
    custom-VJP tier and the reference tier — the round-step hot loop is safe
    to route through the kernels."""
    dispatch.force_mode("interpret")
    toks = jax.random.randint(jax.random.key(4), (2, 8), 0, 64)
    grads = {}
    for flag in (False, True):
        model, params, lora = _tiny_setup(_tiny_cfg(flag))

        def loss_fn(l):
            return model.loss(params, {"tokens": toks},
                              adapters=AdapterSet(lora=l, gamma=1.1))[0]

        grads[flag] = jax.grad(loss_fn)(lora)
    for g1, g2 in zip(jax.tree.leaves(grads[True]),
                      jax.tree.leaves(grads[False])):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=2e-3, atol=2e-5)


def test_fused_tier_matches_reference_dtype_promotion():
    """Mixed precision (bf16 activations, fp32 weights): the fused tier must
    produce the same output dtype as the reference tier's `x @ w` promotion,
    so toggling use_pallas never changes downstream numerics."""
    x, w, a, b = _operands(16, 32, 16, 4)
    xb = x.astype(jnp.bfloat16)
    lora = {"a": a, "b": b}
    ref_out = dispatch.lora_linear(xb, w, lora, 1.5)       # reference tier
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        fused_out = dispatch.lora_linear(xb, w, lora, 1.5)
    assert fused_out.dtype == ref_out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(fused_out), np.asarray(ref_out),
                               rtol=1e-2, atol=1e-2)
    # pure-bf16 operands stay bf16 on both tiers
    wb, ab, bb = (t.astype(jnp.bfloat16) for t in (w, a, b))
    ref_out = dispatch.lora_linear(xb, wb, {"a": ab, "b": bb}, 1.5)
    with dispatch.scope(True):
        fused_out = dispatch.lora_linear(xb, wb, {"a": ab, "b": bb}, 1.5)
    assert fused_out.dtype == ref_out.dtype == jnp.bfloat16
    # fp32 adapters on a bf16 base also promote identically
    ref_out = dispatch.lora_linear(xb, wb, {"a": a, "b": b}, 1.5)
    with dispatch.scope(True):
        fused_out = dispatch.lora_linear(xb, wb, {"a": a, "b": b}, 1.5)
    assert fused_out.dtype == ref_out.dtype == jnp.float32


def test_fused_tier_rejects_traced_gamma():
    """gamma is baked into the kernels at trace time; a traced gamma on the
    fused tier must fail with a clear message, not a ConcretizationTypeError
    deep inside (callers jit with gamma in static_argnames, as
    FederatedTrainer.eval_perplexity does)."""
    x, w, a, b = _operands(16, 32, 16, 4)
    lora = {"a": a, "b": b}
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        with pytest.raises(TypeError, match="static"):
            jax.jit(lambda g: dispatch.lora_linear(x, w, lora, g))(
                jnp.asarray(1.5))


def test_jitted_round_step_routes_and_matches_reference():
    """The actual hot loop — make_fed_round_step's jit(vmap(scan(grad(...))))
    — runs on the fused tier and produces the same loss/grad-norm as the
    reference tier (guards the custom-VJP pallas_call against vmap/scan
    batching regressions)."""
    from repro.configs.base import FederatedConfig, OptimizerConfig
    from repro.core.federated import FederatedTrainer
    from repro.data.synthetic import FederatedDataset
    dispatch.force_mode("interpret")
    metrics = {}
    for flag in (False, True):
        cfg = _tiny_cfg(flag)
        model = build_model(cfg)
        ds = FederatedDataset(cfg.vocab_size, 2, seq_len=8, batch_per_client=2)
        tr = FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=4),
                              fed_cfg=FederatedConfig(num_clients=2,
                                                      local_steps=1),
                              opt_cfg=OptimizerConfig(lr=1e-2))
        dispatch.reset_stats()
        metrics[flag] = (tr.run_round(), dict(dispatch.stats))
    assert metrics[False][1]["fused"] == 0
    assert metrics[True][1]["fused"] > 0
    for key in ("loss", "grad_norm"):
        np.testing.assert_allclose(metrics[True][0][key],
                                   metrics[False][0][key], rtol=1e-4)


def test_eval_perplexity_on_fused_tier():
    """FederatedTrainer.eval_perplexity jits the loss with static gamma —
    must work with use_pallas enabled."""
    from repro.configs.base import FederatedConfig, OptimizerConfig
    from repro.core.federated import FederatedTrainer
    from repro.data.synthetic import FederatedDataset
    dispatch.force_mode("interpret")
    cfg = _tiny_cfg(True)
    model = build_model(cfg)
    ds = FederatedDataset(cfg.vocab_size, 2, seq_len=8, batch_per_client=2)
    tr = FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=4),
                          fed_cfg=FederatedConfig(num_clients=2,
                                                  local_steps=1),
                          opt_cfg=OptimizerConfig(lr=1e-2))
    dispatch.reset_stats()
    ppl = tr.eval_perplexity(batch=2)
    assert dispatch.stats["fused"] > 0
    assert np.isfinite(ppl) and ppl > 1.0


def test_decode_step_routes_through_dispatch():
    dispatch.force_mode("interpret")
    model, params, lora = _tiny_setup(_tiny_cfg(True))
    cache = model.init_cache(2, 16)
    dispatch.reset_stats()
    logits, _ = model.decode_step(params, cache, jnp.zeros((2, 1), jnp.int32),
                                  jnp.zeros((2,), jnp.int32),
                                  adapters=AdapterSet(lora=lora, gamma=1.1))
    assert dispatch.stats["fused"] > 0
    assert logits.shape[:2] == (2, 1)

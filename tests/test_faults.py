"""Fault-tolerant federated engine (ISSUE 10): deterministic fault
injection, async buffered aggregation semantics, screening, the
staleness-corrected gamma, checkpointing under faults, and the
collapse-watchdog rollback policy.

The staleness-0 bit-identity anchor (buffered == sync for every strategy,
both tiers) lives in tests/test_conformance.py; this file covers the
engine once faults are ACTIVE, where the conformance guarantee becomes:
same seed + same FaultConfig => same failure schedule => bit-exact replay
(chunking-aligned runs and crash-resume).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.stability_check import (ScalingCollapseError,
                                            recovery_action,
                                            stability_report)
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.faults import FaultConfig, FaultModel, parse_faults
from repro.core.federated import (FederatedTrainer, WatchdogConfig,
                                  _quantize_rho)
from repro.core.scaling import staleness_corrected_gamma
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

VOCAB = 64


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="faults-tiny", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                      d_ff=64, vocab_size=VOCAB)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def make_trainer(model, base, *, n=4, rank=4, alpha=8.0, scaling="sfedlora",
                 local_steps=1, chunk_rounds=0, seed=0, watchdog=None,
                 participation=1.0, **fed_kw):
    ds = FederatedDataset(VOCAB, n, seq_len=8, batch_per_client=1, seed=seed)
    return FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=rank, alpha=alpha, scaling=scaling),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=local_steps,
                                aggregation="fedsa",
                                participation=participation, **fed_kw),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=seed,
        base_params=base, chunk_rounds=chunk_rounds, watchdog=watchdog)


def assert_state_bitequal(tr_a, tr_b):
    for x, y in zip(jax.tree.leaves((tr_a.lora, tr_a.opt_state)),
                    jax.tree.leaves((tr_b.lora, tr_b.opt_state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ parse_faults

def test_parse_faults_full_spec():
    cfg = parse_faults("dropout=0.1,straggle=geom:0.3,corrupt=0.01,"
                       "mode=noise,noise=5,seed=3")
    assert cfg == FaultConfig(dropout=0.1, straggle=0.3, corrupt=0.01,
                              corrupt_mode="noise", noise_scale=5.0, seed=3)


def test_parse_faults_empty_is_null():
    assert parse_faults("").null
    assert parse_faults("dropout=0.1").null is False


def test_parse_faults_rejects_bad_input():
    with pytest.raises(ValueError, match="key=value"):
        parse_faults("dropout")
    with pytest.raises(ValueError, match="unknown --faults key"):
        parse_faults("jitter=0.5")
    with pytest.raises(ValueError, match="in \\[0, 1\\]"):
        parse_faults("dropout=1.5")
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultConfig(corrupt=0.1, corrupt_mode="bitflip")


# -------------------------------------------------------------- FaultModel

def test_fault_masks_deterministic_and_seed_dependent():
    key = jax.random.key(0)
    fm = FaultModel(FaultConfig(dropout=0.5, straggle=0.5, corrupt=0.5))
    a = fm.sample(key, 64)
    b = fm.sample(key, 64)
    for k in ("drop", "straggle", "corrupt"):
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        assert a[k].shape == (64,) and a[k].dtype == jnp.bool_
    # a different FaultConfig.seed draws an independent schedule
    fm2 = FaultModel(FaultConfig(dropout=0.5, straggle=0.5, corrupt=0.5,
                                 seed=1))
    c = fm2.sample(key, 64)
    assert any(not np.array_equal(np.asarray(a[k]), np.asarray(c[k]))
               for k in a)


def test_fault_masks_zero_rate_is_constant_false():
    fm = FaultModel(FaultConfig(dropout=0.5))
    masks = fm.sample(jax.random.key(0), 256)
    assert not np.any(np.asarray(masks["straggle"]))
    assert not np.any(np.asarray(masks["corrupt"]))
    # and the rates are roughly honored where nonzero
    assert 0.3 < np.asarray(masks["drop"]).mean() < 0.7


def test_corrupt_tree_nan_mode_touches_only_masked_rows():
    tree = {"a": jnp.ones((4, 3, 2)), "b": jnp.ones((4, 2))}
    mask = jnp.asarray([True, False, True, False])
    fm = FaultModel(FaultConfig(corrupt=0.5, corrupt_mode="nan"))
    out = fm.corrupt_tree(jax.random.key(0), tree, mask)
    for leaf in jax.tree.leaves(out):
        leaf = np.asarray(leaf)
        assert not np.isfinite(leaf[0]).any()
        assert not np.isfinite(leaf[2]).any()
        np.testing.assert_array_equal(leaf[1], 1.0)
        np.testing.assert_array_equal(leaf[3], 1.0)
    # nan/inf alternate across leaves so both screens get exercised
    finite_kinds = {str(np.asarray(leaf)[0].flat[0])
                    for leaf in jax.tree.leaves(out)}
    assert finite_kinds == {"nan", "inf"}


def test_corrupt_tree_noise_mode_is_finite_norm_outlier():
    tree = {"a": jnp.ones((4, 8))}
    mask = jnp.asarray([True, False, False, False])
    fm = FaultModel(FaultConfig(corrupt=0.5, corrupt_mode="noise",
                                noise_scale=50.0))
    out = np.asarray(fm.corrupt_tree(jax.random.key(0), tree, mask)["a"])
    assert np.isfinite(out).all()
    assert np.linalg.norm(out[0]) > 10 * np.linalg.norm(out[1])
    np.testing.assert_array_equal(out[1:], 1.0)


def test_corrupt_tree_zero_rate_returns_input():
    tree = {"a": jnp.ones((2, 2))}
    fm = FaultModel(FaultConfig())
    assert fm.corrupt_tree(jax.random.key(0), tree,
                           jnp.ones((2,), bool)) is tree


# ------------------------------------------------- scaling-factor helpers

def test_staleness_corrected_gamma():
    assert staleness_corrected_gamma(8.0, 4, 4) == 8.0
    assert staleness_corrected_gamma(8.0, 1, 4) == pytest.approx(4.0)
    assert staleness_corrected_gamma(8.0, 0, 4) == 0.0
    with pytest.raises(ValueError, match="n_clients"):
        staleness_corrected_gamma(8.0, 1, 0)


def test_quantize_rho():
    assert _quantize_rho(1.0) == 1.0
    assert _quantize_rho(0.996) == 1.0          # near-1 snaps to exact 1.0
    assert _quantize_rho(0.707) == 0.71
    assert _quantize_rho(0.0) == 0.01           # floored: never kills gamma
    assert isinstance(_quantize_rho(jnp.asarray(0.5)), float)


# -------------------------------------------------- engine under faults

def test_dropout_shrinks_n_eff_and_gamma_eff(tiny):
    model, base = tiny
    tr = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                      faults=FaultConfig(dropout=0.5, seed=2))
    tr.run(6)
    n_eff = np.asarray([h["n_eff"] for h in tr.history])
    assert (n_eff < tr.fed_cfg.num_clients).any()
    assert tr.gamma_eff < tr.adapters.gamma
    assert tr.gamma_eff == tr.adapters.gamma * tr._rho_host
    assert all(np.isfinite(h["loss"]) for h in tr.history)


def test_stragglers_deliver_late_with_staleness(tiny):
    """A straggling upload stays in flight and lands in a later round:
    the stale metric counts tau>0 deliveries and every update is still
    eventually delivered or superseded (no client starves forever)."""
    model, base = tiny
    tr = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                      faults=FaultConfig(straggle=0.5, seed=1))
    tr.run(8)
    stale = np.asarray([h["stale"] for h in tr.history])
    delivered = np.asarray([h["delivered"] for h in tr.history])
    assert stale.sum() > 0                      # late arrivals happened
    assert (delivered < tr.fed_cfg.num_clients).any()
    assert delivered.sum() > 0
    assert all(np.isfinite(h["loss"]) for h in tr.history)


def test_nan_corruption_is_screened(tiny):
    """NaN/Inf uploads must be rejected server-side: the run stays finite,
    the rejected metric counts them, and the corrupted client's LOCAL
    state (which the corruption never touched) keeps training."""
    model, base = tiny
    tr = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                      faults=FaultConfig(corrupt=0.4, seed=3))
    tr.run(6)
    assert sum(h["rejected"] for h in tr.history) > 0
    assert all(np.isfinite(h["loss"]) for h in tr.history)
    for leaf in jax.tree.leaves((tr.lora, tr.opt_state)):
        assert np.isfinite(np.asarray(leaf)).all()


def test_noise_corruption_is_screened_as_norm_outlier(tiny):
    """Finite norm bombs are rejected against the candidate MEDIAN (a
    mean-based screen fails here: the bomb inflates the mean by ~norm/N,
    so at small N it never exceeds mult x mean).  The median's breakdown
    point is half the cohort — keep the corruption rate safely below it."""
    model, base = tiny
    tr = make_trainer(model, base, n=8, buffer_size=0, chunk_rounds=2,
                      faults=FaultConfig(corrupt=0.25, corrupt_mode="noise",
                                         noise_scale=1e4, seed=3))
    tr.run(6)
    assert sum(h["rejected"] for h in tr.history) > 0
    for leaf in jax.tree.leaves(tr.lora):
        assert np.abs(np.asarray(leaf)).max() < 1e3


def test_screening_off_lets_nan_poison_state(tiny):
    """Negative control: with screen_updates=False the same corruption
    schedule reaches the aggregate — proving the screen is what saved the
    run above, not the fault model being too gentle."""
    model, base = tiny
    tr = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                      screen_updates=False,
                      faults=FaultConfig(corrupt=0.4, seed=3))
    tr.run(6)
    leaves = [np.asarray(x) for x in jax.tree.leaves(tr.lora)]
    assert any(not np.isfinite(x).all() for x in leaves)


def test_buffer_cap_limits_delivered(tiny):
    model, base = tiny
    cap = 2
    tr = make_trainer(model, base, n=4, buffer_size=cap, chunk_rounds=2,
                      faults=FaultConfig(straggle=0.3, seed=1))
    tr.run(6)
    delivered = np.asarray([h["delivered"] for h in tr.history])
    assert (delivered <= cap).all()
    assert delivered.max() == cap               # the cap actually binds


def test_fault_schedule_chunking_invariant(tiny):
    """Same seed + same chunk length => the chunked run(6) and three
    aligned run(2) calls replay the identical fault schedule AND state.
    (Alignment matters: the staleness-corrected gamma folds statically at
    chunk boundaries, so runs chunked DIFFERENTLY legitimately diverge
    once rho != 1 — the schedule itself, keyed per round, never does.)"""
    model, base = tiny
    faults = FaultConfig(dropout=0.3, straggle=0.3, seed=5)
    one = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                       faults=faults)
    one.run(6)
    many = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                        faults=faults)
    for _ in range(3):
        many.run(2)
    assert_state_bitequal(one, many)
    for k in ("delivered", "stale", "n_eff"):
        np.testing.assert_array_equal([h[k] for h in one.history],
                                      [h[k] for h in many.history])


def test_crash_resume_under_faults_bit_exact(tiny, tmp_path):
    """Kill-and-restore mid-run: the checkpoint carries the PRNG key,
    round index, and async_state (tau + rho), so the resumed run replays
    the remaining fault schedule and staleness accounting bit-exactly
    against the uninterrupted run (chunk boundaries aligned)."""
    model, base = tiny
    path = str(tmp_path / "faulty.npz")
    faults = FaultConfig(dropout=0.3, straggle=0.4, corrupt=0.2, seed=4)
    kw = dict(buffer_size=0, chunk_rounds=3, faults=faults)

    full = make_trainer(model, base, **kw)
    full.run(6)

    half = make_trainer(model, base, **kw)
    half.run(3)
    half.save(path)
    payload = np.load(path)
    assert "async_state::tau" in payload.files
    assert "async_state::rho" in payload.files

    res = make_trainer(model, base, **kw)
    res.restore(path)
    assert res.round_idx == 3
    np.testing.assert_array_equal(np.asarray(res.async_state["tau"]),
                                  np.asarray(half.async_state["tau"]))
    assert res._rho_host == half._rho_host
    res.run(3)
    assert_state_bitequal(full, res)


def test_restore_legacy_checkpoint_resets_async_state(tiny, tmp_path):
    """A checkpoint written by the synchronous engine restores into a
    buffered trainer with fresh async bookkeeping (tau=0, rho=1), not an
    error — old checkpoints stay loadable."""
    model, base = tiny
    path = str(tmp_path / "legacy.npz")
    sync = make_trainer(model, base, chunk_rounds=2)
    sync.run(2)
    sync.save(path)
    buf = make_trainer(model, base, buffer_size=0, chunk_rounds=2)
    buf.restore(path)
    assert np.asarray(buf.async_state["tau"]).sum() == 0
    assert buf._rho_host == 1.0


# --------------------------------------------------- watchdog + recovery

def _report(norms, *, gamma, r=4, n=4, alpha=8.0):
    return stability_report(norms, gamma=gamma, r=r, n_clients=n,
                            alpha=alpha)


def test_recovery_action_classifies_config_vs_drift():
    # config half violated (classic LoRA gamma at large r): retrying the
    # same gamma cannot help — rescale
    bad = _report([1.0, 1.0], gamma=8.0 / 64, r=64, n=8)
    assert bad.verdict == "collapse"
    assert recovery_action(bad) == "rescale"
    # config sound but the measured norms explode: backoff
    drift = _report([1.0, 9.0, 81.0], gamma=8.0)
    assert not drift.ok
    assert recovery_action(drift) == "backoff"


def test_watchdog_rescale_rescues_collapsed_gamma(tiny):
    """The ISSUE 10 acceptance scenario: classic gamma = alpha/r at r=64,
    N=8 (Theorem 4.2 predicts moment scale 1/(rN) — deep collapse) plus
    corrupted uploads.  The watchdog must catch the first chunk verdict,
    roll back to the chunk-start snapshot, adopt the paper's
    gamma = alpha*sqrt(N/r), and complete with a final 'stabilized'
    report rather than raising."""
    model, base = tiny
    tr = make_trainer(model, base, n=8, rank=64, scaling="lora",
                      buffer_size=0, chunk_rounds=4,
                      faults=FaultConfig(corrupt=0.25, seed=1),
                      watchdog=WatchdogConfig(max_retries=2))
    gamma0 = tr.adapters.gamma
    assert gamma0 == pytest.approx(8.0 / 64)
    tr.run(8)
    assert tr.watchdog_events, "watchdog never fired"
    ev = tr.watchdog_events[0]
    assert ev["verdict"] == "collapse" and ev["action"] == "rescale"
    # the adopted factor is the paper's: alpha*sqrt(N/r) = 8*sqrt(8/64)
    assert tr.adapters.gamma == pytest.approx(8.0 * (8 / 64) ** 0.5)
    assert tr.lora_cfg.scaling == "sfedlora"
    assert tr.stability_report().verdict == "stabilized"
    assert all(np.isfinite(h["loss"]) for h in tr.history)


def test_watchdog_bounded_retries_raise(tiny):
    """With gamma rescue disabled, a config-half collapse is unfixable by
    participation backoff — after max_retries the watchdog must raise
    ScalingCollapseError instead of looping, and each retry must have
    backed participation off (floored at one client)."""
    model, base = tiny
    tr = make_trainer(model, base, n=4, rank=64, scaling="lora",
                      buffer_size=0, chunk_rounds=2,
                      watchdog=WatchdogConfig(max_retries=1,
                                              rescale_gamma=False))
    with pytest.raises(ScalingCollapseError, match="collapse"):
        tr.run(4)
    assert len(tr.watchdog_events) == 1
    assert tr.watchdog_events[0]["action"] == "backoff"
    assert tr.fed_cfg.participation == 0.5
    # the raise fires after the final failed retry ran its chunk
    assert tr.round_idx == 2


def test_watchdog_rollback_restores_chunk_start_state(tiny):
    """A failed chunk must leave NO trace: after rollback + recovery the
    retried chunk starts from bit-identical state, history, and round
    index — only the recovery policy (gamma) differs."""
    model, base = tiny
    ref = make_trainer(model, base, n=4, rank=64, scaling="lora",
                       buffer_size=0, chunk_rounds=2)
    wd = make_trainer(model, base, n=4, rank=64, scaling="lora",
                      buffer_size=0, chunk_rounds=2,
                      watchdog=WatchdogConfig(max_retries=2))
    ref.run(2)                                  # un-watched collapse run
    wd.run(2)                                   # watched: rescued
    assert wd.watchdog_events and wd.round_idx == 2
    assert len(wd.history) == 2                 # rolled-back rounds pruned
    # the rescued run trained with the sfedlora gamma, not the original
    assert wd.adapters.gamma != ref.adapters.gamma


def test_watchdog_quiet_on_healthy_run(tiny):
    model, base = tiny
    tr = make_trainer(model, base, buffer_size=0, chunk_rounds=2,
                      watchdog=WatchdogConfig(max_retries=2))
    tr.run(4)
    assert tr.watchdog_events == []
    assert tr.gamma_eff == tr.adapters.gamma


def test_gamma_eff_rides_fault_seed_not_retry(tiny):
    """Backoff recovery reseeds the fault stream (seed+1) so the retry is
    a fresh draw, not a replay of the same failures."""
    f0 = FaultConfig(dropout=0.5, seed=7)
    f1 = dataclasses.replace(f0, seed=f0.seed + 1)
    fm0, fm1 = FaultModel(f0), FaultModel(f1)
    k = jax.random.key(0)
    assert not np.array_equal(np.asarray(fm0.sample(k, 64)["drop"]),
                              np.asarray(fm1.sample(k, 64)["drop"]))

"""Paged KV cache + continuous-batching scheduler.

Four layers of guarantees, strongest first:

  * BlockPool allocator invariants, property-based (hypothesis when
    installed, a seeded op-sequence sweep otherwise): no block aliasing
    across outstanding allocations, the null block 0 is never handed out,
    frees return capacity exactly, double frees raise without corrupting.
  * Paged fill/gather reproduces the ring-buffer layout ELEMENT FOR
    ELEMENT — including sliding-window ring overflow (prompt longer than
    the ring) — whenever block_size divides the ring size.
  * The Pallas paged-attention kernel matches the exact-softmax oracle
    (kernels/ref.py) to fp32 tolerance across window/softcap variants.
  * The scheduled paged engine is token-IDENTICAL to the PR-5 fixed-batch
    engine at a static schedule, on the reference tier and under the
    Pallas interpreter (BGMV adapter kernels engaged), through slot/block
    churn (waves recycling freed slots and blocks), and for per-slot
    recurrent state (rglru blocks reset at admission).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig, ModelConfig
from repro.core.lora import AdapterBank, init_adapter_set
from repro.kernels import dispatch
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.launch import serve
from repro.models import attention
from repro.models.api import build_model

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _cfg(use_pallas=False, num_layers=3, **kw):
    base = dict(name="paged", family="dense", num_layers=num_layers,
                d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                d_ff=64, vocab_size=64, use_pallas=use_pallas)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    yield
    dispatch.force_mode(None)


# ------------------------------------------------- BlockPool allocator invariants

def _check_pool_ops(num_blocks, ops):
    """Replay an (alloc n | free i)* op sequence against a fresh pool,
    asserting the allocator invariants after every op."""
    pool = serve.BlockPool(num_blocks)
    held = []                     # outstanding allocations, each a list
    capacity = num_blocks - 1     # block 0 reserved
    for kind, arg in ops:
        outstanding = sum(len(h) for h in held)
        if kind == "alloc":
            got = pool.alloc(arg)
            if arg > capacity - outstanding:
                assert got is None, "over-allocation must refuse, not split"
            else:
                assert got is not None and len(got) == arg
                assert len(set(got)) == arg
                assert all(0 < b < num_blocks for b in got), \
                    "null block 0 handed out"
                taken = {b for h in held for b in h}
                assert not (set(got) & taken), "block aliased across requests"
                held.append(got)
        elif held:
            blocks = held.pop(arg % len(held))
            before = pool.available
            pool.free(blocks)
            assert pool.available == before + len(blocks)
            if blocks:
                with pytest.raises(ValueError):
                    pool.free(blocks)                 # double free raises...
                assert pool.available == before + len(blocks)  # ...harmlessly
    assert pool.available == capacity - sum(len(h) for h in held)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(num_blocks=st.integers(2, 40),
           ops=st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                                  st.integers(0, 8)), max_size=60))
    def test_block_pool_invariants(num_blocks, ops):
        _check_pool_ops(num_blocks, ops)
else:
    def test_block_pool_invariants():
        rng = random.Random(0)
        for _ in range(300):
            num_blocks = rng.randint(2, 40)
            ops = [(rng.choice(["alloc", "free"]), rng.randint(0, 8))
                   for _ in range(rng.randint(0, 60))]
            _check_pool_ops(num_blocks, ops)


def test_block_pool_rejects_degenerate():
    with pytest.raises(ValueError):
        serve.BlockPool(1)        # no room for the null block + any request


def test_block_pool_double_free_names_blocks():
    """The double-free error must NAME the offending blocks — the message
    is what a scheduler bug report hangs on."""
    pool = serve.BlockPool(8)
    got = pool.alloc(3)
    pool.free(got)
    with pytest.raises(ValueError) as ei:
        pool.free(got)
    msg = str(ei.value)
    assert "double free" in msg
    for b in got:
        assert str(b) in msg
    # a mixed batch reports exactly the not-held blocks
    held = pool.alloc(2)
    with pytest.raises(ValueError) as ei:
        pool.free(held + [got[0]])
    assert str(got[0]) in str(ei.value)
    assert pool.available == 5          # failed free released nothing


def test_block_pool_duplicate_in_one_call_raises():
    pool = serve.BlockPool(8)
    b = pool.alloc(1)[0]
    before = pool.available
    with pytest.raises(ValueError):
        pool.free([b, b])
    assert pool.available == before     # refused atomically
    pool.free([b])                      # the block is still cleanly held


# ------------------------------------------------- ring vs paged layout parity

def _check_ring_paged_layout(seed, batch, size, bs, s):
    """Random prompt fill + sequential decode writes: the paged gather must
    reproduce the ring arrays element for element (bs divides size)."""
    cfg = _cfg()
    mb = size // bs
    key = jax.random.key(seed)
    kk, kv = jax.random.split(key)
    k = jax.random.normal(kk, (batch, s, cfg.num_kv_heads, cfg.head_dim))
    v = jax.random.normal(kv, (batch, s, cfg.num_kv_heads, cfg.head_dim))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (batch, s))

    ring = attention.init_kv_cache(cfg, batch, size, k.dtype)
    ring = attention.fill_kv_cache(ring, k, v, positions)

    paged = attention.init_paged_kv_cache(cfg, 1 + batch * mb, bs, k.dtype)
    table = jnp.arange(1, 1 + batch * mb, dtype=jnp.int32).reshape(batch, mb)
    paged = attention.fill_paged_kv_cache(paged, k, v, positions, table)

    kg, vg, pg = attention.paged_gather(paged, table)
    np.testing.assert_array_equal(np.asarray(ring["k"]), np.asarray(kg))
    np.testing.assert_array_equal(np.asarray(ring["v"]), np.asarray(vg))
    np.testing.assert_array_equal(np.asarray(ring["pos"]), np.asarray(pg))
    assert not np.any(np.asarray(paged["pos_pool"][0]) >= 0), \
        "fill leaked into the null block"


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 3),
           mb=st.integers(1, 4), bs=st.sampled_from([1, 2, 4]),
           extra=st.integers(0, 12))
    def test_ring_vs_paged_fill_layout(seed, batch, mb, bs, extra):
        # extra > 0 overflows the ring (sliding-window prompt longer than
        # the cache) — the survivors must still agree
        _check_ring_paged_layout(seed, batch, mb * bs, bs, mb * bs + extra)
else:
    def test_ring_vs_paged_fill_layout():
        rng = random.Random(1)
        for _ in range(40):
            bs = rng.choice([1, 2, 4])
            mb = rng.randint(1, 4)
            _check_ring_paged_layout(rng.randint(0, 2**31 - 1),
                                     rng.randint(1, 3), mb * bs, bs,
                                     mb * bs + rng.randint(0, 12))


# ------------------------------------------------- Pallas kernel vs exact oracle

@pytest.mark.parametrize("window,softcap", [(None, None), (6, None),
                                            (None, 30.0), (6, 30.0)])
def test_paged_attention_kernel_matches_oracle(window, softcap):
    b, h, kh, hd, bsz, mb = 3, 4, 2, 16, 4, 3
    npool = 1 + b * mb
    key = jax.random.key(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, hd), jnp.float32)
    k_pool = jax.random.normal(kk, (npool, bsz, kh, hd), jnp.float32)
    v_pool = jax.random.normal(kv, (npool, bsz, kh, hd), jnp.float32)
    table = jnp.arange(1, 1 + b * mb, dtype=jnp.int32).reshape(b, mb)
    # staggered fill levels incl. one wrapped request
    pos_pool = jnp.full((npool, bsz), -1, jnp.int32)
    vlen = mb * bsz
    for i, filled in enumerate((vlen // 2, vlen, vlen + 3)):
        pos = jnp.arange(filled, dtype=jnp.int32)
        vslot = pos % vlen
        pos_pool = pos_pool.at[table[i, vslot // bsz], vslot % bsz].set(pos)
    qpos = jnp.asarray([vlen // 2 - 1, vlen - 1, vlen + 2], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, pos_pool, table, qpos,
                          window=window, softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, k_pool, v_pool, pos_pool, table, qpos,
                              window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- scheduled vs fixed identity

def _bank(model, params, ranks=(4, 8)):
    cfg = model.cfg
    sets = [init_adapter_set(params, jax.random.fold_in(jax.random.key(1), i),
                             LoRAConfig(rank=r, alpha=8.0,
                                        targets=cfg.lora_targets),
                             n_clients=len(ranks))
            for i, r in enumerate(ranks)]
    return AdapterBank.from_sets(sets)


def _run_static_identity(cfg, *, bank_ranks=None, B=4, p=8, steps=12,
                         block_size=4, chunk=5, max_len=None):
    """All-at-once arrivals, uniform shapes: scheduled greedy tokens must
    equal the fixed-batch engine's exactly."""
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    bank = _bank(model, params, bank_ranks) if bank_ranks else None
    prompt = np.asarray(jax.random.randint(jax.random.key(2), (B, p), 0,
                                           cfg.vocab_size), np.int32)
    max_len = max_len or p + steps
    ids = np.arange(B, dtype=np.int32) % (bank.size if bank else 1)
    if bank is not None:
        fixed = serve.generate_banked(model, params, bank, jnp.asarray(ids),
                                      jnp.asarray(prompt), steps, max_len)
    else:
        fixed = serve.generate(model, params, jnp.asarray(prompt), steps,
                               max_len)
    fixed = np.asarray(fixed)[:, p:]
    reqs = [serve.Request(rid=i, prompt=prompt[i], steps=steps,
                          adapter_id=int(ids[i])) for i in range(B)]
    done = serve.serve_scheduled(model, params, reqs, bank=bank, max_batch=B,
                                 block_size=block_size, chunk=chunk,
                                 max_len=max_len, wait=False)
    sched = np.stack([np.asarray(r.tokens) for r in done])
    np.testing.assert_array_equal(fixed, sched)
    return model


def test_scheduled_identity_base():
    _run_static_identity(_cfg())


def test_scheduled_identity_banked():
    _run_static_identity(_cfg(), bank_ranks=(4, 8))


def test_scheduled_identity_sliding_window_overflow():
    # max_len 8 < prompt+steps 17: both engines wrap their (virtual) ring;
    # block_size 4 divides 8 so the layouts stay element-identical
    _run_static_identity(_cfg(attn_window=6), p=5, steps=12, max_len=8,
                         block_size=2)


def test_scheduled_identity_recurrent_blocks():
    # per-slot recurrent state (rglru h/conv tail) must come back fresh at
    # admission and merge without disturbing attention pools
    _run_static_identity(_cfg(num_layers=4,
                              block_pattern=("rglru", "attn")),
                         B=2, p=6, steps=8)


def test_scheduled_identity_interpret_tier():
    # the full serving stack under the Pallas interpreter: BGMV adapter
    # kernel bodies run inside both engines; tokens still identical
    dispatch.force_mode("interpret")
    dispatch.reset_stats()
    _run_static_identity(_cfg(use_pallas=True), bank_ranks=(4, 8), B=2,
                         p=5, steps=6, chunk=3)
    assert dispatch.stats["bgmv"] > 0, "BGMV kernel tier never engaged"


def test_scheduled_churn_matches_fixed_waves():
    """Staggered completion: 6 requests through 2 engine slots — three
    waves recycling freed slots AND freed blocks.  Each wave must match
    the fixed engine run on that wave alone (same shapes), proving freed
    blocks are reset before reuse and per-slot merge doesn't leak."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    bank = _bank(model, params)
    N, p, steps, max_len = 6, 6, 10, 16
    prompt = np.asarray(jax.random.randint(jax.random.key(3), (N, p), 0,
                                           cfg.vocab_size), np.int32)
    ids = np.asarray([0, 1, 1, 0, 0, 1], np.int32)
    fixed = np.concatenate([
        np.asarray(serve.generate_banked(
            model, params, bank, jnp.asarray(ids[w:w + 2]),
            jnp.asarray(prompt[w:w + 2]), steps, max_len))
        for w in range(0, N, 2)])[:, p:]
    reqs = [serve.Request(rid=i, prompt=prompt[i], steps=steps,
                          adapter_id=int(ids[i])) for i in range(N)]
    done = serve.serve_scheduled(model, params, reqs, bank=bank, max_batch=2,
                                 block_size=4, chunk=4, max_len=max_len,
                                 wait=False)
    sched = np.stack([np.asarray(r.tokens) for r in done])
    np.testing.assert_array_equal(fixed, sched)


def test_scheduled_mixed_lengths_and_steps_complete():
    """Heterogeneous stream: mixed prompt lengths (FIFO same-length
    admission groups), mixed step counts (mid-chunk finishes truncate),
    more requests than slots.  Everyone completes with exactly their
    requested token count, and the run is deterministic."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    def mk():
        return [serve.Request(
            rid=i,
            prompt=rng_prompts[i],
            steps=int(steps_list[i]),
            adapter_id=0) for i in range(7)]

    plens = [4, 4, 6, 6, 4, 6, 4]
    steps_list = [1, 5, 9, 3, 7, 2, 4]
    rng_prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in plens]
    out = []
    for _ in range(2):
        done = serve.serve_scheduled(model, params, mk(), max_batch=3,
                                     block_size=4, chunk=4, wait=False)
        assert [len(r.tokens) for r in done] == steps_list
        out.append([r.tokens for r in done])
    assert out[0] == out[1]


def test_scheduled_immediate_finish_latency_sane():
    """steps=1 requests finish AT admission (their only token comes from
    the prefill); under wait=True their t_done is taken from t_first, so
    both timestamps must exist, be monotone w.r.t. arrival, and yield
    non-negative latency — the metrics serve_bench aggregates."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(jax.random.randint(jax.random.key(5), (3, 4), 0,
                                           cfg.vocab_size), np.int32)
    reqs = [serve.Request(rid=i, prompt=prompt[i], steps=s, arrival=0.0)
            for i, s in enumerate((1, 1, 4))]
    done = serve.serve_scheduled(model, params, reqs, max_batch=3,
                                 block_size=4, chunk=2, wait=True)
    for r in done:
        assert r.t_first is not None and r.t_done is not None
        assert r.t_done >= r.t_first >= 0.0
        assert len(r.tokens) == r.steps
    for r in done[:2]:                  # immediate finishers: one timestamp
        assert r.t_done == r.t_first


def test_scheduled_block_starvation_waits_not_fails():
    """With exactly one request's worth of blocks, admission serializes:
    every request still completes (the head of the queue waits for blocks
    instead of deadlocking or aliasing)."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(jax.random.randint(jax.random.key(4), (3, 4), 0,
                                           cfg.vocab_size), np.int32)
    reqs = [serve.Request(rid=i, prompt=prompt[i], steps=5)
            for i in range(3)]
    done = serve.serve_scheduled(model, params, reqs, max_batch=1,
                                 block_size=4, chunk=2, max_len=12,
                                 wait=False)
    assert all(len(r.tokens) == 5 for r in done)
    fixed = np.concatenate([
        np.asarray(serve.generate(model, params, jnp.asarray(prompt[i:i+1]),
                                  5, 12))[:, 4:] for i in range(3)])
    np.testing.assert_array_equal(fixed,
                                  np.stack([r.tokens for r in done]))


# ------------------------------------------------- deadline-bounded serving

def test_deadline_evicts_at_chunk_boundary_with_exact_prefix():
    """Graceful degradation: a request with deadline_steps=8 inside a
    steps=32 ask is evicted at a chunk boundary with EXACTLY 8 tokens,
    marked timed_out, counted by the timeout meter — and its tokens are a
    bit-exact prefix of the un-deadlined run (eviction only ever happens
    between chunks, so it cannot perturb decode numerics)."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(jax.random.randint(jax.random.key(5), (1, 6), 0,
                                           cfg.vocab_size), np.int32)
    full = serve.serve_scheduled(
        model, params,
        [serve.Request(rid=0, prompt=prompt[0], steps=32)],
        max_batch=2, block_size=4, chunk=4, max_len=40, wait=False)
    serve.reset_timeout_meter()
    done = serve.serve_scheduled(
        model, params,
        [serve.Request(rid=0, prompt=prompt[0], steps=32,
                       deadline_steps=8)],
        max_batch=2, block_size=4, chunk=4, max_len=40, wait=False)
    (r,) = done
    assert r.timed_out and len(r.tokens) == 8
    assert serve.timeouts == 1
    np.testing.assert_array_equal(np.asarray(r.tokens),
                                  np.asarray(full[0].tokens)[:8])
    # an un-deadlined sibling is untouched
    assert not full[0].timed_out and len(full[0].tokens) == 32


def test_deadline_frees_slot_for_queued_request():
    """The evicted request's slot and blocks go back to the pool: a queued
    third request (max_batch=2) is admitted after the eviction and every
    request completes -- deadlined ones at their cap, the rest in full."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(jax.random.randint(jax.random.key(6), (3, 4), 0,
                                           cfg.vocab_size), np.int32)
    serve.reset_timeout_meter()
    reqs = [serve.Request(rid=0, prompt=prompt[0], steps=24,
                          deadline_steps=4),
            serve.Request(rid=1, prompt=prompt[1], steps=24,
                          deadline_steps=4),
            serve.Request(rid=2, prompt=prompt[2], steps=6)]
    done = serve.serve_scheduled(model, params, reqs, max_batch=2,
                                 block_size=4, chunk=4, max_len=32,
                                 wait=False)
    by_rid = {r.rid: r for r in done}
    assert len(by_rid) == 3
    assert by_rid[0].timed_out and len(by_rid[0].tokens) == 4
    assert by_rid[1].timed_out and len(by_rid[1].tokens) == 4
    assert not by_rid[2].timed_out and len(by_rid[2].tokens) == 6
    assert serve.timeouts == 2


def test_deadline_not_hit_is_a_noop():
    """A deadline looser than steps changes nothing: same tokens as the
    un-deadlined run, no timeout flagged."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(jax.random.randint(jax.random.key(7), (1, 5), 0,
                                           cfg.vocab_size), np.int32)
    serve.reset_timeout_meter()
    runs = [serve.serve_scheduled(
        model, params,
        [serve.Request(rid=0, prompt=prompt[0], steps=6, deadline_steps=d)],
        max_batch=1, block_size=4, chunk=3, max_len=16, wait=False)
        for d in (None, 32)]
    assert serve.timeouts == 0
    for run in runs:
        assert not run[0].timed_out and len(run[0].tokens) == 6
    np.testing.assert_array_equal(runs[0][0].tokens, runs[1][0].tokens)


def test_make_requests_deadline_default_and_trace_override(tmp_path):
    trace = tmp_path / "trace.json"
    trace.write_text('[{"arrival": 0.0, "steps": 8},'
                     ' {"arrival": 0.0, "steps": 8, "deadline": 2}]')
    reqs = serve.make_requests(str(trace), prompt_len=4, steps=8, tenants=1,
                               vocab=64, deadline_steps=5)
    assert reqs[0].deadline_steps == 5          # module default applies
    assert reqs[1].deadline_steps == 2          # trace record overrides
    trace.write_text('[{"arrival": 0.0, "steps": 8, "deadline": 0}]')
    with pytest.raises(ValueError, match="deadline_steps"):
        serve.make_requests(str(trace), prompt_len=4, steps=8, tenants=1,
                            vocab=64)

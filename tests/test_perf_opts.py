"""The beyond-paper performance switches (sharding/opts.py) must be
numerics-preserving: same loss and finite grads as the baseline path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.api import build_model
from repro.sharding import opts


@pytest.fixture(autouse=True)
def _reset_opts():
    opts.reset()
    yield
    opts.reset()


@pytest.fixture(scope="module")
def dense():
    cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128)
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 48), 0, 128)
    return m, p, {"tokens": toks}


@pytest.mark.parametrize("opt", ["expand_kv", "seq_parallel_attn",
                                 "chunked_ce", "remat_dots"])
def test_opt_preserves_loss_and_grads(dense, opt):
    m, p, batch = dense
    prev = A.BLOCKWISE_THRESHOLD
    A.BLOCKWISE_THRESHOLD = 16      # exercise the blockwise paths
    try:
        base, _ = m.loss(p, batch)
        opts.set_opts([opt])
        l, _ = m.loss(p, batch)
        g = jax.grad(lambda pp: m.loss(pp, batch)[0])(p)
    finally:
        A.BLOCKWISE_THRESHOLD = prev
    assert abs(float(l - base)) < 1e-4
    assert all(not bool(jnp.isnan(x).any()) for x in jax.tree.leaves(g))


def test_moe_grouped_matches_flat():
    cfg = ModelConfig(
        name="moe", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_ff_expert=32, d_ff_shared=64, capacity_factor=4.0))
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 128)
    l1, _ = m.forward(p, {"tokens": toks})
    opts.set_opts(["moe_grouped"])
    l2, _ = m.forward(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_unknown_opt_raises():
    with pytest.raises(ValueError):
        opts.set_opts(["nope"])

"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=512, <=4 experts) runs one forward + one federated LoRA
train step on CPU; asserts output shapes and no NaNs.  Full configs are
exercised compile-only by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config, supports_shape
from repro.configs.base import (FederatedConfig, LoRAConfig, OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import PATCH_EMBED_DIM, build_model

SEQ = 32
BATCH = 2


def reduced_batch(cfg, batch=BATCH, seq=SEQ, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.family == "vlm":
        out["tokens"] = out["tokens"][:, :seq - cfg.num_patches]
        out["patches"] = jax.random.normal(
            ks[1], (batch, cfg.num_patches, PATCH_EMBED_DIM), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_no_nan(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = reduced_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (BATCH, SEQ, model.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_federated_train_step(arch):
    """One full federated round (2 clients x 2 local steps) with SFed-LoRA
    scaling; loss finite, grads flow, A synchronized across clients."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    n = 2
    ds = FederatedDataset(cfg.vocab_size, n, seq_len=SEQ,
                          batch_per_client=BATCH)
    tr = FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=4, scaling="sfedlora",
                            targets=cfg.lora_targets),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=2,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=1e-2))
    if cfg.family in ("vlm", "audio"):
        # federated trainer's synthetic data is tokens-only; drive the round
        # step directly with modality stubs
        batch = reduced_batch(cfg)
        batches = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n, 2) + x.shape), batch)
        aset, opt, m = tr.round_step(tr.base, tr.adapters, tr.opt_state,
                                     batches, jnp.asarray(0))
        lora = aset.lora
    else:
        m = tr.run_round()
        lora = tr.lora
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # FedSA invariant: A equal across clients post-round, B client-specific
    def leaves_named(tree, name):
        out = []
        def walk(node):
            if isinstance(node, dict):
                for k, v in node.items():
                    if k == name and not isinstance(v, dict):
                        out.append(v)
                    else:
                        walk(v)
        walk(tree)
        return out
    for a in leaves_named(lora, "a"):
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(a[1]),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("arch", [a for a in sorted(ASSIGNED)
                                  if supports_shape(a, "decode_32k")])
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(BATCH, SEQ)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok,
                                       jnp.zeros((BATCH,), jnp.int32))
    assert logits.shape == (BATCH, 1, model.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)

"""BGMV kernel tier: fused multi-adapter parity (interpret vs the einsum
reference) across mixed ranks / rank masks, dispatch routing for banked
{"a","b","ids"} nodes, and K=1 vs single-adapter equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, init_adapter_set
from repro.kernels import dispatch
from repro.kernels.bgmv import bgmv_gemv, bgmv_matmul, bgmv_reference


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    dispatch.reset_stats()
    yield
    dispatch.force_mode(None)


def _bank_operands(B, s, k, n, K, r, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (B, s, k))
    w = jax.random.normal(ks[1], (k, n)) * k ** -0.5
    a = jax.random.normal(ks[2], (K, r, k)) * 0.05
    b = jax.random.normal(ks[3], (K, n, r)) * 0.05
    ids = jax.random.randint(ks[4], (B,), 0, K, jnp.int32)
    return x, w, a, b, ids


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("B,s,k,n,K,r", [
    (4, 8, 64, 64, 4, 8),          # block-divisible
    (5, 3, 70, 50, 3, 9),          # nothing divides: padding in every dim
    (8, 1, 128, 96, 8, 16),        # decode shape through the matmul form
    (2, 6, 32, 256, 5, 4),         # n spans two blocks
])
def test_bgmv_matmul_parity(B, s, k, n, K, r):
    x, w, a, b, ids = _bank_operands(B, s, k, n, K, r, seed=B + r)
    got = bgmv_matmul(x, w, a, b, ids, interpret=True)
    want = bgmv_reference(x, w, a, b, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,k,n,K,r", [
    (4, 64, 64, 4, 8), (7, 70, 50, 3, 5), (8, 128, 300, 8, 16)])
def test_bgmv_gemv_parity(B, k, n, K, r):
    x, w, a, b, ids = _bank_operands(B, 1, k, n, K, r, seed=B)
    got = bgmv_gemv(x[:, 0], w, a, b, ids, interpret=True)
    want = bgmv_reference(x, w, a, b, ids)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bgmv_traced_ids_under_jit():
    """ids are traced (one executable per tenant-mix is the whole point):
    the scalar-prefetch index_maps must work on runtime values."""
    x, w, a, b, ids = _bank_operands(4, 2, 64, 64, 4, 8, seed=3)
    f = jax.jit(lambda i: bgmv_matmul(x, w, a, b, i, interpret=True))
    for perm in (ids, ids[::-1], jnp.zeros_like(ids)):
        np.testing.assert_allclose(
            np.asarray(f(perm)), np.asarray(bgmv_reference(x, w, a, b, perm)),
            rtol=2e-5, atol=2e-5)


def test_bgmv_mixed_rank_zero_padding_exact():
    """A mixed-rank bank stores zero-padded adapters; the kernel must treat
    the padding as exactly free — each row matches the UNPADDED single-
    adapter reference for its tenant."""
    B, s, k, n, K, r_max = 6, 4, 64, 64, 3, 16
    ranks = (4, 16, 7)
    ks = jax.random.split(jax.random.key(9), 2 + K * 2)
    x = jax.random.normal(ks[0], (B, s, k))
    w = jax.random.normal(ks[1], (k, n)) * k ** -0.5
    a_list, b_list = [], []
    for i, ri in enumerate(ranks):
        ai = jax.random.normal(ks[2 + 2 * i], (ri, k)) * 0.05
        bi = jax.random.normal(ks[3 + 2 * i], (n, ri)) * 0.05
        a_list.append(jnp.pad(ai, ((0, r_max - ri), (0, 0))))
        b_list.append(jnp.pad(bi, ((0, 0), (0, r_max - ri))))
    a, b = jnp.stack(a_list), jnp.stack(b_list)
    ids = jnp.asarray([0, 1, 2, 2, 0, 1], jnp.int32)
    got = bgmv_matmul(x, w, a, b, ids, interpret=True)
    for row, tid in enumerate(ids):
        ri = ranks[int(tid)]
        want = (x[row] @ w + (x[row] @ a[tid, :ri].T) @ b[tid, :, :ri].T)
        np.testing.assert_allclose(np.asarray(got[row]), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"row {row} tenant {int(tid)}")


# ---------------------------------------------------------- dispatch routing

def test_dispatch_banked_node_routes_to_bgmv():
    """A lazy bank node ({"a","b","ids"}) takes the BGMV kernel on the
    interpret tier and the einsum expression on the reference tier — same
    numbers either way."""
    x, w, a, b, ids = _bank_operands(4, 3, 32, 48, 4, 8, seed=5)
    node = {"a": a, "b": b, "ids": ids}
    want = dispatch.lora_linear(x, w, node, 1.0)        # reference tier
    assert dispatch.stats["bgmv"] == 0
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        got = dispatch.lora_linear(x, w, node, 1.0)
    assert dispatch.stats["bgmv"] == 1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_materialized_batched_routes_to_bgmv():
    """Pre-gathered (B, r, k) leaves (AdapterBank.gather) also take the
    kernel on fused tiers — ids default to the identity map."""
    x, w, a, b, ids = _bank_operands(4, 1, 32, 48, 4, 8, seed=6)
    ag, bg = jnp.take(a, ids, axis=0), jnp.take(b, ids, axis=0)
    node = {"a": ag, "b": bg}
    want = dispatch.lora_linear(x, w, node, 1.0)
    with dispatch.scope(True):
        dispatch.force_mode("interpret")
        got = dispatch.lora_linear(x, w, node, 1.0)
    assert dispatch.stats["bgmv"] == 1                  # gemv form (s == 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_banked_requires_matching_rows():
    x, w, a, b, ids = _bank_operands(4, 2, 32, 32, 4, 4)
    with pytest.raises(ValueError, match="batched adapters"):
        dispatch.lora_linear(x, w, {"a": a, "b": b, "ids": ids[:2]}, 1.0)


# ------------------------------------------------- K=1 vs single-adapter

@pytest.mark.parametrize("tier", ["reference", "interpret"])
def test_bank_k1_equals_single_adapter(tier):
    """A K=1 bank served to every row is the single-adapter path: same
    projection, only the adapter plumbing differs."""
    B, s, k, n, r = 4, 3, 64, 64, 8
    ks = jax.random.split(jax.random.key(11), 4)
    x = jax.random.normal(ks[0], (B, s, k))
    w = jax.random.normal(ks[1], (k, n)) * k ** -0.5
    a1 = jax.random.normal(ks[2], (r, k)) * 0.05
    b1 = jax.random.normal(ks[3], (n, r)) * 0.05
    node = {"a": a1[None], "b": b1[None],
            "ids": jnp.zeros((B,), jnp.int32)}
    with dispatch.scope(tier == "interpret"):
        if tier == "interpret":
            dispatch.force_mode("interpret")
        got = dispatch.lora_linear(x, w, node, 1.0)
        single = dispatch.lora_linear(x, w, {"a": a1, "b": b1}, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(single),
                               rtol=2e-5, atol=2e-5)


def test_model_bank_gather_vs_requests_bit_identical():
    """Through the full model stack, the lazy requests() view decodes
    bit-identically to the materialized gather() path (the reference
    einsums see the same operands in the same contraction order)."""
    from repro.configs.base import ModelConfig
    from repro.models.api import build_model
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    def nonzero(aset, seed):
        return dataclasses.replace(aset, lora=jax.tree.map(
            lambda t: t + 0.03 * jax.random.normal(jax.random.key(seed),
                                                   t.shape), aset.lora))
    sets = [nonzero(init_adapter_set(params, jax.random.key(10 + i),
                                     LoRAConfig(rank=ri)), 20 + i)
            for i, ri in enumerate((2, 8, 4))]
    bank = AdapterBank.from_sets(sets)
    ids = jnp.asarray([2, 0], jnp.int32)
    toks = jax.random.randint(jax.random.key(3), (2, 4), 0, 64)
    step = jax.jit(model.decode_step)
    lg_g, _ = step(params, model.init_cache(2, 8), toks[:, :1],
                   jnp.zeros((2,), jnp.int32), bank.gather(ids))
    lg_r, _ = step(params, model.init_cache(2, 8), toks[:, :1],
                   jnp.zeros((2,), jnp.int32), bank.requests(ids))
    np.testing.assert_array_equal(np.asarray(lg_g), np.asarray(lg_r))
    pg, _ = model.prefill(params, model.init_cache(2, 8), toks,
                          bank.gather(ids))
    pr, _ = model.prefill(params, model.init_cache(2, 8), toks,
                          bank.requests(ids))
    np.testing.assert_array_equal(np.asarray(pg), np.asarray(pr))

"""repro.analysis: lint rules R1-R7, pragma policy, runtime sanitizers
(RecompileGuard / transfer guard), and the Theorem 4.2 collapse sentinel.

Every lint rule gets a bad fixture (the historical bug class it encodes,
reduced to a few lines) and a good fixture (the idiom that replaced it) —
the rule must flag the former and stay silent on the latter.  The
sanitizer tests SEED the failure (a shape-churning engine, a numpy operand
into a warmed jit) and assert the guard converts it into a loud error.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hostcheck import HostOnlyError, check_adapter_ids, host_only
from repro.analysis.lint import LintConfig, lint_source, report
from repro.analysis.sanitizers import (RecompileError, RecompileGuard,
                                       TransferGuardError, guard_transfers,
                                       no_implicit_transfers)
from repro.analysis.stability_check import (ScalingCollapseError,
                                            assert_stabilized,
                                            predicted_scale, scaling_flatness,
                                            stability_report)
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.core.lora import AdapterBank, LiveAdapterBank, init_adapter_set
from repro.data.synthetic import FederatedDataset
from repro.launch import serve
from repro.models.api import build_model


# --------------------------------------------------------------- lint helpers

def _findings(src, **cfg):
    config = LintConfig(**cfg) if cfg else None
    return lint_source("<fixture>", textwrap.dedent(src), config)


def _active_rules(src, **cfg):
    return sorted({f.rule for f in _findings(src, **cfg) if not f.suppressed})


# ------------------------------------------------------- R1: host nondeterminism

def test_r1_flags_host_time_in_jitted_body():
    assert "R1" in _active_rules("""
        import time
        import jax

        @jax.jit
        def step(x):
            return x * time.time()
        """)


def test_r1_flags_np_random_in_scan_body():
    # indirectly traced: the def is passed to lax.scan, not decorated
    assert "R1" in _active_rules("""
        import jax
        import numpy as np
        from jax import lax

        def body(c, x):
            return c + np.random.randn(), x

        def run(xs):
            return lax.scan(body, 0.0, xs)
        """)


def test_r1_allows_host_time_outside_traces():
    assert _active_rules("""
        import time

        def bench(fn):
            t0 = time.perf_counter()
            fn()
            return time.perf_counter() - t0
        """) == []


# ------------------------------------------------------------- R2: inline jit

def test_r2_flags_jit_in_loop_body():
    assert "R2" in _active_rules("""
        import jax

        def serve_all(xs):
            out = []
            for x in xs:
                out.append(jax.jit(lambda y: y + 1)(x))
            return out
        """)


def test_r2_flags_jit_of_bound_method():
    # model.decode_step is a fresh bound-method object per access: jitting
    # it inline builds a new executable cache on every call (the PR-5 bug
    # serve._jit_decode_step exists to prevent)
    assert "R2" in _active_rules("""
        import jax

        def decode(model, tok):
            return jax.jit(model.decode_step)(tok)
        """)


def test_r2_allows_builder_and_module_level_jit():
    assert _active_rules("""
        import jax

        step = jax.jit(lambda y: y + 1)

        def make_step(model):
            return jax.jit(lambda p, t: model.apply(p, t))
        """) == []


# ----------------------------------------------------------- R3: pytree aux

def test_r3_flags_unhashable_aux():
    assert "R3" in _active_rules("""
        import jax

        class Box:
            def tree_flatten(self):
                return (self.x,), [self.meta]
        """)


def test_r3_allows_tuple_aux():
    assert _active_rules("""
        import jax

        class Box:
            def tree_flatten(self):
                return (self.x,), (self.meta,)
        """) == []


# -------------------------------------------------- R4: unguarded host coercion

def test_r4_flags_bare_np_coercion_of_param():
    assert "R4" in _active_rules("""
        import numpy as np
        import jax

        def log_stats(x):
            return float(np.asarray(x).mean())
        """)


def test_r4_allows_host_only_guarded_def():
    assert _active_rules("""
        import numpy as np
        import jax
        from repro.analysis.hostcheck import host_only

        @host_only
        def log_stats(x):
            return float(np.asarray(x).mean())
        """) == []


# -------------------------------------------------- R5: unvalidated id gather

def test_r5_flags_bare_adapter_id_gather():
    assert "R5" in _active_rules("""
        import jax.numpy as jnp

        def gather(bank, ids):
            return bank[ids]
        """)


def test_r5_allows_checked_gather():
    assert _active_rules("""
        from repro.analysis.hostcheck import check_adapter_ids

        def gather(bank, ids):
            check_adapter_ids(ids, bank.shape[0])
            return bank[ids]
        """) == []


# ----------------------------------------------------- R6: Pallas discipline

_PALLAS_HEADER = """
    import jax
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]
"""


def test_r6_flags_vmem_budget_blowout():
    # (4096, 4096) fp32 blocks double-buffered: ~256 MiB >> 16 MiB budget
    assert "R6" in _active_rules(_PALLAS_HEADER + """
        def run(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                in_specs=[pl.BlockSpec((4096, 4096), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((4096, 4096), lambda i: (0, 0)),
            )(x)
        """)


def test_r6_flags_impure_index_map():
    assert "R6" in _active_rules(_PALLAS_HEADER + """
        def pick(i):
            return i

        def run(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                in_specs=[pl.BlockSpec((128, 128), lambda i: (pick(i), 0))],
                out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
            )(x)
        """)


def test_r6_allows_disciplined_call():
    assert _active_rules(_PALLAS_HEADER + """
        BM = 128

        def run(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                in_specs=[pl.BlockSpec((BM, BM), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((BM, BM), lambda i: (i, 0)),
            )(x)
        """) == []


def test_r6_silent_without_pallas_import():
    # the budget heuristic must not fire on modules that never touch Pallas
    assert _active_rules("""
        def run(x):
            return x.reshape(4096, 4096)
        """) == []


# ----------------------------------------------------- R7: shadowed imports

def test_r7_flags_local_shadow_of_module_level_import():
    assert "R7" in _active_rules("""
        import numpy as np

        def f(x):
            import numpy as np
            return np.sum(x)
        """)


def test_r7_allows_lazy_import_without_module_binding():
    # jax-free modules lazily importing jax inside one function is the
    # repo's deliberate idiom — nothing is shadowed
    assert _active_rules("""
        def f(x):
            import numpy as np
            return np.sum(x)
        """) == []


# ------------------------------------------------------------- pragma policy

_BAD_GATHER = """
    def gather(bank, ids):
        return bank[ids]{pragma}
"""


def test_pragma_with_justification_suppresses():
    src = _BAD_GATHER.format(
        pragma="  # lint: disable=R5 -- ids validated at the host boundary")
    findings = _findings(src)
    assert [f.rule for f in findings] == ["R5"]
    assert findings[0].suppressed
    assert "host boundary" in findings[0].justification
    text, status = report(findings)
    assert status == 0 and "suppressed" in text


def test_pragma_without_justification_is_itself_a_finding():
    findings = _findings(_BAD_GATHER.format(pragma="  # lint: disable=R5"))
    rules = sorted(f.rule for f in findings)
    assert rules == ["PRAGMA", "R5"]          # unexplained pragma: R5 stays live
    assert not any(f.suppressed for f in findings)
    _, status = report(findings)
    assert status == 1


def test_pragma_unknown_rule_is_a_finding():
    findings = _findings(_BAD_GATHER.format(
        pragma="  # lint: disable=R99 -- because"))
    assert "PRAGMA" in {f.rule for f in findings}


def test_report_status_reflects_active_findings():
    _, bad = report(_findings("import time\nimport jax\n\n@jax.jit\n"
                              "def f(x):\n    return x * time.time()\n"))
    _, good = report(_findings("def f(x):\n    return x\n"))
    assert (bad, good) == (1, 0)


def test_lint_runs_clean_on_the_repo_source():
    # the tentpole acceptance bar: src/ lints clean (pragmas justified)
    import os

    from repro.analysis.lint import lint_paths
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    active = [f for f in lint_paths([root]) if not f.suppressed]
    assert active == [], "\n".join(f.render() for f in active)


# ---------------------------------------------------------- RecompileGuard

def test_recompile_guard_watch_mode_detects_cold_shape():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones((4,)))                       # warm one shape
    guard = RecompileGuard()
    guard.watch("double", f)
    f(jnp.ones((4,)))                       # served shape: fine
    guard.check()
    f(jnp.ones((8,)))                       # cold shape inside guarded region
    with pytest.raises(RecompileError, match="double"):
        guard.check()


def test_recompile_guard_wrap_catches_seeded_recompile():
    """A churn engine: stable outer signature, but an inner static arg
    changes every call so the jit cache grows on a previously-served
    signature — exactly the class of bug wrap mode exists to name."""

    class ChurnEngine:
        def __init__(self):
            self.calls = 0
            self.fn = jax.jit(lambda x, c: x + c, static_argnums=1)

        def __call__(self, x):
            self.calls += 1
            return self.fn(x, self.calls)

    engine = ChurnEngine()
    guard = RecompileGuard()
    step = guard.wrap("churn", engine, cache_probe=engine.fn)
    x = jnp.ones((4,))
    step(x)                                  # first compile: allowed
    with pytest.raises(RecompileError, match="previously-served"):
        step(x)                              # same outer sig, cache grew


def test_recompile_guard_wrap_catches_treedef_churn():
    f = jax.jit(lambda d: sum(jax.tree.leaves(d)))
    guard = RecompileGuard(max_treedef_variants=2)
    step = guard.wrap("aux_churn", f, cache_probe=f)
    x = jnp.ones((4,))
    with pytest.raises(RecompileError, match="distinct treedefs"):
        for i in range(6):                   # per-call dict key = aux churn
            step({f"k{i}": x})


def test_recompile_guard_context_manager_and_stable_engine():
    f = jax.jit(lambda x: x + 1)
    for n in (4, 8):
        f(jnp.ones((n,)))                    # warm every shape up front
    guard = RecompileGuard()
    guard.watch("inc", f)
    with guard:
        for n in (4, 8, 4, 8):
            f(jnp.ones((n,)))                # replays only: exits clean
    assert guard.events == []


# ------------------------------------------------------------ transfer guard

def test_transfer_guard_passes_device_resident_calls():
    f = jax.jit(lambda x: x * 2)
    dev = jnp.arange(8, dtype=jnp.float32)
    f(dev)                                   # warm
    with no_implicit_transfers():
        out = f(dev)
    assert float(out[3]) == 6.0


def test_transfer_guard_catches_seeded_numpy_operand():
    f = jax.jit(lambda x: x * 2)
    f(jnp.arange(8, dtype=jnp.float32))      # warm
    host = np.arange(8, dtype=np.float32)    # un-staged operand
    with pytest.raises(TransferGuardError, match="host boundary"):
        with no_implicit_transfers():
            f(host)


def test_guard_transfers_wrapper():
    f = guard_transfers(jax.jit(lambda x: x + 1))
    assert f.__transfer_guarded__
    dev = jnp.ones((4,))
    f(dev)                                   # warm (staging is the 1st call)
    f(dev)
    with pytest.raises(TransferGuardError):
        f(np.ones((4,), np.float32))


# ----------------------------------------------------------- hostcheck units

def test_host_only_rejects_tracers():
    @host_only
    def to_host(x):
        return np.asarray(x)

    assert to_host(jnp.ones((2,))).shape == (2,)
    with pytest.raises(HostOnlyError, match="to_host"):
        jax.jit(lambda x: to_host(x))(jnp.ones((2,)))


def test_check_adapter_ids_rejects_out_of_range():
    assert check_adapter_ids(np.asarray([0, 1]), 2) is not None
    with pytest.raises(ValueError, match="out of range"):
        check_adapter_ids(np.asarray([0, 2]), 2)
    with pytest.raises(ValueError, match="out of range"):
        check_adapter_ids(np.asarray([-1]), 2)

    def traced(ids):
        check_adapter_ids(ids, 2)            # tracer passthrough: no error
        return ids

    jax.jit(traced)(jnp.asarray([5]))


# -------------------------------------------------- Theorem 4.2 sentinel

def test_sentinel_flags_lora_scaling_collapse_at_high_rank():
    """r=64, N=8: classic LoRA gamma=alpha/r predicts a moment scale of
    (1/r)^2 * r/N = 1/(rN) of alpha^2 — collapse; SFed-LoRA's
    alpha*sqrt(N/r) lands exactly at 1.0."""
    r, n, alpha = 64, 8, 8.0
    flat = [1.0, 1.01, 0.99, 1.0]

    sfed = stability_report(flat, gamma=alpha * np.sqrt(n / r), r=r,
                            n_clients=n, alpha=alpha)
    assert sfed.ok and sfed.verdict == "stabilized"
    assert sfed.predicted == pytest.approx(1.0)

    lora = stability_report(flat, gamma=alpha / r, r=r, n_clients=n,
                            alpha=alpha)
    assert not lora.ok and lora.verdict == "collapse"
    assert lora.predicted == pytest.approx(1.0 / (r * n))
    assert "gamma=alpha*sqrt(N/r)" in str(lora)

    with pytest.raises(ScalingCollapseError, match="collapse"):
        assert_stabilized(flat, gamma=alpha / r, r=r, n_clients=n,
                          alpha=alpha)


def test_sentinel_measured_trend_overrides_good_config():
    r, n, alpha = 16, 4, 8.0
    gamma = alpha * np.sqrt(n / r)
    exploding = [1.0, 4.0, 16.0, 64.0]
    rep = stability_report(exploding, gamma=gamma, r=r, n_clients=n,
                           alpha=alpha)
    assert rep.verdict == "explosion" and not rep.ok


def test_sentinel_reference_ratio_detects_drift():
    r, n, alpha = 16, 4, 8.0
    gamma = alpha * np.sqrt(n / r)
    base = [1.0, 1.0, 1.0]
    # a run whose measured level is 100x the reference while the theorem
    # predicts parity (same gamma/r/N): the aggregation path drifted
    rep = stability_report([100.0, 100.0, 100.0], gamma=gamma, r=r,
                           n_clients=n, alpha=alpha,
                           reference=(base, gamma, r, n))
    assert rep.verdict == "drift" and not rep.ok


def test_scaling_flatness():
    flat, ratio = scaling_flatness({(4, 8): 1.0, (8, 16): 1.2, (16, 64): 0.9})
    assert flat and ratio < 2.0
    flat, _ = scaling_flatness([1.0, 100.0])
    assert not flat


def test_predicted_scale_sfed_invariance():
    for n in (2, 8, 32):
        for r in (4, 16, 64):
            gamma = 8.0 * np.sqrt(n / r)
            assert predicted_scale(gamma, r, n, 8.0) == pytest.approx(1.0)


# -------------------------------------- benchmark trajectory hardening

def test_trajectory_warns_instead_of_silently_skipping(tmp_path, monkeypatch,
                                                       capsys):
    """A historical revision whose BENCH_*.json is unreadable (renamed) or
    malformed must surface as a ``__warning__`` row, not vanish — and the
    readable revisions still print."""
    from benchmarks import run as bench_run

    (tmp_path / "BENCH_t.json").write_text('{"s": {"tok_s": 2.0}}')
    blobs = {
        "aaa:BENCH_t.json": None,                      # git show fails
        "bbb:BENCH_t.json": "{not json",               # malformed snapshot
        "ccc:BENCH_t.json": '{"s": {"tok_s": 2.0}}',   # == worktree: dedup
    }

    def fake_git(*args):
        if args[0] == "log":
            return "aaa\nbbb\nccc\n"
        return blobs[args[1]]

    monkeypatch.setattr(bench_run, "ROOT", str(tmp_path))
    monkeypatch.setattr(bench_run, "_git", fake_git)
    bench_run.trajectory()
    rows = capsys.readouterr().out.strip().splitlines()
    assert "trajectory,BENCH_t.json,aaa,__warning__,unreadable: " \
           "git show failed (renamed or missing at this revision)" in rows
    assert any(r.startswith("trajectory,BENCH_t.json,bbb,__warning__,"
                            "malformed JSON") for r in rows)
    assert "trajectory,BENCH_t.json,ccc,s.tok_s,2" in rows
    assert not any(",worktree," in r for r in rows)    # deduped vs ccc


# ------------------------------------------- sanitizers on the real engines

def _cfg(**kw):
    base = dict(name="ana", family="dense", num_layers=2, d_model=32,
                num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                vocab_size=64)
    base.update(kw)
    return ModelConfig(**base)


def _mk_set(params, cfg, rank, seed):
    return init_adapter_set(params, jax.random.key(seed),
                            LoRAConfig(rank=rank, alpha=8.0,
                                       targets=cfg.lora_targets))


def test_serve_scheduled_guarded_zero_recompile_across_publish():
    """The acceptance bar: a RecompileGuard wrapped around the paged
    engines stays silent across a full serve with mid-serve publishes
    (wrap mode on run 1, watch mode proving zero growth on run 2)."""
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sets = [_mk_set(params, cfg, 4, seed=30 + t) for t in range(3)]
    pub = _mk_set(params, cfg, 4, seed=77)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(6)]

    def run(guard):
        live = LiveAdapterBank.from_sets(sets, hot_slots=2)

        def on_boundary(i):
            if i == 2:
                live.publish(0, pub)         # resident hot swap mid-serve
                live.publish(2, pub)         # overflow host write

        reqs = [serve.Request(rid=i, prompt=prompts[i], steps=6,
                              adapter_id=i % 3) for i in range(6)]
        return serve.serve_scheduled(model, params, reqs, bank=live,
                                     max_batch=2, chunk=3, wait=False,
                                     on_boundary=on_boundary, guard=guard)

    g1 = RecompileGuard()
    run(g1)                                  # wrap mode: compiles are fresh sigs
    watch = RecompileGuard()
    watch.watch_model(model)                 # baselines after full warmup
    run(RecompileGuard())
    watch.check()                            # publish schedule: zero growth


def test_serve_scheduled_transfer_guarded():
    cfg = _cfg()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(3)]

    def mk():
        return [serve.Request(rid=i, prompt=prompts[i], steps=4)
                for i in range(3)]

    plain = serve.serve_scheduled(model, params, mk(), max_batch=2,
                                  chunk=2, wait=False)       # warm
    guarded = serve.serve_scheduled(model, params, mk(), max_batch=2,
                                    chunk=2, wait=False, transfer_guard=True)
    assert [r.tokens for r in plain] == [r.tokens for r in guarded]


def _tiny_trainer(track=False):
    cfg = _cfg()
    model = build_model(cfg)
    ds = FederatedDataset(64, 3, seq_len=16, batch_per_client=2, seed=0)
    return FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=4, alpha=8.0),
        fed_cfg=FederatedConfig(num_clients=3, local_steps=1,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=0,
        data_mode="device", chunk_rounds=2, track_stability=track)


def test_run_chunk_transfer_guarded_after_warmup():
    """The training engine holds all-device state: after one warm chunk, a
    guarded chunk runs clean, and a seeded numpy pytree leaf trips."""
    tr = _tiny_trainer()
    r0 = jnp.asarray(0, jnp.int32)
    aset, opt, key, _ = tr._run_chunk(tr.base, tr.adapters, tr.opt_state,
                                      tr._key, r0, num_rounds=2)
    run = guard_transfers(tr._run_chunk)
    aset, opt, key, ms = run(tr.base, aset, opt, key, r0 + 2, num_rounds=2)
    assert np.isfinite(np.asarray(ms["loss"])).all()
    opt_np = jax.tree.map(np.asarray, opt)   # un-staged state: must trip
    with pytest.raises(TransferGuardError):
        run(tr.base, aset, opt_np, key, r0 + 4, num_rounds=2)


def test_trainer_stability_report_end_to_end():
    tr = _tiny_trainer(track=True)
    with pytest.raises(ValueError, match="track_stability"):
        tr.stability_report()                # no history yet
    tr.run(4)
    assert all("update_norm" in h for h in tr.history)
    rep = tr.stability_report()
    assert rep.ok and rep.verdict == "stabilized"
    assert len(rep.norms) == 4


def test_track_stability_preserves_metric_values():
    """Opt-in update_norm must not perturb training itself: losses are
    bit-identical with and without the extra metric."""
    a, b = _tiny_trainer(track=False), _tiny_trainer(track=True)
    a.run(2)
    b.run(2)
    np.testing.assert_array_equal([h["loss"] for h in a.history],
                                  [h["loss"] for h in b.history])

"""Production-FL features: partial client participation and LR schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.aggregation import aggregate_clients
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model
from repro.optim.schedules import make_schedule, warmup_cosine


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def test_partial_participation_trains_subset(tiny):
    cfg, model, base = tiny
    ds = FederatedDataset(64, 4, seq_len=32, batch_per_client=2)
    tr = FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=4),
        fed_cfg=FederatedConfig(num_clients=4, local_steps=2,
                                participation=0.5),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), base_params=base)
    for _ in range(5):
        tr.run_round()
    t = np.asarray(tr.opt_state["t"])
    # 2 of 4 clients per round x 2 local steps x 5 rounds = 20 total steps
    assert t.sum() == 20
    assert t.max() < 10 * 2      # no client trained every round (w.h.p.)
    # aggregated A still synchronized across ALL clients (incl. non-sampled)
    a = np.asarray(tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]["a"])
    np.testing.assert_allclose(a[0], a[3], rtol=1e-5, atol=1e-7)


def test_weighted_aggregation():
    lora = {"x": {"q": {"a": jnp.arange(12.0).reshape(3, 2, 2),
                        "b": jnp.ones((3, 2, 2))}}}
    w = jnp.array([1.0, 0.0, 1.0])
    out = aggregate_clients(lora, True, False, weights=w)
    a = np.asarray(out["x"]["q"]["a"])
    want = (np.arange(12.0).reshape(3, 2, 2)[[0, 2]]).mean(0)
    np.testing.assert_allclose(a[1], want)


def test_warmup_cosine_shape():
    lr = warmup_cosine(2.0, 10, 110, final_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(2.0)
    assert float(lr(110)) == pytest.approx(0.2, rel=1e-3)
    # monotone decay after warmup
    vals = [float(lr(t)) for t in range(10, 111, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_make_schedule_in_optimizer():
    from repro.optim.optimizers import make_optimizer
    cfg = OptimizerConfig(name="sgd", lr=1.0, lr_schedule="step",
                          lr_schedule_kwargs={"decay": 0.5, "every": 2})
    init, update = make_optimizer(cfg)
    p = {"w": jnp.ones((4,))}
    st = init(p)
    g = {"w": jnp.ones((4,))}
    deltas = []
    for _ in range(4):
        upd, st = update(g, st, p)
        deltas.append(float(-upd["w"][0]))
    assert deltas[0] == pytest.approx(1.0)       # t=1: no decay yet
    assert deltas[1] == pytest.approx(0.5)       # t=2: one decay
    assert deltas[3] == pytest.approx(0.25)      # t=4: two decays

"""Adapter lifecycle: versioned bank hot-swap + host-overflow LRU.

Four layers of guarantees, strongest first:

  * ``AdapterBank.publish`` swaps exactly one padded slot, bumps the
    version, leaves every other tenant bit-untouched, and rejects
    rank-ceiling / structure violations instead of silently reshaping.
  * ZERO RECOMPILES: what the serving engines trace (``bank.requests``)
    keeps its treedef and leaf shapes across publishes, and the jitted
    engine caches (fixed generate, paged admit/chunk, the slot-swap
    executable itself) do not grow when publishes land mid-serve.
  * ``LiveAdapterBank`` residency: LRU promotion into free-then-oldest
    slots, pinned slots never evicted (impossible acquires defer, not
    corrupt), demotion is free because the host store is authoritative,
    and an overflowing live bank serves token-identically to a static
    bank holding every tenant.
  * Train->serve: ``FederatedTrainer.publish_adapters`` /
    ``publish_adapter_state`` stream round results into a live bank with
    logit parity bit-identical to the trainer's own stacked adapters —
    across hot swaps, at fixed shapes.

Plus the tenant-identity regressions the lifecycle depends on: evicted
engine slots reset their ids_arr entry (stale ids would corrupt LRU
accounting), and out-of-range adapter ids raise at the host boundary
instead of being clamp-gathered to the last tenant.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import publish_adapter_state
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.core.lora import (AdapterBank, AdapterSet, LiveAdapterBank,
                             _bank_slot_swap, init_adapter_set)
from repro.data.synthetic import FederatedDataset
from repro.kernels import dispatch
from repro.launch import serve
from repro.models.api import build_model


def _cfg(use_pallas=False, num_layers=2):
    return ModelConfig(name="lifec", family="dense", num_layers=num_layers,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=64, use_pallas=use_pallas)


@pytest.fixture(autouse=True)
def _clean_dispatch():
    dispatch.force_mode(None)
    yield
    dispatch.force_mode(None)


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _mk_set(params, cfg, rank, seed, n_clients=1):
    return init_adapter_set(params, jax.random.key(seed),
                            LoRAConfig(rank=rank, alpha=8.0,
                                       targets=cfg.lora_targets),
                            n_clients=n_clients)


def _mk_bank(params, cfg, ranks=(4, 8, 4)):
    return AdapterBank.from_sets(
        [_mk_set(params, cfg, r, 10 + i) for i, r in enumerate(ranks)])


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


# --------------------------------------------------- versioned bank publish

def test_bank_publish_swaps_one_slot(tiny):
    cfg, model, params = tiny
    bank = _mk_bank(params, cfg)
    new = _mk_set(params, cfg, 4, seed=99)
    b2 = bank.publish(1, new, donate=False)
    assert (bank.version, b2.version) == (0, 1)
    assert b2.ranks == (4, 4, 4) and b2.size == bank.size
    # slot 1 now holds the prepared+padded new set; slots 0/2 bit-untouched
    from repro.core.lora import adapter_rank, pad_rank_tree
    want = pad_rank_tree(new.prepared().lora, bank.r_max)
    for got, exp in zip(_leaves(b2.adapter(1).lora), _leaves(want)):
        np.testing.assert_array_equal(got, exp)
    for k in (0, 2):
        for got, exp in zip(_leaves(b2.adapter(k).lora),
                            _leaves(bank.adapter(k).lora)):
            np.testing.assert_array_equal(got, exp)


def test_bank_publish_rejects_bad_inputs(tiny):
    cfg, model, params = tiny
    bank = _mk_bank(params, cfg)
    new = _mk_set(params, cfg, 4, seed=5)
    with pytest.raises(ValueError, match="out of range"):
        bank.publish(bank.size, new)
    with pytest.raises(ValueError, match="exceeds the bank's r_max"):
        bank.publish(0, _mk_set(params, cfg, 16, seed=6))
    broken = dataclasses.replace(
        new, lora={"oops": jax.tree.leaves(new.lora)[0]})
    with pytest.raises(ValueError, match="structure"):
        bank.publish(0, broken)


def test_bank_version_is_not_a_cache_key(tiny):
    """The invariant behind zero-recompile swaps: what jit traces — the
    bank's requests() view — has an identical treedef and identical leaf
    shapes before and after a publish (even one changing the slot's active
    rank), and the version counter never enters the pytree."""
    cfg, model, params = tiny
    bank = _mk_bank(params, cfg)
    b2 = bank.publish(2, _mk_set(params, cfg, 8, seed=7), donate=False)
    ids = jnp.asarray([0, 1, 2])
    assert (jax.tree.structure(bank.requests(ids))
            == jax.tree.structure(b2.requests(ids)))
    assert ([x.shape for x in jax.tree.leaves(bank.requests(ids))]
            == [x.shape for x in jax.tree.leaves(b2.requests(ids))])
    # version is host-only bookkeeping: flatten/unflatten drops it
    leaves, td = jax.tree.flatten(b2)
    assert jax.tree.unflatten(td, leaves).version == 0


def test_publish_zero_recompile_fixed_engine(tiny):
    """Publishing between generate_banked calls reuses every executable:
    neither the generation program nor the slot-swap jit gains an entry."""
    cfg, model, params = tiny
    bank = _mk_bank(params, cfg)
    ids = jnp.asarray([0, 1, 2])
    prompt = jnp.asarray(np.full((3, 4), 7), jnp.int32)
    out0 = serve.generate_banked(model, params, bank, ids, prompt, 4, 8)
    bank = bank.publish(0, _mk_set(params, cfg, 4, seed=19))  # warm the swap
    gen_c = model._serve_jit_cache["generate"]._cache_size()
    swap_c = _bank_slot_swap._cache_size()
    for slot in (0, 1, 2):
        bank = bank.publish(slot, _mk_set(params, cfg, 4, seed=20 + slot))
        serve.generate_banked(model, params, bank, ids, prompt, 4, 8)
    assert model._serve_jit_cache["generate"]._cache_size() == gen_c
    assert _bank_slot_swap._cache_size() == swap_c
    assert bank.version == 4
    # and the published adapters actually serve: tenant rows changed
    out3 = serve.generate_banked(model, params, bank, ids, prompt, 4, 8)
    assert out0.shape == out3.shape


# ------------------------------------------------------- live bank residency

def test_live_bank_lru_promotion_and_pinning(tiny):
    cfg, model, params = tiny
    sets = [_mk_set(params, cfg, 4, seed=30 + t) for t in range(4)]
    live = LiveAdapterBank.from_sets(sets, hot_slots=2)
    assert live.tenants == [0, 1, 2, 3]
    assert live.resident(0) and live.resident(1) and not live.resident(2)

    # promote 2: tenant 0 is older (never touched) -> slot 0 is the victim
    live.touch([1])
    sm = live.acquire([2], ())
    assert sm == {2: live.tenant_slot[2]}
    assert not live.resident(0) and live.resident(1) and live.resident(2)
    assert (live.promotions, live.demotions) == (1, 1)

    # pinned slots never evicted: with both slots pinned, acquire defers
    pinned = set(live.tenant_slot.values())
    assert live.acquire([0], pinned) is None
    assert not live.resident(0)          # nothing changed on the failed path

    # unknown tenants are an error, not a clamp
    with pytest.raises(KeyError, match="unknown tenant 9"):
        live.acquire([9], ())


def test_live_bank_publish_resident_vs_overflow(tiny):
    cfg, model, params = tiny
    sets = [_mk_set(params, cfg, 4, seed=40 + t) for t in range(3)]
    live = LiveAdapterBank.from_sets(sets, hot_slots=2)
    new = _mk_set(params, cfg, 4, seed=77)
    # resident tenant: host store AND device slot update (one hot swap)
    v = live.publish(0, new)
    assert v == 1 and live.swaps == 1 and live.bank.version == 1
    # overflow tenant: host store only — no device traffic
    v = live.publish(2, new)
    assert v == 1 and live.swaps == 1
    # a brand-new tenant registers at version 0
    assert live.publish(7, new) == 0
    assert 7 in live.store and not live.resident(7)
    # when tenant 2 is later promoted, it must carry the PUBLISHED weights
    sm = live.acquire([2], pinned={live.tenant_slot[0]})
    from repro.core.lora import pad_rank_tree
    want = pad_rank_tree(new.prepared().lora, live.r_max)
    for got, exp in zip(_leaves(live.bank.adapter(sm[2]).lora),
                        _leaves(want)):
        np.testing.assert_array_equal(got, exp)


def test_scheduled_live_overflow_token_identity(tiny):
    """An overflowing live bank (2 hot slots, 4 tenants, promotion/demotion
    churn through the stream) serves the exact tokens of a static bank
    holding all 4 tenants on device."""
    cfg, model, params = tiny
    sets = [_mk_set(params, cfg, r, seed=50 + i, n_clients=4)
            for i, r in enumerate((4, 8, 4, 8))]
    static = AdapterBank.from_sets(sets)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(8)]

    def mk():
        return [serve.Request(rid=i, prompt=prompts[i], steps=6,
                              adapter_id=i % 4) for i in range(8)]

    done_s = serve.serve_scheduled(model, params, mk(), bank=static,
                                   max_batch=2, chunk=3, wait=False)
    live = LiveAdapterBank.from_sets(sets, hot_slots=2)
    done_l = serve.serve_scheduled(model, params, mk(), bank=live,
                                   max_batch=2, chunk=3, wait=False)
    assert live.promotions > 0 and live.demotions > 0
    for a, b in zip(done_s, done_l):
        assert a.tokens == b.tokens


def test_scheduled_swap_window_zero_recompile_and_deterministic(tiny):
    """Publishes landing mid-serve through on_boundary: the paged engine's
    executables do not grow, and the run is deterministic (same stream +
    same publish schedule twice -> identical tokens)."""
    cfg, model, params = tiny
    sets = [_mk_set(params, cfg, 4, seed=60 + t) for t in range(3)]
    pub = _mk_set(params, cfg, 4, seed=88)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(6)]

    def run():
        live = LiveAdapterBank.from_sets(sets, hot_slots=2)

        def on_boundary(i):
            if i == 2:
                live.publish(0, pub)        # resident: device hot swap
                live.publish(2, pub)        # overflow: host store only

        reqs = [serve.Request(rid=i, prompt=prompts[i], steps=6,
                              adapter_id=i % 3) for i in range(6)]
        done = serve.serve_scheduled(model, params, reqs, bank=live,
                                     max_batch=2, chunk=3, wait=False,
                                     on_boundary=on_boundary)
        assert live.swaps >= 1
        return [r.tokens for r in done]

    first = run()
    admit_c = model._serve_jit_cache["paged_admit"]._cache_size()
    chunk_c = model._serve_jit_cache["paged_chunk"]._cache_size()
    assert first == run()
    assert model._serve_jit_cache["paged_admit"]._cache_size() == admit_c
    assert model._serve_jit_cache["paged_chunk"]._cache_size() == chunk_c


# ----------------------------------------------------------- train -> serve

def _tiny_trainer(model, n=3):
    ds = FederatedDataset(64, n, seq_len=16, batch_per_client=2, seed=3)
    return FederatedTrainer(
        model, ds, lora_cfg=LoRAConfig(rank=4, alpha=8.0),
        fed_cfg=FederatedConfig(num_clients=n, local_steps=1,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05), seed=3)


def test_trainer_publish_logit_parity_across_swap(tiny):
    """The acceptance bar: after a round publishes into a live bank —
    including hot swaps of resident tenants — serve-side logits through the
    live bank are BIT-IDENTICAL to the trainer's own stacked adapters at
    fixed shapes, for every tenant (resident and promoted-from-host)."""
    cfg, model, params = tiny
    tr = _tiny_trainer(model, n=3)
    live = LiveAdapterBank.from_sets(
        [tr.client_adapters(c) for c in range(3)], hot_slots=2)
    tr.run(2)
    assert tr.publish_adapters(live) == 3        # 2 hot swaps + 1 host write
    assert live.swaps == 2
    toks = jnp.asarray(tr.dataset.eval_batch(2))
    static = AdapterBank.from_adapter_set(tr.adapters)   # train-side stack
    for c in range(3):
        sm = live.acquire([c], ())
        serve_side, _ = model.forward(
            tr.base, {"tokens": toks},
            adapters=live.bank.gather(jnp.asarray([sm[c]] * toks.shape[0])))
        train_side, _ = model.forward(
            tr.base, {"tokens": toks},
            adapters=static.gather(jnp.asarray([c] * toks.shape[0])))
        np.testing.assert_array_equal(np.asarray(serve_side),
                                      np.asarray(train_side))


def test_publish_adapter_state_roundtrip(tiny, tmp_path):
    """Checkpoint handoff: trainer saves, the server publishes every client
    from the file into a live bank; the served rows equal the restored
    stacked set exactly."""
    cfg, model, params = tiny
    tr = _tiny_trainer(model, n=2)
    tr.run(1)
    path = str(tmp_path / "round.npz")
    tr.save(path)
    live = LiveAdapterBank.from_sets(
        [tr.client_adapters(c) for c in range(2)], hot_slots=2)
    tr.run(1)                                    # trainer moves on...
    tr.save(path)                                # ...and re-publishes
    base, n = publish_adapter_state(path, live)
    assert n == 2 and live.version == 2
    static = AdapterBank.from_adapter_set(tr.adapters)
    for c in range(2):
        for got, exp in zip(_leaves(live.bank.adapter(live.tenant_slot[c]).lora),
                            _leaves(static.adapter(c).lora)):
            np.testing.assert_array_equal(got, exp)


# ------------------------------------------------ tenant-identity regressions

class _RecordingBank:
    """Duck-typed AdapterBank wrapper recording every ids array the
    scheduler gathers — the satellite-1 pin needs to SEE what idle slots
    request."""

    def __init__(self, bank):
        self._bank = bank
        self.seen = []

    @property
    def size(self):
        return self._bank.size

    def requests(self, ids):
        self.seen.append(np.asarray(ids).copy())
        return self._bank.requests(ids)


def test_evicted_slot_resets_tenant_id(tiny):
    """satellite 1: finish() clears ids_arr[slot].  Admit tenants (0, 2) on
    two slots with different step counts; after the short request finishes,
    every later full-width gather must read 0 for its slot — a stale 2
    would keep driving LRU/residency accounting for an idle slot."""
    cfg, model, params = tiny
    rec = _RecordingBank(_mk_bank(params, cfg))
    rng = np.random.default_rng(2)
    reqs = [serve.Request(rid=0, prompt=rng.integers(0, 64, 4).astype(np.int32),
                          steps=9, adapter_id=0),
            serve.Request(rid=1, prompt=rng.integers(0, 64, 4).astype(np.int32),
                          steps=2, adapter_id=2)]
    serve.serve_scheduled(model, params, reqs, bank=rec, max_batch=2,
                          chunk=3, wait=False)
    full = [ids for ids in rec.seen if ids.shape == (2,)]
    slot1 = [int(ids[1]) for ids in full]
    assert 2 in slot1, "tenant 2 never gathered while running"
    tail = slot1[slot1.index(2) + 1:]
    assert tail and all(s == 0 for s in tail[1:]), \
        f"stale tenant id after eviction: {slot1}"


def test_out_of_range_adapter_id_raises(tiny):
    """satellite 2: ids past the bank raise at the host boundary (gather
    would silently clamp to the last tenant) — naming the offending rid."""
    cfg, model, params = tiny
    bank = _mk_bank(params, cfg)
    prompt = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(ValueError, match="clamp"):
        serve.generate_banked(model, params, bank, jnp.asarray([0, 3]),
                              prompt, 2, 8)
    reqs = [serve.Request(rid=5, prompt=np.zeros(4, np.int32), steps=2,
                          adapter_id=-1)]
    with pytest.raises(ValueError, match="rid=5"):
        serve.serve_scheduled(model, params, reqs, bank=bank, max_batch=2,
                              wait=False)
    live = LiveAdapterBank.from_sets(
        [_mk_set(params, cfg, 4, seed=1)], hot_slots=1)
    reqs = [serve.Request(rid=3, prompt=np.zeros(4, np.int32), steps=2,
                          adapter_id=4)]
    with pytest.raises(ValueError, match="rid=3"):
        serve.serve_scheduled(model, params, reqs, bank=live, max_batch=2,
                              wait=False)


def test_make_requests_validates_trace_ids(tmp_path):
    p = tmp_path / "trace.json"
    p.write_text(json.dumps([{"arrival": 0.0, "adapter": 1},
                             {"arrival": 0.1, "adapter": 5}]))
    with pytest.raises(ValueError, match="rid=1"):
        serve.make_requests(str(p), prompt_len=4, steps=4, tenants=2,
                            vocab=64)


# ---------------------------------------------------------- interpret tier

def test_lifecycle_interpret_tier(tiny):
    """CI serve-perf proof: swap parity + zero recompiles survive the fused
    BGMV interpret tier (kernel bodies engaged, ids-indexed BlockSpecs)."""
    dispatch.force_mode("interpret")
    dispatch.reset_stats()
    cfg = _cfg(use_pallas=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    sets = [_mk_set(params, cfg, 4, seed=70 + t, n_clients=2)
            for t in range(3)]
    static = AdapterBank.from_sets(sets)
    pub = _mk_set(params, cfg, 4, seed=91, n_clients=2)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(4)]

    def mk():
        return [serve.Request(rid=i, prompt=prompts[i], steps=5,
                              adapter_id=i % 3) for i in range(4)]

    done_s = serve.serve_scheduled(model, params, mk(), bank=static,
                                   max_batch=2, chunk=3, wait=False)
    live = LiveAdapterBank.from_sets(sets, hot_slots=2)
    done_l = serve.serve_scheduled(model, params, mk(), bank=live,
                                   max_batch=2, chunk=3, wait=False)
    for a, b in zip(done_s, done_l):
        assert a.tokens == b.tokens
    assert dispatch.stats["bgmv"] > 0, "BGMV kernel tier never engaged"
    admit_c = model._serve_jit_cache["paged_admit"]._cache_size()
    chunk_c = model._serve_jit_cache["paged_chunk"]._cache_size()
    serve.serve_scheduled(
        model, params, mk(), bank=live, max_batch=2, chunk=3, wait=False,
        on_boundary=lambda i: live.publish(0, pub) if i == 1 else None)
    assert live.swaps >= 1
    assert model._serve_jit_cache["paged_admit"]._cache_size() == admit_c
    assert model._serve_jit_cache["paged_chunk"]._cache_size() == chunk_c

"""PartitionSpec assignment for every pytree in the system.

Policy (DESIGN.md §6):
  - base params: tensor-parallel over "model" (out-features of up-projections,
    in-features of down-projections, vocab dim of embed/head, expert dim of
    MoE stacks); stacked layer dims replicated.
  - LoRA: A replicated (the aggregated client-shared object), B model-sharded
    on d_out; leading client dim over ("pod","data").
  - batch dims over ("pod","data"); decode caches: batch if divisible, else
    the cache sequence dim; kv-heads over "model" when divisible.

Every rule checks divisibility and degrades to replication, so the same code
serves the 16x16 pod, the 2x16x16 multi-pod, and 1-device CPU tests.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _div(size, mesh, axes) -> bool:
    if axes is None:
        return True
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    prod = 1
    for n in names:
        if n not in mesh.axis_names:
            return False
        prod *= mesh.shape[n]
    return size % prod == 0 and prod > 1


def _maybe(size, mesh, axes):
    return axes if _div(size, mesh, axes) else None


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ------------------------------------------------------------------ params

# leaf-name -> which trailing dim gets "model"   (-1 = last, -2, ... ; None)
_COL = {"q": -1, "k": -1, "v": -1, "w_gate": -1, "w_up": -1, "shared_gate": -1,
        "shared_up": -1, "wx": -1, "wy": -1, "w_z": -1, "w_i": -1, "w_f": -1,
        "w_o": -1, "ogate": -1, "w_a": -1, "lm_head": -1, "patch_proj": -1,
        "w_proj": -1}
_ROW = {"o": -2, "w_down": -2, "shared_down": -2, "w_out": -2, "embed": -2}
_EXPERT = ("moe",)          # subtree name whose 3D leaves shard dim -3


def param_spec(path_keys, shape, mesh) -> P:
    """path_keys: tuple of str keys from the pytree root to the leaf."""
    leaf = path_keys[-1]
    parent = path_keys[-2] if len(path_keys) > 1 else ""
    nd = len(shape)
    spec = [None] * nd
    if leaf == "embed":
        from repro.sharding.opts import enabled
        if enabled("embed_dshard"):
            if _div(shape[-1], mesh, "model"):
                spec[-1] = "model"
            return P(*spec)

    def set_model(dim):
        d = dim % nd
        if _div(shape[d], mesh, "model"):
            spec[d] = "model"

    if parent == "moe" and nd >= 3 and leaf in ("w_gate", "w_up", "w_down"):
        set_model(-3)                      # expert-parallel stacks
    elif parent == "moe" and len(path_keys) > 2 and nd >= 4:
        set_model(-3)
    elif leaf in _COL and not (parent == "moe" and leaf in ("w_gate", "w_up")):
        set_model(_COL[leaf])
    elif leaf in _ROW:
        set_model(_ROW[leaf])
    elif leaf == "r_z" or leaf.startswith("r_") and nd == 3:
        set_model(-1)
    # stacked-layer leading dims / norms / biases stay replicated
    # MoE stacked under repeat: path ... 'moe' 'w_gate' with nd==4 (L,E,d,ff)
    if parent == "moe" and leaf in ("w_gate", "w_up", "w_down") and nd == 4:
        spec = [None] * nd
        if _div(shape[1], mesh, "model"):
            spec[1] = "model"
    return P(*spec)


def tree_specs(tree, mesh, spec_fn):
    """Map a path-aware spec function over a pytree -> NamedSharding tree."""
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (f"#{i}",)) for i, v in enumerate(node)]
            return type(node)(t)
        return NamedSharding(mesh, spec_fn(path, node.shape, mesh))
    return walk(tree, ())


def params_sharding(params, mesh):
    return tree_specs(params, mesh, param_spec)


# ------------------------------------------------------------------- LoRA

def lora_spec(path_keys, shape, mesh, *, client_dim: bool) -> P:
    leaf = path_keys[-1]          # "a" or "b"
    nd = len(shape)
    spec = [None] * nd
    if client_dim:
        ca = batch_axes(mesh)
        if ca and _div(shape[0], mesh, ca):
            spec[0] = ca if len(ca) > 1 else ca[0]
    if leaf == "b" and _div(shape[-2], mesh, "model"):
        spec[-2] = "model"        # B rows follow the base weight's out dim
    return P(*spec)


def lora_sharding(lora, mesh, *, client_dim=True):
    """Shardings for a LoRA tree — or an :class:`AdapterSet`, which comes
    back as an AdapterSet of shardings (same treedef: gamma/rank mask are
    static aux data, so only the A/B leaves need placements)."""
    from repro.core.lora import AdapterSet
    if isinstance(lora, AdapterSet):
        import dataclasses
        return dataclasses.replace(
            lora, lora=lora_sharding(lora.lora, mesh, client_dim=client_dim))
    return tree_specs(lora, mesh,
                      lambda p, s, m: lora_spec(p, s, m,
                                                client_dim=client_dim))


# ------------------------------------------------------------------- cache

def cache_spec(path_keys, shape, mesh) -> P:
    leaf = path_keys[-1]
    nd = len(shape)
    stacked = any(k.startswith("p") and k[1:].isdigit() for k in path_keys)
    off = 1 if (stacked and "repeat" in path_keys) else 0
    spec = [None] * nd
    ba = batch_axes(mesh)
    bdim = off                                # batch dim position
    bsz = shape[bdim] if nd > bdim else 0
    batch_ok = ba and _div(bsz, mesh, ba)
    if leaf in ("k", "v"):                    # (b, S, kh, hd)
        from repro.sharding.opts import enabled
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        elif _div(shape[off + 1], mesh, ba):
            spec[off + 1] = ba if len(ba) > 1 else ba[0]   # seq-sharded cache
        if enabled("cache_seq_shard") and spec[off + 1] is None and                 _div(shape[off + 1], mesh, "model"):
            spec[off + 1] = "model"
        elif _div(shape[off + 2], mesh, "model"):
            spec[off + 2] = "model"
        elif _div(shape[off + 3], mesh, "model"):
            spec[off + 3] = "model"
    elif leaf == "pos":                       # (b, S)
        from repro.sharding.opts import enabled
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        elif _div(shape[off + 1], mesh, ba):
            spec[off + 1] = ba if len(ba) > 1 else ba[0]
        if enabled("cache_seq_shard") and spec[off + 1] is None and                 _div(shape[off + 1], mesh, "model"):
            spec[off + 1] = "model"
    elif leaf in ("cross_k", "cross_v"):
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        if _div(shape[off + 2], mesh, "model"):
            spec[off + 2] = "model"
    elif leaf in ("h", "c", "n", "conv_tail"):  # recurrent states (b, ..., d)
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
    elif leaf == "C":                          # mlstm (b, h, hd, hd)
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
    elif leaf == "m":
        if batch_ok:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
    return P(*spec)


def cache_sharding(cache, mesh):
    return tree_specs(cache, mesh, cache_spec)


# ------------------------------------------------------------------- inputs

def input_spec(path_keys, shape, mesh, *, client_dim: bool) -> P:
    """tokens (b, s) / (N, steps, b, s); frames/patches analogous."""
    nd = len(shape)
    spec = [None] * nd
    ba = batch_axes(mesh)
    if not ba:
        return P(*spec)
    ax = ba if len(ba) > 1 else ba[0]
    if client_dim:
        if _div(shape[0], mesh, ba):
            spec[0] = ax
    else:
        if _div(shape[0], mesh, ba):
            spec[0] = ax
    return P(*spec)


def inputs_sharding(batch, mesh, *, client_dim=False):
    return tree_specs(batch, mesh,
                      lambda p, s, m: input_spec(p, s, m,
                                                 client_dim=client_dim))


def chunked_input_spec(path_keys, shape, mesh) -> P:
    """Scan-staged training batches (chunk_rounds, N, steps, b, s): the
    leading scan dim stays replicated, the client dim (dim 1) shards over
    the client/batch axes when divisible."""
    nd = len(shape)
    spec = [None] * nd
    ba = batch_axes(mesh)
    if nd > 1 and ba and _div(shape[1], mesh, ba):
        spec[1] = ba if len(ba) > 1 else ba[0]
    return P(*spec)


def chunked_inputs_sharding(batch, mesh):
    return tree_specs(batch, mesh, chunked_input_spec)

"""Beyond-paper performance switches (hillclimbed in EXPERIMENTS.md §Perf).

All default False = paper-faithful baseline.  The dry-run enables subsets via
``--opts a,b,c`` so baseline and optimized variants lower separately.

  expand_kv          repeat GQA KV heads to the full head count before the
                     attention einsums so the head dim shards cleanly over
                     `model` (kills replicated-attention redundant compute
                     when kv_heads < model-axis size).
  seq_parallel_attn  shard the query block's sequence dim over `model` inside
                     blockwise attention when heads don't divide the axis
                     (context parallelism; paligemma/gemma 8-head case).
  chunked_ce         compute the CE loss in sequence chunks so the (b, s, V)
                     logits tensor never materializes (memory-term fix).
  remat_dots         layer-scan checkpoint saves dot outputs instead of
                     recomputing the whole block (compute-term fix, costs
                     memory).
  moe_grouped        per-batch-row MoE dispatch groups: router cumsum and
                     capacity are group-local, buffers shard (group->data,
                     expert->model) (collective/memory-term fix).
  seq_parallel_residual  shard the residual stream's sequence dim over
                     `model` between blocks (Megatron-SP analogue): norms and
                     per-token ops run seq-sharded, activations stored 1/16
                     per device (memory-term fix; GSPMD inserts the gathers
                     at the attention boundary).
"""
from __future__ import annotations

OPTS = {
    "expand_kv": False,
    "seq_parallel_attn": False,
    "chunked_ce": False,
    "remat_dots": False,
    "moe_grouped": False,
    "seq_parallel_residual": False,
    # decode-path: shard the embedding table on d instead of vocab, making
    # the token lookup shard-local (kills the full-table all-gather that
    # dominates decode collective terms); the tied head pays a small
    # (b, 1, V) psum instead.
    "embed_dshard": False,
    # decode-path: shard the KV cache's sequence dim over `model` (instead of
    # kv-heads/head-dim).  Attention then computes per-shard partial scores
    # and GSPMD combines via a tiny (b,h,1,S) gather + psum instead of
    # all-gathering the hd-sharded cache (~134MB/layer for gemma decode).
    "cache_seq_shard": False,
}


def enabled(name: str) -> bool:
    return OPTS[name]


def set_opts(names, value: bool = True) -> None:
    for n in names:
        if n not in OPTS:
            raise ValueError(f"unknown opt '{n}'; options {sorted(OPTS)}")
        OPTS[n] = value


def reset() -> None:
    for k in OPTS:
        OPTS[k] = False

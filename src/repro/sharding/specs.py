"""Sharding rules + an in-model constraint helper that no-ops off-mesh."""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axis_in_mesh(mesh, name) -> bool:
    if name is None:
        return True
    names = (name,) if isinstance(name, str) else tuple(name)
    return all(n in mesh.axis_names for n in names)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Enables in-model ``constrain`` calls for the duration."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def current_mesh():
    return getattr(_state, "mesh", None)


def constrain(x, spec):
    """``with_sharding_constraint`` when a mesh is active and the dims divide
    evenly; identity otherwise (keeps single-device tests unannotated)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    parts = []
    for dim, name in enumerate(spec):
        if name is None or not _axis_in_mesh(mesh, name):
            parts.append(None)
            continue
        names = (name,) if isinstance(name, str) else tuple(name)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        parts.append(name if x.shape[dim] % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


# ------------------------------------------------------------------ param rules

def _divides(n, k):
    return k > 0 and n % k == 0


def param_spec(path: str, shape, mesh_shape) -> P:
    """Default tensor-parallel placement for a base-model parameter.

    ``path`` is the flattened pytree key path (``/``-joined).  ``mesh_shape``
    maps axis name -> size.  Client/pod axes never appear on base params.
    """
    m = mesh_shape.get("model", 1)

    def mdl(dim_size):
        return "model" if _divides(dim_size, m) else None

    leaf = path.split("/")[-1]
    if leaf in ("embed", "lm_head", "patch_proj"):
        # (vocab, d) or (d, vocab): shard the big dim
        big = 0 if shape[0] >= shape[-1] else len(shape) - 1
        spec = [None] * len(shape)
        spec[big] = mdl(shape[big])
        return P(*spec)
    if leaf in ("q", "k", "v", "w_gate", "w_up", "shared_gate", "shared_up",
                "wx", "wy", "w_in"):
        return P(None, mdl(shape[-1]))
    if leaf in ("o", "w_down", "shared_down", "w_out"):
        return P(mdl(shape[0]), None)
    if leaf in ("w_gate_e", "w_up_e", "w_down_e"):
        return P(mdl(shape[0]), None, None)
    if len(shape) == 3 and leaf in ("w_gate", "w_up", "w_down"):
        # stacked experts (E, ., .)
        return P(mdl(shape[0]), None, None)
    return P(*([None] * len(shape)))


def stacked(spec: P, extra=None) -> P:
    """Prepend a leading (layer-stack or client) dim to a spec."""
    return P(extra, *spec)

"""Runtime sanitizers: recompile detection and implicit-transfer guards.

:class:`RecompileGuard` generalizes the ad-hoc ``_cache_size()`` asserts
the adapter-lifecycle tests grew: instead of hand-picking one jitted
function and asserting its cache size, wrap or watch any engine and get
a structured error naming the function, the cache growth, and the avals
of the offending call.

Two modes, composable:

* ``watch(name, fn)`` — snapshot the executable-cache size now (use
  *after* warmup); :meth:`check` raises if any watched cache grew.
* ``wrap(name, fn)`` — return a callable proxy that records each call's
  signature (leaf avals + static values).  Cache growth on a signature
  seen before is a hard error — that is a true recompile.  Growth on a
  *new* signature is recorded as a legitimate first compile, unless the
  same aval signature keeps arriving with fresh treedefs
  (``max_treedef_variants``), which is the aux-churn failure mode: a
  per-call object in pytree aux gives every call a new treedef, so the
  cache grows without bound while the avals never change.

``no_implicit_transfers`` / ``guard_transfers`` wire JAX's
``transfer_guard("disallow")`` around compiled engines: once an engine is
warmed, dispatching it must not trigger implicit host<->device copies
(an un-device_put operand recompiles nothing but silently serializes
every step on a transfer).
"""

from __future__ import annotations

import contextlib
import functools

import jax


class RecompileError(RuntimeError):
    """A jitted function compiled again for a signature it already served."""


class TransferGuardError(RuntimeError):
    """An implicit host<->device transfer fired inside a guarded region."""


def _cache_size(fn) -> int | None:
    """Executable-cache size of a jitted callable, or None if ``fn`` does
    not expose one (plain callables are watchable no-ops)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def _describe_leaf(leaf) -> tuple:
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is not None and dtype is not None:
        return ("aval", tuple(shape), str(dtype))
    try:
        hash(leaf)
    except TypeError:
        return ("obj", type(leaf).__name__)
    return ("val", type(leaf).__name__, leaf)


def _signature(args, kwargs):
    """(aval_sig, full_sig): aval_sig is shapes/dtypes + static values —
    what *should* determine compilation; full_sig adds the treedef, so
    structurally different calls stay distinct."""
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    aval_sig = tuple(_describe_leaf(leaf) for leaf in leaves)
    return aval_sig, (aval_sig, str(treedef))


def _render_sig(aval_sig) -> str:
    parts = []
    for entry in aval_sig[:12]:
        if entry[0] == "aval":
            _, shape, dtype = entry
            parts.append(f"{dtype}[{','.join(map(str, shape))}]")
        else:
            parts.append(repr(entry[-1]))
    if len(aval_sig) > 12:
        parts.append(f"... +{len(aval_sig) - 12} more")
    return ", ".join(parts)


class _GuardedFn:
    """Callable proxy around a jitted function.  Attribute access (e.g.
    ``_cache_size``, ``lower``) passes through, so existing cache-size
    asserts keep working on wrapped engines."""

    def __init__(self, guard: "RecompileGuard", name: str, fn, cache_probe=None):
        self._guard = guard
        self._name = name
        self._fn = fn
        self._probe = cache_probe if cache_probe is not None else fn

    def __call__(self, *args, **kwargs):
        before = _cache_size(self._probe)
        out = self._fn(*args, **kwargs)
        after = _cache_size(self._probe)
        self._guard._record_call(self._name, args, kwargs, before, after)
        return out

    def __getattr__(self, item):
        return getattr(self._fn, item)

    def __repr__(self):
        return f"<RecompileGuard wrap of {self._name}: {self._fn!r}>"


class RecompileGuard:
    """Detect unexpected executable-cache growth in jitted engines.

    Usage (watch mode, after warmup)::

        guard = RecompileGuard()
        guard.watch_model(model)          # every _serve_jit_cache entry
        ... timed / production section ...
        guard.check()                     # raises RecompileError on growth

    Usage (wrap mode, per-call attribution)::

        step = guard.wrap("decode_step", jitted_step)
        step(params, tokens)              # raises at the offending call

    As a context manager, ``__enter__`` snapshots all watched baselines
    and ``__exit__`` runs :meth:`check`.
    """

    def __init__(self, *, max_treedef_variants: int = 4):
        self._watched: dict[str, tuple[object, int | None]] = {}
        self._seen_full: dict[str, set] = {}
        self._aval_treedefs: dict[str, dict[tuple, set]] = {}
        self._cache_after: dict[str, int | None] = {}
        self.max_treedef_variants = max_treedef_variants
        self.events: list[str] = []

    # -- watch mode --------------------------------------------------------

    def watch(self, name: str, fn) -> None:
        """Snapshot ``fn``'s cache size now; later growth fails check().
        Callables without a cache probe are recorded as no-ops."""
        self._watched[name] = (fn, _cache_size(fn))

    def watch_model(self, model) -> None:
        """Watch every jitted engine cached on a model via the
        ``_serve_jit_cache`` attribute-cache protocol (serve._model_jit)."""
        cache = getattr(model, "_serve_jit_cache", None) or {}
        for name, fn in cache.items():
            self.watch(name, fn)

    def check(self) -> None:
        grew = []
        for name, (fn, baseline) in self._watched.items():
            current = _cache_size(fn)
            if baseline is not None and current is not None and current > baseline:
                grew.append(f"{name}: executable cache {baseline} -> {current}")
        if grew:
            raise RecompileError(
                "unexpected recompilation after warmup — "
                + "; ".join(grew)
                + ". Every shape/static combination must be warmed before "
                "the guarded section (register-then-warm discipline)."
            )

    # -- wrap mode ---------------------------------------------------------

    def wrap(self, name: str, fn, *, cache_probe=None) -> _GuardedFn:
        """Return a guarded proxy for ``fn``.  ``cache_probe`` lets you
        attribute an engine whose jit cache lives on an inner attribute
        (e.g. an object whose ``__call__`` dispatches ``self.fn``)."""
        return _GuardedFn(self, name, fn, cache_probe)

    def _record_call(self, name, args, kwargs, before, after) -> None:
        aval_sig, full_sig = _signature(args, kwargs)
        seen = self._seen_full.setdefault(name, set())
        treedefs = self._aval_treedefs.setdefault(name, {})
        grew = before is not None and after is not None and after > before
        if grew and full_sig in seen:
            raise RecompileError(
                f"RecompileGuard[{name}]: recompiled on a previously-served "
                f"signature (cache {before} -> {after}); offending avals: "
                f"{_render_sig(aval_sig)}. Something non-hashable or "
                "unstable (weak types, treedef aux, static arg identity) is "
                "defeating the jit cache."
            )
        variants = treedefs.setdefault(aval_sig, set())
        variants.add(full_sig)
        if grew and len(variants) > self.max_treedef_variants:
            raise RecompileError(
                f"RecompileGuard[{name}]: {len(variants)} distinct treedefs "
                f"for identical avals ({_render_sig(aval_sig)}), cache "
                f"{before} -> {after}. A per-call object in pytree aux "
                "churns the treedef and grows the executable cache without "
                "bound — move it out of aux (see AdapterBank versioning)."
            )
        if grew:
            self.events.append(f"{name}: first compile for {_render_sig(aval_sig)}")
        seen.add(full_sig)
        self._cache_after[name] = after

    # -- context manager ---------------------------------------------------

    def __enter__(self) -> "RecompileGuard":
        self._watched = {
            name: (fn, _cache_size(fn)) for name, (fn, _) in self._watched.items()
        }
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.check()


# ---------------------------------------------------------------------------
# transfer guard
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def no_implicit_transfers():
    """Region in which implicit host<->device transfers are errors.

    Explicit conversion (``jax.device_put``, ``jnp.asarray``) stays
    allowed — the point is to catch *un-staged* operands: calling a
    compiled engine with a numpy array silently re-uploads it on every
    dispatch.  Enable after warmup (tracing inside the region would trip
    on constant staging)."""
    try:
        with jax.transfer_guard("disallow"):
            yield
    except Exception as exc:  # XlaRuntimeError has an unstable module path
        if "transfer" in str(exc).lower() and "disallow" in str(exc).lower():
            raise TransferGuardError(
                f"implicit host<->device transfer inside a guarded engine "
                f"region: {exc}. device_put the operand once at the host "
                "boundary instead of re-uploading per call."
            ) from exc
        raise


def guard_transfers(fn):
    """Wrap a warmed, compiled engine so every call runs under
    ``jax.transfer_guard('disallow')``."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        with no_implicit_transfers():
            return fn(*args, **kwargs)

    wrapper.__transfer_guarded__ = True
    return wrapper

"""Lint rules R1-R5 and R7 (R6, the Pallas checks, lives in pallas_rules).

Each rule is a generator ``rule(info: ModuleInfo) -> Iterator[(rule_id,
lineno, message)]``.  Every rule encodes one bug class this repo has
actually shipped and debugged — the message says which invariant broke,
the rule table in README.md says which PR it came from.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from repro.analysis.astutil import FuncNode, ModuleInfo, call_name, decorator_names

Emit = Iterator[tuple[str, int, str]]

# ---------------------------------------------------------------------------
# R1 — host nondeterminism inside traced code
# ---------------------------------------------------------------------------

_R1_TIME = {"time", "monotonic", "perf_counter", "process_time", "time_ns"}


def rule_r1_host_rng(info: ModuleInfo) -> Emit:
    """No host RNG / wall clock reachable from jit/scan bodies.

    ``time.time()`` or ``np.random``/stdlib ``random`` inside a traced
    function executes once at trace time and bakes a constant into the
    compiled program — the scan body silently reuses the same "random"
    draw every round.  Use ``jax.random`` with explicit key threading.
    """
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if not name:
            continue
        parts = name.split(".")
        bad = None
        if len(parts) == 2 and parts[1] in _R1_TIME and info.module_alias_of(
            parts[0], "time"
        ):
            bad = f"wall clock `{name}`"
        elif len(parts) >= 2 and info.module_alias_of(parts[0], "random"):
            bad = f"host RNG `{name}` (stdlib random)"
        elif (
            len(parts) >= 3
            and parts[1] == "random"
            and info.module_alias_of(parts[0], "numpy")
        ):
            bad = f"host RNG `{name}` (numpy.random)"
        if bad and info.in_traced_context(node):
            yield (
                "R1",
                node.lineno,
                f"{bad} inside a traced (jit/scan) body: executes once at "
                "trace time and freezes into the compiled program; thread a "
                "jax.random key instead",
            )


# ---------------------------------------------------------------------------
# R2 — inline jit construction in per-step code
# ---------------------------------------------------------------------------


def _is_builder_style(info: ModuleInfo, node: ast.Call) -> bool:
    """jit calls that are fine at function scope: immediately returned
    (builder pattern, result cached by the caller), assigned to a ``self``
    attribute in ``__init__``-style caching, or chained into ``.lower()``
    for AOT compilation."""
    parent = info.parents.get(node)
    # return jax.jit(...)  /  lambda m: jax.jit(m.step)
    if isinstance(parent, (ast.Return, ast.Lambda)):
        return True
    # jax.jit(...).lower(...) / .eval_shape(...): AOT, no cache at play
    if isinstance(parent, ast.Attribute):
        return True
    # self._eval_loss = jax.jit(...): cached attribute
    if isinstance(parent, ast.Assign):
        for target in parent.targets:
            if (
                isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"
            ):
                return True
    # jax.jit(...)(x) immediately called once is still a per-call compile,
    # so no exemption for ast.Call parents.
    return False


def rule_r2_inline_jit(info: ModuleInfo) -> Emit:
    """No inline ``jax.jit`` construction in per-step code.

    Two high-signal shapes: (a) ``jax.jit(...)`` inside a Python loop body
    builds a fresh jit wrapper (and executable cache) every iteration;
    (b) ``jax.jit(obj.method)`` on a non-module object at function scope
    rebinds the method each call, so the cache never hits.  Hoist to
    module level or a cached attribute (see ``serve._model_jit``).
    """
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name not in ("jax.jit", "jit", "jax.pmap"):
            continue
        fn = info.enclosing_function(node)
        if fn is None:  # module level is always fine
            continue
        if _is_builder_style(info, node):
            continue
        if info.in_loop(node):
            yield (
                "R2",
                node.lineno,
                f"inline `{name}(...)` inside a loop body: a fresh jit "
                "wrapper (with an empty executable cache) is built every "
                "iteration; hoist to module level or a cached attribute",
            )
            continue
        # jax.jit(x.method) where x is a local/parameter (not an imported
        # module): the bound-method object is new on every access, so a
        # per-call jit never reuses its cache (the PR-4 decode_step bug).
        target = node.args[0] if node.args else None
        if (
            isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id not in info.imports
        ):
            yield (
                "R2",
                node.lineno,
                f"inline `{name}({ast.unparse(target)})` at function scope "
                "binds a fresh method object per call, so the jit cache "
                "never hits; build once at module level or cache on the "
                "model (serve._model_jit)",
            )


# ---------------------------------------------------------------------------
# R3 — pytree aux hygiene
# ---------------------------------------------------------------------------

_R3_UNHASHABLE_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)


def _aux_expressions(info: ModuleInfo) -> Iterator[tuple[ast.AST, int]]:
    """Yield the aux expression of every tree_flatten / register_pytree."""
    for node in ast.walk(info.tree):
        # def tree_flatten(self): return (children, aux)
        if (
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and node.name == "tree_flatten"
        ):
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Return) and isinstance(
                    stmt.value, ast.Tuple
                ) and len(stmt.value.elts) == 2:
                    yield stmt.value.elts[1], stmt.lineno
        # register_pytree_node(Cls, lambda x: ((...), aux), ...)
        if isinstance(node, ast.Call) and (call_name(node) or "").endswith(
            "register_pytree_node"
        ):
            for arg in node.args[1:2]:
                if isinstance(arg, ast.Lambda) and isinstance(
                    arg.body, ast.Tuple
                ) and len(arg.body.elts) == 2:
                    yield arg.body.elts[1], arg.lineno


def rule_r3_pytree_aux(info: ModuleInfo) -> Emit:
    """Pytree aux must be hashable host data.

    A device array, list, or dict in ``tree_flatten`` aux makes the
    treedef unhashable and aborts the C++ pjit fast path (every dispatch
    falls back to the slow python path — or worse, a per-call aux object
    churns the executable cache).  Aux must be tuples of host scalars /
    bytes; device values belong in the children.
    """
    for aux, lineno in _aux_expressions(info):
        for sub in ast.walk(aux):
            if isinstance(sub, _R3_UNHASHABLE_DISPLAYS):
                yield (
                    "R3",
                    getattr(sub, "lineno", lineno),
                    f"pytree aux contains an unhashable "
                    f"`{type(sub).__name__.lower()}` display: treedef "
                    "hashing fails and the pjit C++ fast path aborts; use "
                    "nested tuples",
                )
            elif isinstance(sub, ast.Call):
                name = call_name(sub) or ""
                root = name.split(".")[0]
                if info.module_alias_of(root, "jax") or root == "jnp":
                    yield (
                        "R3",
                        getattr(sub, "lineno", lineno),
                        f"pytree aux built from `{name}(...)`: device values "
                        "in aux are unhashable (and churn the jit cache if "
                        "they vary); put arrays in the children and encode "
                        "statics as host scalars/bytes",
                    )


# ---------------------------------------------------------------------------
# R4 — host-only code must guard against tracers
# ---------------------------------------------------------------------------

_NP_COERCIONS = {"asarray", "array", "stack", "concatenate"}


def _has_tracer_guard(info: ModuleInfo, fn: FuncNode, seen: set | None = None) -> bool:
    """Tracer protection: an explicit ``jax.core.Tracer`` isinstance check
    in the body, the ``@host_only`` decorator (runtime guard), or a call
    into a same-module function that is itself guarded."""
    seen = seen if seen is not None else set()
    if fn in seen:
        return False
    seen.add(fn)
    if not isinstance(fn, ast.Lambda) and any(
        d.endswith("host_only") for d in decorator_names(fn)
    ):
        return True
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Attribute) and node.attr == "Tracer":
                return True
            if isinstance(node, ast.Call):
                name = call_name(node) or ""
                short = name.split(".")[-1]
                for callee in info.defs_by_name.get(short, ()):
                    if _has_tracer_guard(info, callee, seen):
                        return True
    return False


def rule_r4_host_only(info: ModuleInfo) -> Emit:
    """Host conversions of function parameters need tracer guards.

    ``np.asarray(param)`` on a traced value raises a cryptic
    ``TracerArrayConversionError`` deep inside numpy (or silently
    constant-folds at trace time).  Host-only entry points must either
    carry ``@host_only`` (runtime guard over all args) or check
    ``isinstance(x, jax.core.Tracer)`` before coercing.
    """
    for fn in ast.walk(info.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn in info.traced:
            continue  # traced code converting params is a different bug (R1)
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        params.discard("self")
        if not params:
            continue
        flagged: list[tuple[int, str]] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            name = call_name(node) or ""
            parts = name.split(".")
            if (
                len(parts) == 2
                and parts[1] in _NP_COERCIONS
                and info.module_alias_of(parts[0], "numpy")
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id in params
            ):
                flagged.append((node.lineno, name))
        if flagged and not _has_tracer_guard(info, fn):
            for lineno, name in flagged:
                yield (
                    "R4",
                    lineno,
                    f"host coercion `{name}(...)` of parameter in "
                    f"`{fn.name}` without a tracer guard: a traced value "
                    "here either crashes in numpy or constant-folds "
                    "silently; decorate with @host_only or check "
                    "isinstance(x, jax.core.Tracer)",
                )


# ---------------------------------------------------------------------------
# R5 — id-array gathers need host-boundary validation
# ---------------------------------------------------------------------------

_ID_NAME = re.compile(r"^(adapter_)?ids?(_arr)?$|^tenant_ids?$|^gather_ids$")


def _id_gathers(info: ModuleInfo) -> Iterator[tuple[ast.AST, str]]:
    for node in ast.walk(info.tree):
        # x[ids]
        if isinstance(node, ast.Subscript):
            idx = node.slice
            if isinstance(idx, ast.Name) and _ID_NAME.match(idx.id):
                yield node, f"subscript gather on `{idx.id}`"
        # jnp.take(x, ids, ...) / x.take(ids)
        elif isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name.endswith(".take") or name.endswith("take_along_axis"):
                for arg in node.args[:2]:
                    if isinstance(arg, ast.Name) and _ID_NAME.match(arg.id):
                        yield node, f"`{name}` gather on `{arg.id}`"


def rule_r5_unchecked_gather(info: ModuleInfo) -> Emit:
    """Gathers on id arrays must sit behind host-boundary validation.

    JAX gathers clamp out-of-range indices instead of raising, so a bad
    tenant id silently serves the *last* tenant's adapter.  Any function
    gathering by ``ids``-like names must call ``check_adapter_ids`` (or a
    ``_check_adapter_ids``-style validator) on the host boundary first.
    """
    for node, what in _id_gathers(info):
        # search the nearest non-lambda enclosing def (a gather inside a
        # tree.map lambda is validated by its enclosing method)
        fn = info.enclosing_function(node)
        while isinstance(fn, ast.Lambda):
            fn = info.enclosing_function(fn)
        scope_nodes = ast.walk(fn) if fn is not None else ast.walk(info.tree)
        checked = False
        for sub in scope_nodes:
            if isinstance(sub, ast.Call):
                name = (call_name(sub) or "").split(".")[-1]
                if "check" in name and ("ids" in name or "adapter" in name):
                    checked = True
                    break
        if not checked:
            yield (
                "R5",
                node.lineno,
                f"{what} without id validation in scope: JAX clamps "
                "out-of-range indices, so a bad id silently gathers the "
                "last slot (wrong tenant); route through "
                "check_adapter_ids() at the host boundary",
            )


# ---------------------------------------------------------------------------
# R7 — shadowed / function-local numpy+jax imports
# ---------------------------------------------------------------------------

_R7_MODULES = ("numpy", "jax")


def _imports_root_at_module_level(info: ModuleInfo, root: str) -> bool:
    return any(
        target == root or target.startswith(root + ".")
        for target in info.imports.values()
    )


def rule_r7_shadowed_import(info: ModuleInfo) -> Emit:
    """No shadowing numpy/jax imports, no rebinding of their aliases.

    A ``import numpy as _np`` inside an engine function whose module
    already imports numpy gives the file two bindings for one library —
    the next refactor that moves a line out of the function picks up the
    *other* binding (this is how host RNG leaked into the round loop).
    Function-local imports in modules that deliberately avoid a top-level
    jax dependency (lazy imports) are allowed: with no module binding
    there is nothing to shadow.
    """
    for node in ast.walk(info.tree):
        fn = info.enclosing_function(node)
        if fn is None:
            continue
        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                if root in _R7_MODULES and _imports_root_at_module_level(
                    info, root
                ):
                    yield (
                        "R7",
                        node.lineno,
                        f"function-local `import {alias.name}` shadows the "
                        f"module-level {root} import with a second binding; "
                        "use the top-level alias so all call sites agree",
                    )
        elif isinstance(node, ast.ImportFrom):
            root = (node.module or "").split(".")[0]
            if root in _R7_MODULES and _imports_root_at_module_level(info, root):
                yield (
                    "R7",
                    node.lineno,
                    f"function-local `from {node.module} import ...` shadows "
                    "module scope; hoist to the top-level imports",
                )
        elif isinstance(node, ast.Assign):
            for target in node.targets:
                if isinstance(target, ast.Name) and target.id in info.imports:
                    mod = info.imports[target.id].split(".")[0]
                    if mod in _R7_MODULES:
                        yield (
                            "R7",
                            node.lineno,
                            f"`{target.id}` rebinds a module-level "
                            f"{info.imports[target.id]} import inside a "
                            "function; pick a different local name",
                        )


RULES = [
    rule_r1_host_rng,
    rule_r2_inline_jit,
    rule_r3_pytree_aux,
    rule_r4_host_only,
    rule_r5_unchecked_gather,
    rule_r7_shadowed_import,
]

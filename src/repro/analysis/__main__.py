"""CLI: ``python -m repro.analysis lint [paths...]``.

Exit status 0 iff every finding is either fixed or suppressed by a
justified pragma — the contract the ``lint-analysis`` CI job enforces.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import RULE_IDS, LintConfig, lint_paths, report


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="command", required=True)

    lint = sub.add_parser("lint", help="run the trace-safety lint pass")
    lint.add_argument("paths", nargs="*", default=["src"], help="files or dirs")
    lint.add_argument(
        "--vmem-budget-mb",
        type=float,
        default=16.0,
        help="R6 per-kernel VMEM budget in MiB (double-buffered estimate)",
    )
    lint.add_argument(
        "--assume-dim",
        type=int,
        default=512,
        help="R6 stand-in for block dims the constant folder cannot resolve",
    )
    lint.add_argument(
        "--rules",
        default=",".join(RULE_IDS),
        help="comma-separated rule subset (default: all)",
    )
    lint.add_argument(
        "--show-suppressed",
        action="store_true",
        help="also print pragma-suppressed findings with their justifications",
    )

    args = parser.parse_args(argv)
    if args.command == "lint":
        config = LintConfig(
            vmem_budget=int(args.vmem_budget_mb * 1024 * 1024),
            assume_dim=args.assume_dim,
            rules=tuple(r.strip() for r in args.rules.split(",") if r.strip()),
        )
        findings = lint_paths(args.paths or ["src"], config)
        text, status = report(findings, show_suppressed=args.show_suppressed)
        print(text)
        return status
    return 2


if __name__ == "__main__":
    sys.exit(main())

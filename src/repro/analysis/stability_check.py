"""Collapse sentinel: the paper's Theorem 4.2 as a runnable assertion.

Theorem 4.2 (PAPER.md, App. A eq. 23): the forward moment contributed by
an aggregated LoRA update scales as ``gamma^2 * r / N``.  Only the
SFed-LoRA factor ``gamma = alpha * sqrt(N / r)`` makes that scale equal
``alpha^2`` independently of client count and rank — vanilla
``alpha / r`` collapses the adapter signal at high rank (the moment
shrinks like ``1/r``), and rsLoRA-style ``alpha / sqrt(r)`` explodes it
with N.

This module checks both halves at runtime:

* the *config* half — :func:`predicted_scale` evaluates the theorem for
  the run's ``(gamma, r, N, alpha)`` and flags a mis-scaled setup before
  a single round runs;
* the *measured* half — :func:`stability_report` takes the per-round
  aggregated update norms from the federated engine's metrics path and
  flags geometric drift (explosion/vanishing) across rounds, plus — when
  a reference run is supplied — deviation of the measured level ratio
  from the theorem's ``(gamma_a / gamma_b)^2 * (r_a N_b) / (r_b N_a)``
  prediction.

No jax dependency: inputs are any float-convertible sequence, so the
sentinel runs on engine history dicts, benchmark JSON, or test fixtures.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.scaling import predicted_moment_scale


class ScalingCollapseError(AssertionError):
    """The run violates the Theorem 4.2 stabilized-moment prediction."""


def predicted_scale(gamma: float, r: int, n_clients: int, alpha: float) -> float:
    """Theorem 4.2 moment scale, normalized by ``alpha^2`` — equals 1.0
    exactly when ``gamma`` is the SFed-LoRA factor ``alpha*sqrt(N/r)``."""
    return predicted_moment_scale(gamma, r, n_clients) / (alpha * alpha)


@dataclass
class StabilityReport:
    ok: bool
    verdict: str  # "stabilized" | "collapse" | "explosion" | "drift"
    predicted: float  # normalized Thm 4.2 scale (1.0 == SFed-LoRA)
    trend: float  # total measured drift norms[-1]/norms[0]
    norms: list[float] = field(default_factory=list)
    messages: list[str] = field(default_factory=list)

    def __str__(self) -> str:
        status = "OK" if self.ok else "FAIL"
        body = "; ".join(self.messages) or "within tolerance"
        return (
            f"[{status}:{self.verdict}] Thm4.2 scale={self.predicted:.4g} "
            f"(1.0=SFed-LoRA), measured drift x{self.trend:.4g} over "
            f"{len(self.norms)} rounds: {body}"
        )


def _as_floats(norms) -> list[float]:
    out = [float(v) for v in norms]
    if len(out) < 2:
        raise ValueError(
            "stability_report needs >= 2 per-round update norms to measure a trend"
        )
    return out


def stability_report(
    update_norms,
    *,
    gamma: float,
    r: int,
    n_clients: int,
    alpha: float,
    scale_tol: float = 4.0,
    trend_tol: float = 8.0,
    reference: tuple | None = None,
) -> StabilityReport:
    """Judge a federated run against Theorem 4.2.

    ``update_norms``: per-round aggregated adapter update norms (the
    engine's ``update_norm`` metric).  ``reference``: optional
    ``(ref_norms, ref_gamma)`` or ``(ref_norms, ref_gamma, ref_r,
    ref_n)`` from a second run; the measured level ratio between the runs
    must match the theorem's predicted ratio within ``scale_tol``.
    """
    norms = _as_floats(update_norms)
    pred = predicted_scale(gamma, r, n_clients, alpha)
    messages: list[str] = []
    verdict = "stabilized"

    # -- config half: the scale the theorem assigns this (gamma, r, N) ----
    if pred < 1.0 / scale_tol:
        verdict = "collapse"
        messages.append(
            f"gamma={gamma:.4g} predicts moment scale {pred:.4g}*alpha^2 "
            f"(Thm 4.2: gamma^2*r/N) — adapter signal vanishes at r={r}, "
            f"N={n_clients}; use gamma=alpha*sqrt(N/r)="
            f"{alpha * math.sqrt(n_clients / r):.4g}"
        )
    elif pred > scale_tol:
        verdict = "explosion"
        messages.append(
            f"gamma={gamma:.4g} predicts moment scale {pred:.4g}*alpha^2 "
            f"(Thm 4.2: gamma^2*r/N) — activations blow up with N={n_clients}, "
            f"r={r}; use gamma=alpha*sqrt(N/r)"
        )

    # -- measured half: geometric drift across rounds ---------------------
    floor = 1e-30
    trend = norms[-1] / max(norms[0], floor)
    if trend > trend_tol:
        verdict = "explosion" if verdict == "stabilized" else verdict
        messages.append(
            f"measured update norms grew x{trend:.3g} over {len(norms)} "
            "rounds (stabilized aggregation keeps them flat)"
        )
    elif trend < 1.0 / trend_tol:
        verdict = "collapse" if verdict == "stabilized" else verdict
        messages.append(
            f"measured update norms decayed x{trend:.3g} over {len(norms)} "
            "rounds — the adapter is going silent"
        )

    # -- cross-run level check vs the theorem's predicted ratio -----------
    if reference is not None:
        ref_norms = _as_floats(reference[0])
        ref_gamma = float(reference[1])
        ref_r = int(reference[2]) if len(reference) > 2 else r
        ref_n = int(reference[3]) if len(reference) > 3 else n_clients
        measured_ratio = (sum(norms) / len(norms)) / max(
            sum(ref_norms) / len(ref_norms), floor
        )
        predicted_ratio = predicted_moment_scale(gamma, r, n_clients) / max(
            predicted_moment_scale(ref_gamma, ref_r, ref_n), floor
        )
        deviation = measured_ratio / max(predicted_ratio, floor)
        if not (1.0 / scale_tol <= deviation <= scale_tol):
            verdict = "drift" if verdict == "stabilized" else verdict
            messages.append(
                f"measured level ratio {measured_ratio:.3g} vs reference "
                f"deviates x{deviation:.3g} from the Thm 4.2 prediction "
                f"{predicted_ratio:.3g} — the aggregation path is not "
                "following gamma^2*r/N"
            )

    ok = verdict == "stabilized"
    return StabilityReport(
        ok=ok, verdict=verdict, predicted=pred, trend=trend, norms=norms,
        messages=messages,
    )


def assert_stabilized(update_norms, **kwargs) -> StabilityReport:
    """``stability_report`` that raises :class:`ScalingCollapseError` on
    failure — the form tests and the engine's metrics path use."""
    rep = stability_report(update_norms, **kwargs)
    if not rep.ok:
        raise ScalingCollapseError(str(rep))
    return rep


def recovery_action(report: StabilityReport, *,
                    scale_tol: float = 4.0) -> str:
    """Classify what a watchdog retry should change after a failed verdict.

    ``"rescale"``: the CONFIG half of Theorem 4.2 is violated — the run's
    gamma itself predicts a collapsed/exploded moment scale, which no
    participation backoff or fault reseed can fix (it is deterministic in
    (gamma, r, N)).  The paper's own remedy applies: adopt
    gamma = alpha*sqrt(N/r).

    ``"backoff"``: the config is sound but the MEASURED norms drifted —
    plausibly corrupt/stale uploads slipping through; retry with reduced
    participation and a fresh fault draw.
    """
    if not (1.0 / scale_tol <= report.predicted <= scale_tol):
        return "rescale"
    return "backoff"


def scaling_flatness(moments, tol: float = 4.0) -> tuple[bool, float]:
    """Theorem 4.2 invariance check over a sweep: SFed-LoRA keeps the
    aggregated forward moment flat across ``(N, r)`` configurations.
    ``moments`` is a mapping ``{(n, r): moment}`` or a sequence; returns
    ``(flat, max/min ratio)``."""
    values = [
        float(v) for v in (moments.values() if hasattr(moments, "values") else moments)
    ]
    if not values:
        raise ValueError("scaling_flatness needs at least one moment")
    lo, hi = min(values), max(values)
    ratio = hi / max(lo, 1e-30)
    return ratio <= tol, ratio

"""Shared AST plumbing for the lint rules.

Everything here is pure stdlib ``ast`` — the linter must run in CI jobs
that may not have jax installed, and must never import the modules it
checks.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

# Call targets that receive a function and trace it (their function-valued
# arguments run under jit/scan and must obey traced-context rules).
TRACING_CALLS = {
    "jax.jit",
    "jit",
    "pjit",
    "jax.pmap",
    "jax.vmap",
    "jax.grad",
    "jax.value_and_grad",
    "jax.checkpoint",
    "jax.remat",
    "jax.lax.scan",
    "lax.scan",
    "jax.lax.while_loop",
    "lax.while_loop",
    "jax.lax.fori_loop",
    "lax.fori_loop",
    "jax.lax.cond",
    "lax.cond",
    "jax.lax.switch",
    "lax.switch",
    "jax.lax.map",
    "lax.map",
    "pl.when",
}

# Decorators that make the decorated def a traced context.
TRACING_DECORATORS = {
    "jax.jit",
    "jit",
    "jax.pmap",
    "jax.vmap",
    "jax.checkpoint",
    "jax.custom_vjp",
    "jax.custom_jvp",
    "pl.when",
}


def dotted_name(node: ast.AST) -> str | None:
    """``jax.lax.scan`` for an Attribute/Name chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    name = dotted_name(node.func)
    if name is not None:
        return name
    # functools.partial(jax.jit, ...) used as a decorator or value: report
    # the partial'd function so decorator matching sees "jax.jit".
    if isinstance(node.func, ast.Call):
        inner = dotted_name(node.func.func)
        if inner in ("functools.partial", "partial") and node.func.args:
            return dotted_name(node.func.args[0])
    return None


def decorator_names(node: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    names = []
    for dec in node.decorator_list:
        if isinstance(dec, ast.Call):
            name = call_name(dec)
            if name is None and isinstance(dec.func, ast.Call):
                name = call_name(dec.func)
            # functools.partial(jax.jit, donate_argnums=...) as decorator
            if name in ("functools.partial", "partial") and dec.args:
                name = dotted_name(dec.args[0])
        else:
            name = dotted_name(dec)
        if name:
            names.append(name)
    return names


FuncNode = ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda


@dataclass
class ModuleInfo:
    """One parsed module plus the derived facts every rule needs."""

    path: str
    source: str
    tree: ast.Module
    parents: dict[ast.AST, ast.AST] = field(default_factory=dict)
    # alias -> full module name, for module-level imports ("np" -> "numpy")
    imports: dict[str, str] = field(default_factory=dict)
    # module-level integer constants, constant-folded ("BN" -> 256)
    constants: dict[str, int] = field(default_factory=dict)
    # defs/lambdas that run under trace (jit/scan/grad bodies + closure)
    traced: set[FuncNode] = field(default_factory=set)
    # all function defs keyed by name (module + nested; name collisions keep all)
    defs_by_name: dict[str, list[FuncNode]] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, source: str) -> "ModuleInfo":
        tree = ast.parse(source, filename=path)
        info = cls(path=path, source=source, tree=tree)
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                info.parents[child] = parent
        info._collect_imports()
        info._collect_constants()
        info._collect_defs()
        info._mark_traced()
        return info

    # -- derivation passes -------------------------------------------------

    def _collect_imports(self) -> None:
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.imports[alias.asname or alias.name.split(".")[0]] = alias.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = (
                        f"{node.module}.{alias.name}"
                    )

    def _collect_constants(self) -> None:
        for node in self.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            for target in node.targets:
                if isinstance(target, ast.Name):
                    val = self.fold_int(node.value)
                    if val is not None:
                        self.constants[target.id] = val
                elif isinstance(target, ast.Tuple) and isinstance(
                    node.value, ast.Tuple
                ):
                    if len(target.elts) == len(node.value.elts):
                        for t, v in zip(target.elts, node.value.elts):
                            if isinstance(t, ast.Name):
                                folded = self.fold_int(v)
                                if folded is not None:
                                    self.constants[t.id] = folded

    def _collect_defs(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs_by_name.setdefault(node.name, []).append(node)

    def _mark_traced(self) -> None:
        # Pass 1: defs directly traced via decorator or by being handed to a
        # tracing call (lax.scan body, jax.jit(fn), grad(loss_fn), ...).
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if set(decorator_names(node)) & TRACING_DECORATORS:
                    self.traced.add(node)
            elif isinstance(node, ast.Call) and call_name(node) in TRACING_CALLS:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        self.traced.add(arg)
                    elif isinstance(arg, ast.Name):
                        for fn in self.defs_by_name.get(arg.id, ()):
                            self.traced.add(fn)
                    elif isinstance(arg, ast.Attribute):
                        for fn in self.defs_by_name.get(arg.attr, ()):
                            self.traced.add(fn)
        # Pass 2: close over same-module calls — a def invoked by name from
        # a traced body is itself traced (one fixpoint loop is enough for
        # this repo's nesting depth; cap the iterations regardless).
        for _ in range(8):
            grew = False
            for fn in list(self.traced):
                body = fn.body if isinstance(fn.body, list) else [fn.body]
                for stmt in body:
                    for node in ast.walk(stmt):
                        if isinstance(node, ast.Call):
                            name = call_name(node)
                            if name and "." not in name:
                                for callee in self.defs_by_name.get(name, ()):
                                    if callee not in self.traced:
                                        self.traced.add(callee)
                                        grew = True
            if not grew:
                break

    # -- queries -----------------------------------------------------------

    def enclosing_function(self, node: ast.AST) -> FuncNode | None:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return cur
            cur = self.parents.get(cur)
        return None

    def in_traced_context(self, node: ast.AST) -> bool:
        cur: ast.AST | None = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                if cur in self.traced:
                    return True
            cur = self.parents.get(cur)
        return False

    def in_loop(self, node: ast.AST) -> bool:
        """Inside a Python for/while body (stopping at function boundaries)."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.For, ast.While)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return False
            cur = self.parents.get(cur)
        return False

    def module_alias_of(self, name: str, module: str) -> bool:
        """True if module-level import binds `name` to `module` (or a submodule)."""
        target = self.imports.get(name)
        return target is not None and (
            target == module or target.startswith(module + ".")
        )

    def fold_int(self, node: ast.AST, env: dict[str, int] | None = None) -> int | None:
        """Best-effort constant folding to a Python int (module constants +
        an optional local env).  Returns None when unresolvable."""
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value if not isinstance(node.value, bool) else None
        if isinstance(node, ast.Name):
            if env and node.id in env:
                return env[node.id]
            return self.constants.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            val = self.fold_int(node.operand, env)
            return -val if val is not None else None
        if isinstance(node, ast.BinOp):
            left = self.fold_int(node.left, env)
            right = self.fold_int(node.right, env)
            if left is None or right is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return left + right
                if isinstance(node.op, ast.Sub):
                    return left - right
                if isinstance(node.op, ast.Mult):
                    return left * right
                if isinstance(node.op, ast.FloorDiv):
                    return left // right
                if isinstance(node.op, ast.Mod):
                    return left % right
                if isinstance(node.op, ast.Pow):
                    return left**right
            except (ZeroDivisionError, OverflowError, ValueError):
                return None
        if isinstance(node, ast.Call):
            name = call_name(node)
            args = [self.fold_int(a, env) for a in node.args]
            if any(a is None for a in args):
                return None
            if name in ("min", "max") and args:
                return min(args) if name == "min" else max(args)
            if name in ("round_up", "tiling.round_up") and len(args) == 2:
                v, mult = args
                return -(-v // mult) * mult
        return None

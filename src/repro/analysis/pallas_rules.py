"""R6 — Pallas kernel discipline: tiling helpers, pure index maps, VMEM budget.

Applies only to modules that touch ``pl.pallas_call`` / ``pl.BlockSpec``.
Three sub-checks share the R6 id (the finding message names the variant):

* R6/tiling — block geometry must come through ``kernels/tiling.py``
  (no re-derived ``SUBLANE``/``LANE`` constants, no inline
  ``-(-a // b) * b`` ceil-round idiom outside tiling.py itself);
* R6/index-map — BlockSpec index maps must be pure index arithmetic
  (no calls, no free variables beyond grid params and module constants);
* R6/vmem — a static worst-case estimate of per-kernel VMEM residency
  (sum of BlockSpec block shapes + scratch shapes, fp32 baseline, 2x for
  the pipeline's double buffering) must stay under a configurable budget.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import ModuleInfo, call_name

Emit = Iterator[tuple[str, int, str]]

#: Default VMEM budget per kernel (bytes).  TPU v4/v5 cores expose ~16 MiB
#: of VMEM; the pipeline double-buffers in/out blocks, so the single-buffer
#: estimate must fit in half of it with headroom for semaphores/regs.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

#: Fallback for block dims the constant-folder cannot resolve (runtime
#: ranks/segments).  512 is the repo's largest tile edge (BK), so this is a
#: deliberately pessimistic stand-in.
DEFAULT_ASSUME_DIM = 512

def _is_tiling_module(info: ModuleInfo) -> bool:
    return info.path.replace("\\", "/").endswith("kernels/tiling.py")


def _uses_pallas(info: ModuleInfo) -> bool:
    return any(
        target in ("jax.experimental.pallas", "jax.experimental.pallas.tpu")
        or target.startswith("jax.experimental.pallas")
        for target in info.imports.values()
    )


# -- R6/tiling --------------------------------------------------------------


def _rule_tiling(info: ModuleInfo) -> Emit:
    for node in ast.walk(info.tree):
        # SUBLANE/LANE re-derived locally
        if isinstance(node, ast.Assign):
            for target in node.targets:
                names = (
                    [target]
                    if isinstance(target, ast.Name)
                    else list(target.elts)
                    if isinstance(target, ast.Tuple)
                    else []
                )
                for t in names:
                    if isinstance(t, ast.Name) and t.id in ("SUBLANE", "LANE"):
                        yield (
                            "R6",
                            node.lineno,
                            f"[tiling] `{t.id}` redefined outside "
                            "kernels/tiling.py: block geometry constants must "
                            "have one source of truth (import them from "
                            "repro.kernels.tiling)",
                        )
        # inline ceil-round idiom -(-a // b) * b
        if (
            isinstance(node, ast.BinOp)
            and isinstance(node.op, ast.Mult)
            and isinstance(node.left, ast.UnaryOp)
            and isinstance(node.left.op, ast.USub)
            and isinstance(node.left.operand, ast.BinOp)
            and isinstance(node.left.operand.op, ast.FloorDiv)
            and isinstance(node.left.operand.left, ast.UnaryOp)
            and isinstance(node.left.operand.left.op, ast.USub)
        ):
            yield (
                "R6",
                node.lineno,
                "[tiling] inline `-(-a // b) * b` ceil-rounding: use "
                "repro.kernels.tiling.round_up so every kernel agrees on "
                "block alignment",
            )


# -- R6/index-map -----------------------------------------------------------

_INDEX_MAP_ALLOWED_CALLS: set[str] = set()


def _block_spec_calls(info: ModuleInfo) -> Iterator[ast.Call]:
    for node in ast.walk(info.tree):
        if isinstance(node, ast.Call) and (call_name(node) or "").endswith(
            "BlockSpec"
        ):
            yield node


def _index_map_of(spec: ast.Call) -> ast.Lambda | None:
    candidates = list(spec.args[1:2]) + [
        kw.value for kw in spec.keywords if kw.arg == "index_map"
    ]
    for cand in candidates:
        if isinstance(cand, ast.Lambda):
            return cand
    return None


def _rule_index_map(info: ModuleInfo) -> Emit:
    for spec in _block_spec_calls(info):
        lam = _index_map_of(spec)
        if lam is None:
            continue
        params = {a.arg for a in lam.args.args}
        for node in ast.walk(lam.body):
            if isinstance(node, ast.Call):
                name = call_name(node) or "<dynamic>"
                if name not in _INDEX_MAP_ALLOWED_CALLS:
                    yield (
                        "R6",
                        node.lineno,
                        f"[index-map] call `{name}(...)` inside a BlockSpec "
                        "index map: index maps must be pure grid-index "
                        "arithmetic (they are traced per grid step and "
                        "anything stateful desyncs the prefetch schedule)",
                    )
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                if node.id not in params and node.id not in info.constants:
                    yield (
                        "R6",
                        node.lineno,
                        f"[index-map] free variable `{node.id}` in a "
                        "BlockSpec index map: only grid params and "
                        "module-level constants are allowed (closure state "
                        "is invisible to the compiled grid schedule)",
                    )


# -- R6/vmem ----------------------------------------------------------------


def _local_int_env(info: ModuleInfo, around: ast.AST) -> dict[str, int]:
    """Fold simple integer assignments in the enclosing function so block
    sizes like ``bn = tiling.block(n, BN, LANE)`` resolve."""
    fn = info.enclosing_function(around)
    env: dict[str, int] = {}
    if fn is None or isinstance(fn, ast.Lambda):
        return env
    # integer parameter defaults (block_seq=128, ...) are static tile knobs
    args = fn.args
    for params, defaults in (
        (args.args[len(args.args) - len(args.defaults):], args.defaults),
        (args.kwonlyargs, args.kw_defaults),
    ):
        for param, default in zip(params, defaults):
            if default is not None:
                val = info.fold_int(default)
                if val is not None:
                    env[param.arg] = val
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign):
            targets = []
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    targets = [(t, stmt.value)]
                elif isinstance(t, ast.Tuple) and isinstance(stmt.value, ast.Tuple):
                    if len(t.elts) == len(stmt.value.elts):
                        targets = [
                            (te, ve)
                            for te, ve in zip(t.elts, stmt.value.elts)
                            if isinstance(te, ast.Name)
                        ]
            for name_node, value in targets:
                val = info.fold_int(value, env)
                if val is not None:
                    env[name_node.id] = val
    return env


def _shape_bytes(
    info: ModuleInfo, shape: ast.AST, env: dict[str, int], assume_dim: int
) -> int:
    if not isinstance(shape, (ast.Tuple, ast.List)):
        # shape passed by name / computed: assume one pessimistic 2D block
        return assume_dim * assume_dim * 4
    total = 4  # fp32 baseline per element
    for dim in shape.elts:
        val = info.fold_int(dim, env)
        total *= val if val is not None and val > 0 else assume_dim
    return total


def _pallas_call_footprint(
    info: ModuleInfo, node: ast.Call, assume_dim: int
) -> tuple[int, list[str]]:
    env = _local_int_env(info, node)
    total = 0
    parts: list[str] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub) or ""
            if name.endswith("BlockSpec") and sub.args:
                b = _shape_bytes(info, sub.args[0], env, assume_dim)
                total += b
                parts.append(f"block {ast.unparse(sub.args[0])}≈{b}B")
            elif name.endswith(".VMEM") or name == "VMEM":
                if sub.args:
                    b = _shape_bytes(info, sub.args[0], env, assume_dim)
                    total += b
                    parts.append(f"scratch {ast.unparse(sub.args[0])}≈{b}B")
    return total, parts


def _rule_vmem(info: ModuleInfo, budget: int, assume_dim: int) -> Emit:
    for node in ast.walk(info.tree):
        if not isinstance(node, ast.Call):
            continue
        if not (call_name(node) or "").endswith("pallas_call"):
            continue
        single, parts = _pallas_call_footprint(info, node, assume_dim)
        estimate = 2 * single  # pipeline double-buffers in/out blocks
        if estimate > budget:
            detail = "; ".join(parts[:6]) or "no resolvable block shapes"
            yield (
                "R6",
                node.lineno,
                f"[vmem] static VMEM estimate {estimate / 2**20:.1f} MiB "
                f"(2x double-buffered) exceeds the "
                f"{budget / 2**20:.1f} MiB budget: {detail}; shrink the "
                "block tiles or raise --vmem-budget-mb with a justification",
            )


def rule_r6_pallas(
    info: ModuleInfo,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    assume_dim: int = DEFAULT_ASSUME_DIM,
) -> Emit:
    if not _uses_pallas(info):
        return
    if not _is_tiling_module(info):
        yield from _rule_tiling(info)
    yield from _rule_index_map(info)
    yield from _rule_vmem(info, vmem_budget, assume_dim)

"""Lint engine: file discovery, pragma handling, rule dispatch, reporting.

Suppression pragmas are line-scoped and *must* carry a justification:

    x = a[ids]  # lint: disable=R5 -- ids validated at the serve boundary

A pragma may sit on the offending line or the line directly above it, and
may list several rules (``disable=R2,R5``).  A pragma without the
``-- justification`` tail is itself an error (rule id ``PRAGMA``) — CI
passing therefore implies every suppression is explained.
"""

from __future__ import annotations

import io
import os
import re
import tokenize
from dataclasses import dataclass, field

from repro.analysis.astutil import ModuleInfo
from repro.analysis.pallas_rules import (
    DEFAULT_ASSUME_DIM,
    DEFAULT_VMEM_BUDGET,
    rule_r6_pallas,
)
from repro.analysis.rules import RULES

_PRAGMA = re.compile(
    r"#\s*lint:\s*disable=(?P<rules>[A-Z0-9,\s]+?)"
    r"(?:\s*--\s*(?P<why>\S.*?))?\s*$"
)

RULE_IDS = ("R1", "R2", "R3", "R4", "R5", "R6", "R7")


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    justification: str | None = None

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}{tag}: {self.message}"


@dataclass
class Pragma:
    line: int
    rules: set[str]
    justification: str | None
    used: bool = False


@dataclass
class LintConfig:
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    assume_dim: int = DEFAULT_ASSUME_DIM
    show_suppressed: bool = False
    rules: tuple[str, ...] = RULE_IDS
    extra: dict = field(default_factory=dict)


def _collect_pragmas(path: str, source: str) -> tuple[list[Pragma], list[Finding]]:
    """Parse ``# lint: disable=...`` comments via tokenize (so pragma-shaped
    strings inside literals — e.g. this linter's own source — don't count)."""
    pragmas: list[Pragma] = []
    bad: list[Finding] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [
            (tok.start[0], tok.string)
            for tok in tokens
            if tok.type == tokenize.COMMENT
        ]
    except tokenize.TokenError:
        comments = []
    for lineno, text in comments:
        match = _PRAGMA.search(text)
        if not match:
            if "lint:" in text and "disable" in text:
                bad.append(
                    Finding(
                        "PRAGMA",
                        path,
                        lineno,
                        f"unparseable lint pragma {text.strip()!r}; expected "
                        "`# lint: disable=R<n>[,R<m>] -- justification`",
                    )
                )
            continue
        rules = {r.strip() for r in match.group("rules").split(",") if r.strip()}
        why = match.group("why")
        if not why:
            bad.append(
                Finding(
                    "PRAGMA",
                    path,
                    lineno,
                    "suppression pragma without a justification: append "
                    "`-- <one-line reason>` (unexplained suppressions fail CI)",
                )
            )
            continue
        unknown = rules - set(RULE_IDS)
        if unknown:
            bad.append(
                Finding(
                    "PRAGMA",
                    path,
                    lineno,
                    f"pragma names unknown rule(s) {sorted(unknown)}; "
                    f"known rules: {', '.join(RULE_IDS)}",
                )
            )
            continue
        pragmas.append(Pragma(line=lineno, rules=rules, justification=why))
    return pragmas, bad


def lint_source(path: str, source: str, config: LintConfig | None = None) -> list[
    Finding
]:
    config = config or LintConfig()
    pragmas, findings = _collect_pragmas(path, source)
    try:
        info = ModuleInfo.parse(path, source)
    except SyntaxError as exc:
        findings.append(
            Finding("PARSE", path, exc.lineno or 0, f"syntax error: {exc.msg}")
        )
        return findings

    raw: list[tuple[str, int, str]] = []
    for rule in RULES:
        raw.extend(rule(info))
    raw.extend(
        rule_r6_pallas(
            info, vmem_budget=config.vmem_budget, assume_dim=config.assume_dim
        )
    )

    by_line = {}
    for pragma in pragmas:
        by_line[pragma.line] = pragma

    for rule_id, lineno, message in sorted(raw, key=lambda r: (r[1], r[0])):
        if rule_id not in config.rules:
            continue
        finding = Finding(rule_id, path, lineno, message)
        for candidate in (lineno, lineno - 1):
            pragma = by_line.get(candidate)
            if pragma is not None and rule_id in pragma.rules:
                finding.suppressed = True
                finding.justification = pragma.justification
                pragma.used = True
                break
        findings.append(finding)
    return findings


def discover(paths: list[str]) -> list[str]:
    files: list[str] = []
    for path in paths:
        if os.path.isfile(path):
            files.append(path)
        else:
            for root, dirs, names in os.walk(path):
                dirs[:] = sorted(
                    d for d in dirs if d not in ("__pycache__", ".git")
                )
                files.extend(
                    os.path.join(root, n) for n in sorted(names) if n.endswith(".py")
                )
    return files


def lint_paths(paths: list[str], config: LintConfig | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for path in discover(paths):
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        findings.extend(lint_source(path, source, config))
    return findings


def report(findings: list[Finding], *, show_suppressed: bool = False) -> tuple[
    str, int
]:
    """Render findings; exit status 1 iff any unsuppressed finding remains."""
    lines: list[str] = []
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    for finding in active:
        lines.append(finding.render())
    if show_suppressed:
        for finding in suppressed:
            lines.append(f"{finding.render()}  [why: {finding.justification}]")
    lines.append(
        f"{len(active)} finding(s), {len(suppressed)} suppressed "
        f"(justified) pragma(s)"
    )
    return "\n".join(lines), (1 if active else 0)

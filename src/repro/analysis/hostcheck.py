"""Runtime host-boundary helpers backing lint rules R4 and R5.

``@host_only`` is the runtime half of R4: the linter accepts a decorated
function as guarded because the decorator actually rejects tracers at
call time.  ``check_adapter_ids`` is the shared validator behind R5 —
every gather on tenant/adapter id arrays routes through it (JAX gathers
clamp out-of-range indices, so an unvalidated id silently serves the
last tenant's adapter).
"""

from __future__ import annotations

import functools

import jax
import numpy as np


class HostOnlyError(TypeError):
    """A traced value reached a function that must run on the host."""


def _find_tracer(args, kwargs):
    for leaf in jax.tree_util.tree_leaves((args, kwargs)):
        if isinstance(leaf, jax.core.Tracer):
            return leaf
    return None


def host_only(fn):
    """Mark ``fn`` as host-side: any tracer among its arguments raises
    :class:`HostOnlyError` immediately, instead of crashing deep inside a
    numpy coercion or — worse — silently constant-folding at trace time.
    The lint pass (R4) treats decorated functions as tracer-guarded."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        tracer = _find_tracer(args, kwargs)
        if tracer is not None:
            raise HostOnlyError(
                f"{fn.__qualname__} is host-only but received a traced value "
                f"({tracer.aval}); call it outside jit, or pass concrete "
                "host data"
            )
        return fn(*args, **kwargs)

    wrapper.__host_only__ = True
    return wrapper


def check_adapter_ids(adapter_ids, size: int, *, what: str = "adapter_id"):
    """Host-boundary validation of request->tenant ids against a bank of
    ``size`` slots.  Inside jit, JAX gather semantics silently CLAMP an
    out-of-range index, so a bad id would be served the LAST tenant's
    adapter with no error — catch it here instead.  Traced ids (a caller
    composing inside its own jit) pass through unchecked; the traced
    path's safety is the caller's host boundary."""
    if isinstance(adapter_ids, jax.core.Tracer):
        return adapter_ids
    ids = np.asarray(adapter_ids)
    bad = np.argwhere((ids < 0) | (ids >= size)).reshape(-1)
    if bad.size:
        raise ValueError(
            f"{what} out of range for a bank of {size} tenants (JAX gather "
            f"would silently clamp to the last tenant): rows "
            f"{bad.tolist()} hold ids {ids.reshape(-1)[bad].tolist()}"
        )
    return adapter_ids

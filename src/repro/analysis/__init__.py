"""Trace-safety static analysis + runtime sanitizers for the JAX/Pallas stack.

Two halves:

* ``repro.analysis.lint`` — an AST lint pass with repo-specific rules
  (R1..R7) codifying the recurring bug classes from CHANGES.md: host RNG
  inside scan bodies, inline ``jax.jit`` recompiles, non-hashable pytree
  aux, unguarded host-only code, bare clip-mode gathers on tenant ids,
  Pallas tiling/VMEM discipline, and shadowed numpy imports.  Run it as
  ``python -m repro.analysis lint src/``.

* ``repro.analysis.sanitizers`` / ``repro.analysis.stability_check`` —
  runtime guards: :class:`RecompileGuard` (generalizes the ad-hoc
  ``_cache_size()`` asserts from the adapter-lifecycle work),
  ``no_implicit_transfers``/``guard_transfers`` (wraps
  ``jax.transfer_guard("disallow")`` around compiled engines), and the
  collapse sentinel that turns the paper's Theorem 4.2 moment-scale
  prediction (gamma^2 * r / N) into a runnable assertion.

Attribute access is lazy so the *linter* stays importable on hosts
without jax: only the sanitizer/stability names pull in the runtime deps.
"""

from __future__ import annotations

_EXPORTS = {
    "Finding": "repro.analysis.lint",
    "lint_paths": "repro.analysis.lint",
    "HostOnlyError": "repro.analysis.hostcheck",
    "check_adapter_ids": "repro.analysis.hostcheck",
    "host_only": "repro.analysis.hostcheck",
    "RecompileError": "repro.analysis.sanitizers",
    "RecompileGuard": "repro.analysis.sanitizers",
    "TransferGuardError": "repro.analysis.sanitizers",
    "guard_transfers": "repro.analysis.sanitizers",
    "no_implicit_transfers": "repro.analysis.sanitizers",
    "ScalingCollapseError": "repro.analysis.stability_check",
    "StabilityReport": "repro.analysis.stability_check",
    "assert_stabilized": "repro.analysis.stability_check",
    "predicted_scale": "repro.analysis.stability_check",
    "scaling_flatness": "repro.analysis.stability_check",
    "stability_report": "repro.analysis.stability_check",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), name)

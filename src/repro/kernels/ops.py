"""Jit'd wrappers over the Pallas kernels — the public kernel API.

On CPU containers the kernels run with interpret=True (Python emulation);
on a real TPU, set ``REPRO_KERNEL_INTERPRET=0`` (or rely on the default
platform detection) to execute the compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.rglru_scan import rglru_scan_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("gamma",))
def fused_lora_matmul(x, w, a, b, gamma: float):
    """Batched fused y = x@W + gamma*(x A^T) B^T; x (..., m, k)."""
    x2 = x.reshape(-1, x.shape[-1])
    out = lora_matmul(x2, w, a, b, gamma, interpret=_interpret())
    return out.reshape(*x.shape[:-1], w.shape[1])


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_mha(q, k, v, *, causal=True, window=None):
    """q (b, s, h, d), k/v (b, t, kh, d) with GQA expansion. -> (b, s, h, d)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        interpret=_interpret())
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@jax.jit
def rglru_scan_op(a, b):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t; a, b (bt, s, d)."""
    return rglru_scan_pallas(a, b, interpret=_interpret())

"""Fused LoRA matmul Pallas-TPU kernel:  y = x @ W + gamma * (x @ A^T) @ B^T.

The paper's hot-spot: every adapted projection pays two extra GEMMs.  A naive
implementation round-trips the rank-r intermediate p = x A^T through HBM and
re-reads x.  This kernel keeps p in VMEM scratch and fuses all three GEMMs in
one pass over x:

  grid (nm, nn, nk), k innermost.  For each m-row of blocks:
    - during the n==0 sweep, p[m] += x[m,k] @ A^T[k]   (accumulated over k)
    - every (n, k) step accumulates out[m,n] += x[m,k] @ W[k,n]
    - at k == nk-1, out[m,n] += gamma * p[m] @ B^T[n]  (p complete by then,
      because the n==0 sweep finishes its k loop before n==1 starts)

Block sizes default to MXU-aligned 256x256x512; the rank dim r stays whole in
VMEM (r <= 512 per the paper's sweeps).  VMEM working set:
bm*bk + bk*bn + bm*bn + bk*r + r*bn + bm*r floats ~= 1.3 MB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, out_ref, p_scratch, *, gamma, nk):
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_scratch[...] = jnp.zeros_like(p_scratch)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)

    @pl.when(n == 0)
    def _acc_p():   # p[m] += x[m,k] @ A^T[k]   (A block is (r, bk))
        p_scratch[...] += xb @ a_ref[...].astype(jnp.float32).T

    out_ref[...] += xb @ w_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _apply_lora():   # out[m,n] += gamma * p[m] @ B^T[n]  (B block (bn, r))
        out_ref[...] += gamma * (p_scratch[...] @
                                 b_ref[...].astype(jnp.float32).T)


def lora_matmul(x, w, a, b, gamma, *, bm=256, bn=256, bk=512,
                interpret=False):
    """x (m, k), w (k, n), a (r, k), b (n, r) -> (m, n) in x.dtype."""
    m, kdim = x.shape
    n = w.shape[1]
    r = a.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    nm, nn, nk = m // bm, n // bn, kdim // bk

    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),    # w
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),     # a
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),     # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)
    return out.astype(x.dtype)

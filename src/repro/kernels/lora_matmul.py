"""Fused LoRA matmul Pallas-TPU kernel:  y = x @ W + gamma * (x @ A^T) @ B^T.

The paper's hot-spot: every adapted projection pays two extra GEMMs.  A naive
implementation round-trips the rank-r intermediate p = x A^T through HBM and
re-reads x.  This kernel keeps p in VMEM and fuses all three GEMMs in one pass
over x:

  grid (nm, nn, nk), k innermost.  For each m-row of blocks:
    - during the n==0 sweep, p[m] += x[m,k] @ A^T[k]   (accumulated over k)
    - every (n, k) step accumulates out[m,n] += x[m,k] @ W[k,n]
    - at k == nk-1, out[m,n] += gamma * p[m] @ B^T[n]  (p complete by then,
      because the n==0 sweep finishes its k loop before n==1 starts)

On differentiated forwards, p is written out as a second output (its
revisited block acts as the accumulator) so the backward pass can reuse it as
a residual instead of recomputing x @ A^T; non-differentiated calls use a
VMEM-scratch variant that never spills p to HBM.

The backward pass is fused the same way (``lora_matmul_vjp`` wires it up as a
``jax.custom_vjp``).  Given the output cotangent g (m, n):

  dx = g @ W^T + gamma * (g @ B) @ A     one fused kernel, structurally the
                                         mirror of the forward (contraction
                                         over n, rank-r intermediate q = g B
                                         kept in VMEM and emitted as residual)
  dA = gamma * q^T @ x                   rank-r reduction over m-blocks
  dB = gamma * g^T @ p                   rank-r reduction over m-blocks
  dW = x^T @ g                           plain XLA GEMM — dead-code-eliminated
                                         whenever the base weights are frozen
                                         (always, in LoRA fine-tuning)

Block sizes default to MXU-aligned 256x256x512; the rank dim r stays whole in
VMEM (r <= 512 per the paper's sweeps).  VMEM working set:
bm*bk + bk*bn + bm*bn + bk*r + r*bn + bm*r floats ~= 1.3 MB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ------------------------------------------------------------------ forward
#
# One kernel body serves two call variants: pallas_call passes scratch refs
# after output refs, so p_ref is either a VMEM scratch buffer (inference,
# decode, non-differentiated calls — p never touches HBM) or a revisited
# (m, r) output block (the custom-VJP fwd rule, which reuses p as a residual
# instead of recomputing x @ A^T in the backward).

def _fwd_kernel(x_ref, w_ref, a_ref, b_ref, out_ref, p_ref, *, gamma, nk):
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)

    @pl.when(n == 0)
    def _acc_p():   # p[m] += x[m,k] @ A^T[k]   (A block is (r, bk))
        p_ref[...] += xb @ a_ref[...].astype(jnp.float32).T

    out_ref[...] += xb @ w_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _apply_lora():   # out[m,n] += gamma * p[m] @ B^T[n]  (B block (bn, r))
        out_ref[...] += gamma * (p_ref[...] @
                                 b_ref[...].astype(jnp.float32).T)


def _clamp_blocks(m, n, kdim, bm, bn, bk):
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    return bm, bn, bk


def _fwd_call_scratch(x, w, a, b, gamma, *, bm, bn, bk, interpret):
    """Forward with p in VMEM scratch; returns y (m, n) fp32 only."""
    m, kdim = x.shape
    n = w.shape[1]
    r = a.shape[0]
    bm, bn, bk = _clamp_blocks(m, n, kdim, bm, bn, bk)
    nm, nn, nk = m // bm, n // bn, kdim // bk
    return pl.pallas_call(
        functools.partial(_fwd_kernel, gamma=gamma, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),    # w
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),     # a
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),     # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)


def _fwd_call(x, w, a, b, gamma, *, bm, bn, bk, interpret):
    """Runs the residual-emitting forward kernel; returns
    (y (m,n) fp32, p (m,r) fp32)."""
    m, kdim = x.shape
    n = w.shape[1]
    r = a.shape[0]
    bm, bn, bk = _clamp_blocks(m, n, kdim, bm, bn, bk)
    nm, nn, nk = m // bm, n // bn, kdim // bk
    return pl.pallas_call(
        functools.partial(_fwd_kernel, gamma=gamma, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),    # w
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),     # a
            pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),     # b
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),    # y
            pl.BlockSpec((bm, r), lambda i, j, k: (i, 0)),     # p (residual)
        ],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32),
                   jax.ShapeDtypeStruct((m, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)


def lora_matmul(x, w, a, b, gamma, *, bm=256, bn=256, bk=512,
                interpret=False):
    """x (m, k), w (k, n), a (r, k), b (n, r) -> (m, n) in x.dtype."""
    out = _fwd_call_scratch(x, w, a, b, gamma, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- backward

def _bwd_dx_kernel(g_ref, w_ref, a_ref, b_ref, dx_ref, q_ref, *, gamma, nt):
    """dx = g @ W^T + gamma * (g @ B) @ A, contraction over the n dim (t);
    q = g @ B accumulates in the revisited q output block (the bwd mirror of
    the forward's p)."""
    j = pl.program_id(1)   # k-block of dx
    t = pl.program_id(2)   # n-block (contraction)

    @pl.when((j == 0) & (t == 0))
    def _init_q():
        q_ref[...] = jnp.zeros_like(q_ref)

    @pl.when(t == 0)
    def _init_dx():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    gb = g_ref[...].astype(jnp.float32)

    @pl.when(j == 0)
    def _acc_q():   # q[m] += g[m,t] @ B[t]   (B block is (bn, r))
        q_ref[...] += gb @ b_ref[...].astype(jnp.float32)

    dx_ref[...] += gb @ w_ref[...].astype(jnp.float32).T

    @pl.when(t == nt - 1)
    def _apply_lora():   # dx[m,j] += gamma * q[m] @ A[:,j]  (A block (r, bk))
        dx_ref[...] += gamma * (q_ref[...] @ a_ref[...].astype(jnp.float32))


def _bwd_dx_call(g, w, a, b, gamma, *, bm, bn, bk, interpret):
    """Returns (dx (m,k) fp32, q = g @ B (m,r) fp32)."""
    m, n = g.shape
    kdim = w.shape[0]
    r = a.shape[0]
    bm, bn, bk = _clamp_blocks(m, n, kdim, bm, bn, bk)
    nm, nkb, nt = m // bm, kdim // bk, n // bn
    return pl.pallas_call(
        functools.partial(_bwd_dx_kernel, gamma=gamma, nt=nt),
        grid=(nm, nkb, nt),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, t: (i, t)),    # g
            pl.BlockSpec((bk, bn), lambda i, j, t: (j, t)),    # w
            pl.BlockSpec((r, bk), lambda i, j, t: (0, j)),     # a
            pl.BlockSpec((bn, r), lambda i, j, t: (t, 0)),     # b
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, j)),    # dx
            pl.BlockSpec((bm, r), lambda i, j, t: (i, 0)),     # q (residual)
        ],
        out_shape=[jax.ShapeDtypeStruct((m, kdim), jnp.float32),
                   jax.ShapeDtypeStruct((m, r), jnp.float32)],
        interpret=interpret,
    )(g, w, a, b)


def _bwd_da_kernel(q_ref, x_ref, da_ref, *, gamma):
    """dA[:, j] += gamma * q[i]^T @ x[i, j], reduced over m-blocks (i)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)

    da_ref[...] += gamma * (q_ref[...].T @ x_ref[...].astype(jnp.float32))


def _bwd_da_call(q, x, gamma, *, bm, bk, interpret):
    m, r = q.shape
    kdim = x.shape[1]
    bm, bk = min(bm, m), min(bk, kdim)
    nm, nkb = m // bm, kdim // bk
    return pl.pallas_call(
        functools.partial(_bwd_da_kernel, gamma=gamma),
        grid=(nkb, nm),
        in_specs=[
            pl.BlockSpec((bm, r), lambda j, i: (i, 0)),        # q
            pl.BlockSpec((bm, bk), lambda j, i: (i, j)),       # x
        ],
        out_specs=pl.BlockSpec((r, bk), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, kdim), jnp.float32),
        interpret=interpret,
    )(q, x)


def _bwd_db_kernel(g_ref, p_ref, db_ref, *, gamma):
    """dB[j] += gamma * g[i, j]^T @ p[i], reduced over m-blocks (i)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        db_ref[...] = jnp.zeros_like(db_ref)

    db_ref[...] += gamma * (g_ref[...].astype(jnp.float32).T @ p_ref[...])


def _bwd_db_call(g, p, gamma, *, bm, bn, interpret):
    m, n = g.shape
    r = p.shape[1]
    bm, bn = min(bm, m), min(bn, n)
    nm, nn = m // bm, n // bn
    return pl.pallas_call(
        functools.partial(_bwd_db_kernel, gamma=gamma),
        grid=(nn, nm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),       # g
            pl.BlockSpec((bm, r), lambda j, i: (i, 0)),        # p
        ],
        out_specs=pl.BlockSpec((bn, r), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), jnp.float32),
        interpret=interpret,
    )(g, p)


# --------------------------------------------------------------- custom VJP

# gamma is baked into the kernels at trace time (a static closure value), so
# each distinct (gamma, blocks, interpret) combination is its own op; the
# cache is bounded so scaling-factor sweeps can't accumulate ops forever.
@functools.lru_cache(maxsize=64)
def _vjp_op(gamma, bm, bn, bk, interpret):
    kw = dict(bm=bm, bn=bn, bk=bk, interpret=interpret)

    @jax.custom_vjp
    def op(x, w, a, b):
        # primal-only evaluation (no grad): scratch variant, no p in HBM
        y = _fwd_call_scratch(x, w, a, b, gamma, **kw)
        return y.astype(x.dtype)

    def fwd(x, w, a, b):
        y, p = _fwd_call(x, w, a, b, gamma, **kw)
        return y.astype(x.dtype), (x, w, a, b, p)

    def bwd(res, g):
        x, w, a, b, p = res
        dx, q = _bwd_dx_call(g, w, a, b, gamma, **kw)
        da = _bwd_da_call(q, x, gamma, bm=bm, bk=bk, interpret=interpret)
        db = _bwd_db_call(g, p, gamma, bm=bm, bn=bn, interpret=interpret)
        dw = x.astype(jnp.float32).T @ g.astype(jnp.float32)
        return (dx.astype(x.dtype), dw.astype(w.dtype),
                da.astype(a.dtype), db.astype(b.dtype))

    op.defvjp(fwd, bwd)
    return op


def lora_matmul_vjp(x, w, a, b, gamma, *, bm=256, bn=256, bk=512,
                    interpret=False):
    """Differentiable fused LoRA matmul (``jax.custom_vjp`` with fused Pallas
    backward kernels).  Same contract as :func:`lora_matmul`; ``gamma`` and
    the block sizes must be static (python) values — the model stack's gamma
    is a host-side float, so this holds on the training path."""
    m, kdim = x.shape
    n = w.shape[1]
    bm, bn, bk = _clamp_blocks(m, n, kdim, bm, bn, bk)
    return _vjp_op(float(gamma), bm, bn, bk, bool(interpret))(x, w, a, b)


# ------------------------------------------------------- quantized variants
#
# The frozen base weight arrives PACKED (core/quant.py): int8 per-channel
# (data (k, n) int8 + scales (1, n)) or int4 grouped (data (k/2, n) uint8,
# two values per byte along k, + scales (k/G, n)).  The BlockSpecs DMA the
# packed tile + its scale rows into VMEM and `dequant_block` expands them
# there — fp base weights never exist in HBM.  Everything else (schedule,
# LoRA delta, residuals, backward) mirrors the fp kernels above.

def _unpack4(wd):
    """uint8 (rows, n) packed nibble pairs -> int32 (2*rows, n) in [-8, 7];
    row 2t is the low nibble of packed row t, row 2t+1 the high nibble."""
    wi = wd.astype(jnp.int32)
    lo = wi & 0xF
    hi = (wi >> 4) & 0xF
    lo = lo - 2 * (lo & 0x8)    # sign-extend 4-bit two's complement
    hi = hi - 2 * (hi & 0x8)
    return jnp.stack([lo, hi], axis=1).reshape(wd.shape[0] * 2, wd.shape[1])


def dequant_block(wd, ws, bits):
    """Expand one packed VMEM tile to its fp32 (bk, bn) block.

    int8: wd (bk, bn) int8, ws (1, bn) — per-channel scale broadcast.
    int4: wd (bk/2, bn) uint8, ws (bk/G, bn) — per-group scale rows; the
    group size G is implied by the shapes (G = bk // ws rows)."""
    if bits == 8:
        return wd.astype(jnp.float32) * ws.astype(jnp.float32)
    vals = _unpack4(wd).astype(jnp.float32)      # (bk, bn)
    ng, bn = ws.shape
    g = vals.shape[0] // ng
    vals = vals.reshape(ng, g, bn) * ws.astype(jnp.float32)[:, None, :]
    return vals.reshape(ng * g, bn)


def _quant_w_shapes(bits, gsize, bk, bn):
    """(data block, scales block) VMEM tile shapes for one (bk, bn) W tile."""
    if bits == 8:
        return (bk, bn), (1, bn)
    return (bk // 2, bn), (bk // gsize, bn)


def _fwd_kernel_q(x_ref, wd_ref, ws_ref, a_ref, b_ref, out_ref, p_ref, *,
                  gamma, nk, bits):
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)

    @pl.when(n == 0)
    def _acc_p():
        p_ref[...] += xb @ a_ref[...].astype(jnp.float32).T

    out_ref[...] += xb @ dequant_block(wd_ref[...], ws_ref[...], bits)

    @pl.when(k == nk - 1)
    def _apply_lora():
        out_ref[...] += gamma * (p_ref[...] @
                                 b_ref[...].astype(jnp.float32).T)


def _quant_dims(x_m, wd, ws, bits, bm, bn, bk):
    """Grid dims + packed block shapes for a padded quant matmul; the padded
    logical k comes from the packed data rows."""
    kdim = wd.shape[0] * (2 if bits == 4 else 1)
    n = wd.shape[1]
    gsize = 0 if bits == 8 else kdim // ws.shape[0]
    assert x_m % bm == 0 and n % bn == 0 and kdim % bk == 0, (x_m, n, kdim)
    bwd, bws = _quant_w_shapes(bits, gsize, bk, bn)
    return kdim, n, bwd, bws


def _fwd_call_q(x, wd, ws, a, b, gamma, *, bits, bm, bn, bk, interpret,
                scratch):
    m = x.shape[0]
    r = a.shape[0]
    kdim, n, bwd, bws = _quant_dims(m, wd, ws, bits, bm, bn, bk)
    nm, nn, nk = m // bm, n // bn, kdim // bk
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),      # x
        pl.BlockSpec(bwd, lambda i, j, k: (k, j)),           # packed W
        (pl.BlockSpec(bws, lambda i, j, k: (0, j)) if bits == 8
         else pl.BlockSpec(bws, lambda i, j, k: (k, j))),    # scales
        pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),       # a
        pl.BlockSpec((bn, r), lambda i, j, k: (j, 0)),       # b
    ]
    kern = functools.partial(_fwd_kernel_q, gamma=gamma, nk=nk, bits=bits)
    if scratch:
        return pl.pallas_call(
            kern, grid=(nm, nn, nk), in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
            interpret=interpret)(x, wd, ws, a, b)
    return pl.pallas_call(
        kern, grid=(nm, nn, nk), in_specs=in_specs,
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bm, r), lambda i, j, k: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32),
                   jax.ShapeDtypeStruct((m, r), jnp.float32)],
        interpret=interpret)(x, wd, ws, a, b)


def _bwd_dx_kernel_q(g_ref, wd_ref, ws_ref, a_ref, b_ref, dx_ref, q_ref, *,
                     gamma, nt, bits):
    j = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((j == 0) & (t == 0))
    def _init_q():
        q_ref[...] = jnp.zeros_like(q_ref)

    @pl.when(t == 0)
    def _init_dx():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    gb = g_ref[...].astype(jnp.float32)

    @pl.when(j == 0)
    def _acc_q():
        q_ref[...] += gb @ b_ref[...].astype(jnp.float32)

    dx_ref[...] += gb @ dequant_block(wd_ref[...], ws_ref[...], bits).T

    @pl.when(t == nt - 1)
    def _apply_lora():
        dx_ref[...] += gamma * (q_ref[...] @ a_ref[...].astype(jnp.float32))


def _bwd_dx_call_q(g, wd, ws, a, b, gamma, *, bits, bm, bn, bk, interpret):
    m, n = g.shape
    r = a.shape[0]
    kdim = wd.shape[0] * (2 if bits == 4 else 1)
    gsize = 0 if bits == 8 else kdim // ws.shape[0]
    bwd_, bws = _quant_w_shapes(bits, gsize, bk, bn)
    nm, nkb, nt = m // bm, kdim // bk, n // bn
    return pl.pallas_call(
        functools.partial(_bwd_dx_kernel_q, gamma=gamma, nt=nt, bits=bits),
        grid=(nm, nkb, nt),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, t: (i, t)),   # g
            pl.BlockSpec(bwd_, lambda i, j, t: (j, t)),       # packed W
            (pl.BlockSpec(bws, lambda i, j, t: (0, t)) if bits == 8
             else pl.BlockSpec(bws, lambda i, j, t: (j, t))),  # scales
            pl.BlockSpec((r, bk), lambda i, j, t: (0, j)),    # a
            pl.BlockSpec((bn, r), lambda i, j, t: (t, 0)),    # b
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, j)),   # dx
            pl.BlockSpec((bm, r), lambda i, j, t: (i, 0)),    # q
        ],
        out_shape=[jax.ShapeDtypeStruct((m, kdim), jnp.float32),
                   jax.ShapeDtypeStruct((m, r), jnp.float32)],
        interpret=interpret,
    )(g, wd, ws, a, b)


def _float0(arr):
    return np.zeros(arr.shape, dtype=jax.dtypes.float0)


@functools.lru_cache(maxsize=64)
def _vjp_op_q(gamma, bits, bm, bn, bk, interpret):
    """Quantized-base custom VJP.  The base is frozen by the LoRA contract:
    the packed data gets a float0 cotangent and the scales get zeros — this
    op is NOT meant for differentiating through the quantization itself."""
    kw = dict(bits=bits, bm=bm, bn=bn, bk=bk, interpret=interpret)

    @jax.custom_vjp
    def op(x, wd, ws, a, b):
        y = _fwd_call_q(x, wd, ws, a, b, gamma, scratch=True, **kw)
        return y.astype(x.dtype)

    def fwd(x, wd, ws, a, b):
        y, p = _fwd_call_q(x, wd, ws, a, b, gamma, scratch=False, **kw)
        return y.astype(x.dtype), (x, wd, ws, a, b, p)

    def bwd(res, g):
        x, wd, ws, a, b, p = res
        dx, q = _bwd_dx_call_q(g, wd, ws, a, b, gamma, **kw)
        da = _bwd_da_call(q, x, gamma, bm=bm, bk=bk, interpret=interpret)
        db = _bwd_db_call(g, p, gamma, bm=bm, bn=bn, interpret=interpret)
        return (dx.astype(x.dtype), _float0(wd), jnp.zeros_like(ws),
                da.astype(a.dtype), db.astype(b.dtype))

    op.defvjp(fwd, bwd)
    return op


def lora_matmul_quant_vjp(x, wd, ws, a, b, gamma, *, bits, bm=256, bn=256,
                          bk=512, interpret=False):
    """Fused LoRA matmul over a PACKED base: x (m, k), data/scales per
    ``dequant_block``, a (r, k), b (n, r) -> (m, n) in x.dtype.  All dims
    must already be padded to block multiples (kernels/dispatch does the
    padding — packed rows pad to bk/2, scale rows to bk/G)."""
    return _vjp_op_q(float(gamma), int(bits), bm, bn, bk,
                     bool(interpret))(x, wd, ws, a, b)


# base-only quantized GEMM (no adapter): y = x @ dequant(W) — the MLP and
# un-adapted projection path, where the packed base is the whole bandwidth
# story on decode.

def _qmm_kernel(x_ref, wd_ref, ws_ref, out_ref, *, bits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += (x_ref[...].astype(jnp.float32)
                     @ dequant_block(wd_ref[...], ws_ref[...], bits))


def _qmm_call(x, wd, ws, *, bits, bm, bn, bk, interpret):
    m = x.shape[0]
    kdim, n, bwd_, bws = _quant_dims(m, wd, ws, bits, bm, bn, bk)
    nm, nn, nk = m // bm, n // bn, kdim // bk
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec(bwd_, lambda i, j, k: (k, j)),
            (pl.BlockSpec(bws, lambda i, j, k: (0, j)) if bits == 8
             else pl.BlockSpec(bws, lambda i, j, k: (k, j))),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, wd, ws)


def _qmm_dx_kernel(g_ref, wd_ref, ws_ref, dx_ref, *, bits):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init_dx():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    dx_ref[...] += (g_ref[...].astype(jnp.float32)
                    @ dequant_block(wd_ref[...], ws_ref[...], bits).T)


def _qmm_dx_call(g, wd, ws, *, bits, bm, bn, bk, interpret):
    m, n = g.shape
    kdim = wd.shape[0] * (2 if bits == 4 else 1)
    gsize = 0 if bits == 8 else kdim // ws.shape[0]
    bwd_, bws = _quant_w_shapes(bits, gsize, bk, bn)
    nm, nkb, nt = m // bm, kdim // bk, n // bn
    return pl.pallas_call(
        functools.partial(_qmm_dx_kernel, bits=bits),
        grid=(nm, nkb, nt),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, t: (i, t)),
            pl.BlockSpec(bwd_, lambda i, j, t: (j, t)),
            (pl.BlockSpec(bws, lambda i, j, t: (0, t)) if bits == 8
             else pl.BlockSpec(bws, lambda i, j, t: (j, t))),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), jnp.float32),
        interpret=interpret,
    )(g, wd, ws)


@functools.lru_cache(maxsize=64)
def _qmm_op(bits, bm, bn, bk, interpret):
    kw = dict(bits=bits, bm=bm, bn=bn, bk=bk, interpret=interpret)

    @jax.custom_vjp
    def op(x, wd, ws):
        return _qmm_call(x, wd, ws, **kw).astype(x.dtype)

    def fwd(x, wd, ws):
        return _qmm_call(x, wd, ws, **kw).astype(x.dtype), (x, wd, ws)

    def bwd(res, g):
        x, wd, ws = res
        dx = _qmm_dx_call(g, wd, ws, **kw)
        return dx.astype(x.dtype), _float0(wd), jnp.zeros_like(ws)

    op.defvjp(fwd, bwd)
    return op


def quant_matmul_vjp(x, wd, ws, *, bits, bm=256, bn=256, bk=512,
                     interpret=False):
    """Differentiable base-only packed GEMM (frozen base: dx only; the packed
    data/scales get float0/zero cotangents).  Pre-padded operands, as with
    :func:`lora_matmul_quant_vjp`."""
    return _qmm_op(int(bits), bm, bn, bk, bool(interpret))(x, wd, ws)

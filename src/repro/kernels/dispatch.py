"""Kernel dispatch: route LoRA-adapted projections to the right kernel tier.

This is the bridge between the model stack and ``repro/kernels``: every
``linear`` in the models delegates here, and this module decides — per
backend and per the model config's ``use_pallas`` flag — which implementation
serves the projection:

  reference   pure-jnp XLA ops (always available, differentiable natively) —
              the default, and the only tier when ``use_pallas`` is off
  interpret   the Pallas kernels under the Pallas interpreter (numerically
              the exact kernel path, but Python-speed — CPU/GPU debugging
              and the parity tests)
  pallas      compiled Mosaic kernels on a real TPU (the production hot path)

Selection, in order:
  1. ``use_pallas=False`` (the config default)        -> reference
  2. ``force_mode(...)`` / ``REPRO_KERNEL_MODE`` env  -> that tier
  3. backend is TPU                                   -> pallas
  4. ``REPRO_KERNEL_INTERPRET`` env is truthy         -> interpret
  5. otherwise                                        -> reference
     (interpret-mode Pallas is emulation — far too slow to be a silent
     CPU default for training loops)

The fused tiers run :func:`repro.kernels.lora_matmul.lora_matmul_vjp`, a
``jax.custom_vjp`` whose backward pass is also fused Pallas kernels, so jitted
training (``core/federated.py`` round steps) hits the fused path in both the
forward and backward directions.  Non-block-divisible shapes are zero-padded
up to block multiples here (padding/slicing is plain jnp, so autodiff routes
cotangents through it for free) and the rank dim is padded to the fp32
sublane multiple.
"""
from __future__ import annotations

import contextlib
import contextvars
import os

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedLinear, dequantize
from repro.kernels.bgmv import (bgmv_gemv, bgmv_gemv_quant, bgmv_matmul,
                                bgmv_matmul_quant)
from repro.kernels.lora_matmul import (lora_matmul_quant_vjp, lora_matmul_vjp,
                                       quant_matmul_vjp)
from repro.kernels import tiling

MODES = ("reference", "interpret", "pallas")

# MXU-aligned kernel block defaults (see lora_matmul.py); tile alignment
# (sublane/lane, rounding, zero-padding) is shared with the BGMV tier via
# kernels/tiling.py
BM, BN, BK = 256, 256, 512

# contextvars so concurrent traces (e.g. an eval thread tracing a reference
# model while a trainer thread traces a fused one) can't cross-contaminate
_use_pallas = contextvars.ContextVar("repro_use_pallas", default=False)
_forced = contextvars.ContextVar("repro_forced_mode", default=None)

# trace-time instrumentation: how many projections lowered to each tier
# (tests assert the model forward provably routes through the fused path).
# Deliberately a plain process-global: it counts trace-time lowerings for
# single-threaded tests/debugging only — cached jit calls don't re-count,
# and concurrent traces share it.  Routing correctness itself is isolated
# via the contextvars above.
stats = {"fused": 0, "reference": 0, "batched": 0, "bgmv": 0, "paged": 0,
         "quant": 0}


def reset_stats() -> None:
    for k in stats:
        stats[k] = 0


def force_mode(mode) -> None:
    """Pin the fused tier (``None`` restores backend-based selection).  Only
    consulted when ``use_pallas`` is active — a forced tier never drags a
    ``use_pallas=False`` model off the reference path."""
    if mode is not None and mode not in MODES:
        raise ValueError(f"unknown kernel mode '{mode}'; options {MODES}")
    _forced.set(mode)


def resolve_mode() -> str:
    if not _use_pallas.get():
        return "reference"
    forced = _forced.get() or os.environ.get("REPRO_KERNEL_MODE")
    if forced:
        if forced not in MODES:
            raise ValueError(
                f"REPRO_KERNEL_MODE='{forced}' invalid; options {MODES}")
        return forced
    if jax.default_backend() == "tpu":
        return "pallas"
    env = os.environ.get("REPRO_KERNEL_INTERPRET", "")
    if env.lower() in ("1", "true", "yes", "on"):
        return "interpret"
    return "reference"


@contextlib.contextmanager
def scope(use_pallas: bool):
    """Trace-time context set by the model API: every ``linear`` underneath
    dispatches per the active model's ``cfg.use_pallas``."""
    token = _use_pallas.set(bool(use_pallas))
    try:
        yield
    finally:
        _use_pallas.reset(token)


# ------------------------------------------------------------------ padding

def fused_lora_apply(x2, w, a, b, gamma, *, interpret: bool):
    """Run the fused custom-VJP kernel on arbitrary (m, k, n, r): pick
    aligned block sizes, zero-pad every dim to a block multiple, slice the
    result back.  Zero rows/cols contribute nothing to any of the GEMMs, so
    padding is exact (fwd and bwd)."""
    m, kdim = x2.shape
    n = w.shape[1]
    r = a.shape[0]
    if 0 in (m, kdim, n, r):
        # nothing to fuse on empty operands; the reference expression gives
        # the correctly-shaped (possibly empty) result on every tier
        return x2 @ w + gamma * ((x2 @ a.T) @ b.T)
    bm = tiling.block(m, BM, tiling.SUBLANE)
    bn = tiling.block(n, BN, tiling.LANE)
    bk = tiling.block(kdim, BK, tiling.LANE)
    mp = tiling.round_up(m, bm)
    kp, np_ = tiling.round_up(kdim, bk), tiling.round_up(n, bn)
    rp = tiling.round_up(r, tiling.SUBLANE)
    y = lora_matmul_vjp(tiling.pad_last2(x2, mp, kp),
                        tiling.pad_last2(w, kp, np_),
                        tiling.pad_last2(a, rp, kp),
                        tiling.pad_last2(b, np_, rp), gamma,
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    if mp != m or np_ != n:
        y = y[:m, :n]
    return y


def _pad_quant(wq: QuantizedLinear, kp: int, np_: int):
    """Zero-pad a packed base leaf to the kernel's padded (kp, np_) logical
    tile: data rows pad to kp (int8) / kp/2 (int4 nibble pairs), scale rows
    to 1 / kp/G.  Zero data dequantizes to zero regardless of scale, so the
    padding stays exact."""
    if wq.bits == 8:
        return (tiling.pad_last2(wq.data, kp, np_),
                tiling.pad_last2(wq.scales, 1, np_))
    return (tiling.pad_last2(wq.data, kp // 2, np_),
            tiling.pad_last2(wq.scales, kp // wq.group_size, np_))


def fused_lora_apply_quant(x2, wq, a, b, gamma, *, interpret: bool):
    """Packed-base twin of :func:`fused_lora_apply` — same block selection
    and padding, but the W operand ships as (packed data, scales) and the
    kernel dequantizes per-tile in VMEM.  Group sizes are powers of two
    <= the 128 lane tile (core/quant.py), so every k-block is group-aligned
    by construction."""
    m, kdim = x2.shape
    n = wq.shape[-1]
    r = a.shape[0]
    if 0 in (m, kdim, n, r):
        w = dequantize(wq)
        return x2 @ w + gamma * ((x2 @ a.T) @ b.T)
    bm = tiling.block(m, BM, tiling.SUBLANE)
    bn = tiling.block(n, BN, tiling.LANE)
    bk = tiling.block(kdim, BK, tiling.LANE)
    mp = tiling.round_up(m, bm)
    kp, np_ = tiling.round_up(kdim, bk), tiling.round_up(n, bn)
    rp = tiling.round_up(r, tiling.SUBLANE)
    wd, ws = _pad_quant(wq, kp, np_)
    y = lora_matmul_quant_vjp(tiling.pad_last2(x2, mp, kp), wd, ws,
                              tiling.pad_last2(a, rp, kp),
                              tiling.pad_last2(b, np_, rp), gamma,
                              bits=wq.bits, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    if mp != m or np_ != n:
        y = y[:m, :n]
    return y


def quant_base_apply(x2, wq, *, interpret: bool):
    """Base-only packed GEMM (no adapter): pad, run the fused dequant+GEMM
    kernel, slice — the MLP / un-adapted projection path."""
    m, kdim = x2.shape
    n = wq.shape[-1]
    if 0 in (m, kdim, n):
        return x2 @ dequantize(wq)
    bm = tiling.block(m, BM, tiling.SUBLANE)
    bn = tiling.block(n, BN, tiling.LANE)
    bk = tiling.block(kdim, BK, tiling.LANE)
    mp = tiling.round_up(m, bm)
    kp, np_ = tiling.round_up(kdim, bk), tiling.round_up(n, bn)
    wd, ws = _pad_quant(wq, kp, np_)
    y = quant_matmul_vjp(tiling.pad_last2(x2, mp, kp), wd, ws, bits=wq.bits,
                         bm=bm, bn=bn, bk=bk, interpret=interpret)
    if mp != m or np_ != n:
        y = y[:m, :n]
    return y


# ----------------------------------------------------------------- dispatch

def lora_linear_batched(x, w, lora, gamma: float = 1.0):
    """Per-request adapters (multi-tenant serving): each batch row of ``x``
    pairs with its own adapter out of an ``AdapterBank``.

    Two leaf layouts arrive here, both with 3-D adapter leaves:

      materialized   ``a`` (B, r, d_in), ``b`` (B, d_out, r) — row i pairs
                     with adapter i (``AdapterBank.gather`` already copied
                     the per-request tree)
      lazy bank      ``a`` (K, r, d_in), ``b`` (K, d_out, r) plus an
                     ``ids`` (B,) entry (``AdapterBank.requests``) — row i
                     is served with tenant ``ids[i]``; the gather happens
                     HERE, per projection, instead of materializing (B, ...)
                     copies of the bank upstream

    Reference tier: one shared base GEMM + a pair of batched rank-r einsums
    (XLA grouped matmuls) on the (possibly just-gathered) per-request leaves
    — each output row bit-identical to the single-adapter path run on that
    row alone.  Fused tiers run the BGMV kernel (`kernels/bgmv.py`): the
    base GEMM and both rank-r GEMMs fuse into one pass over ``x``, and the
    lazy-bank gather moves into the kernel's ids-indexed BlockSpecs, so no
    per-request adapter copy ever exists.  Decode's (B, 1, d_in) shape takes
    the GEMV-form kernel (no s dim, no sublane padding of request rows).
    """
    a, b = lora["a"], lora["b"]
    ids = lora.get("ids")
    nreq = (a if ids is None else ids).shape[0]
    if x.ndim != 3 or nreq != x.shape[0]:
        raise ValueError(
            f"batched adapters need x (B, s, d_in) with B requests; "
            f"got x {x.shape}, a {a.shape}, ids "
            f"{None if ids is None else ids.shape}")
    stats["batched"] += 1
    mode = resolve_mode()
    quantized = isinstance(w, QuantizedLinear)
    if mode == "reference" or 0 in (*x.shape, w.shape[-1], a.shape[-2]):
        if quantized:   # reference tier: dequantize up front (parity policy)
            w = dequantize(w)
        ar = a if ids is None else jnp.take(a, ids, axis=0)  # lint: disable=R5 -- ids traced here; concrete ids range-checked at the host boundary (check_adapter_ids)
        br = b if ids is None else jnp.take(b, ids, axis=0)  # lint: disable=R5 -- same host-boundary check as the gather above
        y = x @ w
        xa = jnp.einsum("bsk,brk->bsr", x, ar)
        return y + gamma * jnp.einsum("bsr,bor->bso", xa, br)
    if isinstance(gamma, jax.core.Tracer):
        raise TypeError(
            "the fused kernel tier needs a static (python float) gamma — "
            "banked adapters arrive scale-folded (gamma == 1), so a traced "
            "gamma here means an unprepared AdapterSet reached serving.")
    stats["bgmv"] += 1
    if float(gamma) != 1.0:
        b = b * jnp.asarray(gamma, b.dtype)
    out_dtype = jnp.result_type(x.dtype, w.dtype, a.dtype, b.dtype)
    interpret = mode == "interpret"
    ids_arr = (jnp.arange(x.shape[0], dtype=jnp.int32) if ids is None
               else ids)
    xc = x.astype(out_dtype)
    if quantized:
        stats["quant"] += 1
        if x.shape[1] == 1:
            y = bgmv_gemv_quant(xc[:, 0], w.data, w.scales, a, b, ids_arr,
                                bits=w.bits, interpret=interpret)
            return y[:, None, :].astype(out_dtype)
        return bgmv_matmul_quant(xc, w.data, w.scales, a, b, ids_arr,
                                 bits=w.bits,
                                 interpret=interpret).astype(out_dtype)
    if x.shape[1] == 1:
        y = bgmv_gemv(xc[:, 0], w, a, b, ids_arr, interpret=interpret)
        return y[:, None, :].astype(out_dtype)
    return bgmv_matmul(xc, w, a, b, ids_arr,
                       interpret=interpret).astype(out_dtype)


def lora_linear(x, w, lora=None, gamma: float = 0.0):
    """y = x W (+ gamma * (x A^T) B^T) through the active kernel tier.

    ``lora`` is ``{"a": (r, d_in), "b": (d_out, r)}`` or None; ``x`` may have
    any number of leading dims.  Base-only projections (``lora=None``) are a
    single XLA GEMM on every tier.  Leaves with one extra leading dim
    (``a`` 3-D) are per-request adapters and take the batched path.
    """
    if lora is not None and lora["a"].ndim == 3:
        return lora_linear_batched(x, w, lora, gamma)
    mode = resolve_mode()
    quantized = isinstance(w, QuantizedLinear)
    empty = (0 in (*x.shape, w.shape[-1])
             or (lora is not None and lora["a"].shape[0] == 0))
    if (mode == "reference" or empty
            or (lora is None and not quantized)):
        # reference tier / empty operands take the jnp expression on every
        # tier (nothing to fuse; kernel blocks would be 0-sized).  Packed
        # bases dequantize to fp UP FRONT here — this is the parity-bounds
        # ground truth the fused tiers are pinned against.
        stats["reference"] += 1
        wf = dequantize(w) if quantized else w
        y = x @ wf
        if lora is not None:
            y = y + gamma * ((x @ lora["a"].T) @ lora["b"].T)
        return y
    lead = x.shape[:-1]
    if lora is None:
        # quantized base-only projection on a fused tier: dequant-in-VMEM
        # GEMM kernel (the MLP / un-adapted projection bandwidth path)
        stats["quant"] += 1
        out_dtype = jnp.result_type(x.dtype, w.dtype)
        x2 = x.reshape(-1, x.shape[-1]).astype(out_dtype)
        y = quant_base_apply(x2, w, interpret=(mode == "interpret"))
        return y.reshape(*lead, w.shape[-1])
    if isinstance(gamma, jax.core.Tracer):
        raise TypeError(
            "the fused kernel tier needs a static (python float) gamma — it "
            "is baked into the Pallas kernels at trace time.  Pass gamma as "
            "a static argument (jit static_argnames) or via closure, as "
            "core/federated.py does.")
    stats["fused"] += 1
    # match the reference tier's output dtype under mixed precision (e.g.
    # bf16 activations x fp32 weights — or fp32 adapters on a bf16 base —
    # promote to fp32 in the jnp expression): the kernel computes in fp32
    # regardless and returns its x operand's dtype
    out_dtype = jnp.result_type(x.dtype, w.dtype, lora["a"].dtype,
                                lora["b"].dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(out_dtype)
    if quantized:
        stats["quant"] += 1
        y = fused_lora_apply_quant(x2, w, lora["a"], lora["b"], float(gamma),
                                   interpret=(mode == "interpret"))
    else:
        y = fused_lora_apply(x2, w, lora["a"], lora["b"], float(gamma),
                             interpret=(mode == "interpret"))
    return y.reshape(*lead, w.shape[-1])

"""Kernel dispatch: route LoRA-adapted projections to the right kernel tier.

This is the bridge between the model stack and ``repro/kernels``: every
``linear`` in the models delegates here, and this module decides — per
backend and per the model config's ``use_pallas`` flag — which implementation
serves the projection:

  reference   pure-jnp XLA ops (always available, differentiable natively) —
              the default, and the only tier when ``use_pallas`` is off
  interpret   the Pallas kernels under the Pallas interpreter (numerically
              the exact kernel path, but Python-speed — CPU/GPU debugging
              and the parity tests)
  pallas      compiled Mosaic kernels on a real TPU (the production hot path)

Selection, in order:
  1. ``use_pallas=False`` (the config default)        -> reference
  2. ``force_mode(...)`` / ``REPRO_KERNEL_MODE`` env  -> that tier
  3. backend is TPU                                   -> pallas
  4. ``REPRO_KERNEL_INTERPRET`` env is truthy         -> interpret
  5. otherwise                                        -> reference
     (interpret-mode Pallas is emulation — far too slow to be a silent
     CPU default for training loops)

The fused tiers run :func:`repro.kernels.lora_matmul.lora_matmul_vjp`, a
``jax.custom_vjp`` whose backward pass is also fused Pallas kernels, so jitted
training (``core/federated.py`` round steps) hits the fused path in both the
forward and backward directions.  Non-block-divisible shapes are zero-padded
up to block multiples here (padding/slicing is plain jnp, so autodiff routes
cotangents through it for free) and the rank dim is padded to the fp32
sublane multiple.
"""
from __future__ import annotations

import contextlib
import contextvars
import os

import jax
import jax.numpy as jnp

from repro.kernels.lora_matmul import lora_matmul_vjp

MODES = ("reference", "interpret", "pallas")

# MXU-aligned kernel block defaults (see lora_matmul.py) and fp32 tiling
BM, BN, BK = 256, 256, 512
_SUBLANE, _LANE = 8, 128

# contextvars so concurrent traces (e.g. an eval thread tracing a reference
# model while a trainer thread traces a fused one) can't cross-contaminate
_use_pallas = contextvars.ContextVar("repro_use_pallas", default=False)
_forced = contextvars.ContextVar("repro_forced_mode", default=None)

# trace-time instrumentation: how many projections lowered to each tier
# (tests assert the model forward provably routes through the fused path).
# Deliberately a plain process-global: it counts trace-time lowerings for
# single-threaded tests/debugging only — cached jit calls don't re-count,
# and concurrent traces share it.  Routing correctness itself is isolated
# via the contextvars above.
stats = {"fused": 0, "reference": 0, "batched": 0}


def reset_stats() -> None:
    stats["fused"] = 0
    stats["reference"] = 0
    stats["batched"] = 0


def force_mode(mode) -> None:
    """Pin the fused tier (``None`` restores backend-based selection).  Only
    consulted when ``use_pallas`` is active — a forced tier never drags a
    ``use_pallas=False`` model off the reference path."""
    if mode is not None and mode not in MODES:
        raise ValueError(f"unknown kernel mode '{mode}'; options {MODES}")
    _forced.set(mode)


def resolve_mode() -> str:
    if not _use_pallas.get():
        return "reference"
    forced = _forced.get() or os.environ.get("REPRO_KERNEL_MODE")
    if forced:
        if forced not in MODES:
            raise ValueError(
                f"REPRO_KERNEL_MODE='{forced}' invalid; options {MODES}")
        return forced
    if jax.default_backend() == "tpu":
        return "pallas"
    env = os.environ.get("REPRO_KERNEL_INTERPRET", "")
    if env.lower() in ("1", "true", "yes", "on"):
        return "interpret"
    return "reference"


@contextlib.contextmanager
def scope(use_pallas: bool):
    """Trace-time context set by the model API: every ``linear`` underneath
    dispatches per the active model's ``cfg.use_pallas``."""
    token = _use_pallas.set(bool(use_pallas))
    try:
        yield
    finally:
        _use_pallas.reset(token)


# ------------------------------------------------------------------ padding

def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _block(dim: int, default: int, align: int) -> int:
    return min(default, _round_up(dim, align))


def _pad2(arr, rows: int, cols: int):
    pr, pc = rows - arr.shape[0], cols - arr.shape[1]
    if pr or pc:
        arr = jnp.pad(arr, ((0, pr), (0, pc)))
    return arr


def fused_lora_apply(x2, w, a, b, gamma, *, interpret: bool):
    """Run the fused custom-VJP kernel on arbitrary (m, k, n, r): pick
    aligned block sizes, zero-pad every dim to a block multiple, slice the
    result back.  Zero rows/cols contribute nothing to any of the GEMMs, so
    padding is exact (fwd and bwd)."""
    m, kdim = x2.shape
    n = w.shape[1]
    r = a.shape[0]
    if 0 in (m, kdim, n, r):
        # nothing to fuse on empty operands; the reference expression gives
        # the correctly-shaped (possibly empty) result on every tier
        return x2 @ w + gamma * ((x2 @ a.T) @ b.T)
    bm = _block(m, BM, _SUBLANE)
    bn = _block(n, BN, _LANE)
    bk = _block(kdim, BK, _LANE)
    mp, kp, np_ = _round_up(m, bm), _round_up(kdim, bk), _round_up(n, bn)
    rp = _round_up(r, _SUBLANE)
    y = lora_matmul_vjp(_pad2(x2, mp, kp), _pad2(w, kp, np_),
                        _pad2(a, rp, kp), _pad2(b, np_, rp), gamma,
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    if mp != m or np_ != n:
        y = y[:m, :n]
    return y


# ----------------------------------------------------------------- dispatch

def lora_linear_batched(x, w, lora, gamma: float = 1.0):
    """Per-request adapters (multi-tenant serving): each batch row of ``x``
    pairs with its own adapter gathered from an ``AdapterBank``.

    ``x`` (B, s, d_in); ``lora`` leaves carry the leading request dim —
    ``a`` (B, r, d_in), ``b`` (B, d_out, r).  The base projection stays one
    shared GEMM; the delta is a pair of batched GEMMs (BGMV-style — the
    rank-r contraction per request), which XLA lowers as grouped matmuls.
    Each output row is bit-identical to the single-adapter path run on that
    row alone: the contractions reduce over the same axes in the same order.
    """
    a, b = lora["a"], lora["b"]
    if x.ndim != 3 or a.shape[0] != x.shape[0]:
        raise ValueError(
            f"batched adapters need x (B, s, d_in) with B == a.shape[0]; "
            f"got x {x.shape}, a {a.shape}")
    stats["batched"] += 1
    y = x @ w
    xa = jnp.einsum("bsk,brk->bsr", x, a)
    return y + gamma * jnp.einsum("bsr,bor->bso", xa, b)


def lora_linear(x, w, lora=None, gamma: float = 0.0):
    """y = x W (+ gamma * (x A^T) B^T) through the active kernel tier.

    ``lora`` is ``{"a": (r, d_in), "b": (d_out, r)}`` or None; ``x`` may have
    any number of leading dims.  Base-only projections (``lora=None``) are a
    single XLA GEMM on every tier.  Leaves with one extra leading dim
    (``a`` 3-D) are per-request adapters and take the batched path.
    """
    if lora is not None and lora["a"].ndim == 3:
        return lora_linear_batched(x, w, lora, gamma)
    mode = resolve_mode()
    if (lora is None or mode == "reference"
            or 0 in (*x.shape, w.shape[1], lora["a"].shape[0])):
        # empty operands take the reference expression on every tier —
        # there is nothing to fuse and the kernel blocks would be 0-sized
        stats["reference"] += 1
        y = x @ w
        if lora is not None:
            y = y + gamma * ((x @ lora["a"].T) @ lora["b"].T)
        return y
    if isinstance(gamma, jax.core.Tracer):
        raise TypeError(
            "the fused kernel tier needs a static (python float) gamma — it "
            "is baked into the Pallas kernels at trace time.  Pass gamma as "
            "a static argument (jit static_argnames) or via closure, as "
            "core/federated.py does.")
    stats["fused"] += 1
    lead = x.shape[:-1]
    # match the reference tier's output dtype under mixed precision (e.g.
    # bf16 activations x fp32 weights — or fp32 adapters on a bf16 base —
    # promote to fp32 in the jnp expression): the kernel computes in fp32
    # regardless and returns its x operand's dtype
    out_dtype = jnp.result_type(x.dtype, w.dtype, lora["a"].dtype,
                                lora["b"].dtype)
    x2 = x.reshape(-1, x.shape[-1]).astype(out_dtype)
    y = fused_lora_apply(x2, w, lora["a"], lora["b"], float(gamma),
                         interpret=(mode == "interpret"))
    return y.reshape(*lead, w.shape[1])

"""Fused multi-adapter BGMV Pallas-TPU kernels for banked LoRA serving.

Multi-tenant serving applies a DIFFERENT adapter to every request row: row i
of ``x`` is served with tenant ``ids[i]``'s (A, B) pair out of a stacked
:class:`~repro.core.lora.AdapterBank`.  The pre-kernel implementation paid
for that twice — a materialized per-request gather (copying every adapter
leaf to a (B, ...) tree each decode step) followed by two unfused batched
einsums on top of the shared base GEMM.

These kernels fuse all of it into one pass over ``x``:

  grid (B, nn, nk), k innermost.  For request row i (block row of x):
    - the A/B BlockSpecs index the STACKED bank leaves by ``ids[i]`` via
      scalar prefetch (``pltpu.PrefetchScalarGridSpec``) — the per-request
      gather happens in the kernel's DMA schedule, no (B, r, k) copy of the
      bank ever materializes in HBM
    - during the n==0 sweep, p[i] += x[i,k] @ A[ids[i],k]^T  (rank-r
      intermediate lives in VMEM scratch)
    - every (n, k) step accumulates out[i,n] += x[i,k] @ W[k,n] (the shared
      base GEMM, fused rather than re-read)
    - at k == nk-1, out[i,n] += p[i] @ B[ids[i],n]^T

Rank masking is free by construction: bank registration stores each tenant's
adapter zero-padded to r_max (``AdapterBank.from_sets``), and zero rank
rows/columns contribute nothing to either rank-r GEMM — mixed-rank banks run
the same kernel at the same cost as uniform-rank ones, no mask multiplies.

Two entry points share the structure:

  ``bgmv_matmul``  x (B, s, k) — prefill / full-sequence forward, one
                   (s, k) block row per request
  ``bgmv_gemv``    x (B, k)    — single-token decode, the m=1 GEMV shape
                   served directly instead of round-tripping through the
                   2-D sublane-padding path

The bank is gamma-free: registration folds every tenant's scaling factor
into its B (``AdapterSet.fold_gamma``), so these kernels have no gamma
parameter — the scale is structurally 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lora_matmul import _quant_w_shapes, dequant_block
from repro.kernels.tiling import LANE, SUBLANE, block, pad_last2, round_up

# kernel block defaults (n, k dims); s and r stay whole in VMEM — serving
# shapes keep both small (s = prompt length or 1, r <= 512 per the paper)
BN, BK = 256, 512


# ------------------------------------------------------------------ kernels

def _bgmv_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref, out_ref, p_ref, *, nk):
    """One request row per i-step; A/B blocks arrive pre-gathered by the
    ids-indexed BlockSpecs.  Mirrors lora_matmul's accumulation schedule."""
    del ids_ref  # consumed by the index_maps, not the body
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[0].astype(jnp.float32)           # (s, bk)

    @pl.when(n == 0)
    def _acc_p():   # p += x[i,k] @ A[ids[i],k]^T       (A block (1, r, bk))
        p_ref[...] += xb @ a_ref[0].astype(jnp.float32).T

    out_ref[0] += xb @ w_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _apply_lora():   # out += p @ B[ids[i],n]^T     (B block (1, bn, r))
        out_ref[0] += p_ref[...] @ b_ref[0].astype(jnp.float32).T


def _bgmv_call(x, w, a, b, ids, *, bn, bk, interpret):
    """x (B, s, k) padded, w (k, n) padded, a (K, r, k), b (K, n, r),
    ids (B,) int32 -> (B, s, n) fp32."""
    bsz, s, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    nn, nk = n // bn, kdim // bk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nn, nk),
        in_specs=[
            pl.BlockSpec((1, s, bk), lambda i, j, k, ids: (i, 0, k)),    # x
            pl.BlockSpec((bk, bn), lambda i, j, k, ids: (k, j)),         # w
            pl.BlockSpec((1, r, bk), lambda i, j, k, ids: (ids[i], 0, k)),
            pl.BlockSpec((1, bn, r), lambda i, j, k, ids: (ids[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, bn), lambda i, j, k, ids: (i, 0, j)),
        scratch_shapes=[pltpu.VMEM((s, r), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bgmv_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, n), jnp.float32),
        interpret=interpret,
    )(ids, x, w, a, b)


def _bgmv_gemv_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref, out_ref, p_ref, *,
                      nk):
    """GEMV shape: one (1, k) token row per request, no s dim anywhere."""
    del ids_ref
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)         # (1, bk)

    @pl.when(n == 0)
    def _acc_p():
        p_ref[...] += xb @ a_ref[0].astype(jnp.float32).T

    out_ref[...] += xb @ w_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _apply_lora():
        out_ref[...] += p_ref[...] @ b_ref[0].astype(jnp.float32).T


def _bgmv_gemv_call(x, w, a, b, ids, *, bn, bk, interpret):
    """x (B, k) padded -> (B, n) fp32; one grid row per request."""
    bsz, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    nn, nk = n // bn, kdim // bk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, k, ids: (i, k)),          # x
            pl.BlockSpec((bk, bn), lambda i, j, k, ids: (k, j)),         # w
            pl.BlockSpec((1, r, bk), lambda i, j, k, ids: (ids[i], 0, k)),
            pl.BlockSpec((1, bn, r), lambda i, j, k, ids: (ids[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, k, ids: (i, j)),
        scratch_shapes=[pltpu.VMEM((1, r), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bgmv_gemv_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=interpret,
    )(ids, x, w, a, b)


# ------------------------------------------------------------------ wrappers

def _pad_operands(w, a, b, kdim, n, r):
    bn = block(n, BN, LANE)
    bk = block(kdim, BK, LANE)
    kp, np_ = round_up(kdim, bk), round_up(n, bn)
    rp = round_up(r, SUBLANE)
    w = pad_last2(w, kp, np_)
    a = pad_last2(a, rp, kp)
    b = pad_last2(b, np_, rp)
    return w, a, b, bn, bk, kp, np_


def bgmv_matmul(x, w, a, b, ids, *, interpret: bool = False):
    """y[i] = x[i] @ W + (x[i] @ A[ids[i]]^T) @ B[ids[i]]^T, fused.

    x (B, s, k), w (k, n), a (K, r, k), b (K, n, r), ids (B,) int.
    Returns (B, s, n) in fp32 (the dispatcher casts per its promotion rule).
    Zero-pads every dim to block multiples — zero rows/cols are exact."""
    bsz, s, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    w, a, b, bn, bk, kp, np_ = _pad_operands(w, a, b, kdim, n, r)
    sp = round_up(s, SUBLANE)
    if sp != s or kp != kdim:
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, kp - kdim)))
    ids = jnp.asarray(ids, jnp.int32)
    y = _bgmv_call(x, w, a, b, ids, bn=bn, bk=bk, interpret=interpret)
    if sp != s or np_ != n:
        y = y[:, :s, :n]
    return y


def bgmv_gemv(x, w, a, b, ids, *, interpret: bool = False):
    """Single-token variant: x (B, k) -> (B, n) fp32, the decode GEMV shape
    served without an s dim or sublane padding of the request rows."""
    bsz, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    w, a, b, bn, bk, kp, np_ = _pad_operands(w, a, b, kdim, n, r)
    if kp != kdim:
        x = jnp.pad(x, ((0, 0), (0, kp - kdim)))
    ids = jnp.asarray(ids, jnp.int32)
    y = _bgmv_gemv_call(x, w, a, b, ids, bn=bn, bk=bk, interpret=interpret)
    if np_ != n:
        y = y[:, :n]
    return y


# ------------------------------------------------------- quantized variants
#
# Banked serving over a PACKED frozen base (core/quant.py): the shared base
# GEMM dequantizes its (bk, bn) tile in VMEM (lora_matmul.dequant_block)
# while the per-request A/B gather stays exactly as above — adapters are fp
# by the LoRA contract, only the base is packed.

def _bgmv_kernel_q(ids_ref, x_ref, wd_ref, ws_ref, a_ref, b_ref, out_ref,
                   p_ref, *, nk, bits):
    del ids_ref
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[0].astype(jnp.float32)

    @pl.when(n == 0)
    def _acc_p():
        p_ref[...] += xb @ a_ref[0].astype(jnp.float32).T

    out_ref[0] += xb @ dequant_block(wd_ref[...], ws_ref[...], bits)

    @pl.when(k == nk - 1)
    def _apply_lora():
        out_ref[0] += p_ref[...] @ b_ref[0].astype(jnp.float32).T


def _bgmv_gemv_kernel_q(ids_ref, x_ref, wd_ref, ws_ref, a_ref, b_ref,
                        out_ref, p_ref, *, nk, bits):
    del ids_ref
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((n == 0) & (k == 0))
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when(k == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)

    @pl.when(n == 0)
    def _acc_p():
        p_ref[...] += xb @ a_ref[0].astype(jnp.float32).T

    out_ref[...] += xb @ dequant_block(wd_ref[...], ws_ref[...], bits)

    @pl.when(k == nk - 1)
    def _apply_lora():
        out_ref[...] += p_ref[...] @ b_ref[0].astype(jnp.float32).T


def _pad_quant_operands(wd, ws, a, b, bits, kdim, n, r):
    """Packed-base twin of :func:`_pad_operands`: data rows pad to kp (int8)
    or kp/2 (int4 nibble pairs), scale rows to 1 / kp/G; zero scales make
    the padding dequantize to exact zeros."""
    bn = block(n, BN, LANE)
    bk = block(kdim, BK, LANE)
    kp, np_ = round_up(kdim, bk), round_up(n, bn)
    rp = round_up(r, SUBLANE)
    if bits == 8:
        wd = pad_last2(wd, kp, np_)
        ws = pad_last2(ws, 1, np_)
    else:
        gsize = (wd.shape[-2] * 2) // ws.shape[-2]
        wd = pad_last2(wd, kp // 2, np_)
        ws = pad_last2(ws, kp // gsize, np_)
    a = pad_last2(a, rp, kp)
    b = pad_last2(b, np_, rp)
    return wd, ws, a, b, bn, bk, kp, np_


def bgmv_matmul_quant(x, wd, ws, a, b, ids, *, bits, interpret: bool = False):
    """:func:`bgmv_matmul` over a packed base: x (B, s, k), wd/ws per
    ``dequant_block``, a (K, r, k), b (K, n, r), ids (B,) -> (B, s, n)."""
    bsz, s, kdim = x.shape
    n = wd.shape[-1]
    r = a.shape[1]
    wd, ws, a, b, bn, bk, kp, np_ = _pad_quant_operands(
        wd, ws, a, b, bits, kdim, n, r)
    r = a.shape[1]
    sp = round_up(s, SUBLANE)
    if sp != s or kp != kdim:
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, kp - kdim)))
    ids = jnp.asarray(ids, jnp.int32)
    gsize = 0 if bits == 8 else kp // ws.shape[-2]
    bwd, bws = _quant_w_shapes(bits, gsize, bk, bn)
    nn, nk = np_ // bn, kp // bk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nn, nk),
        in_specs=[
            pl.BlockSpec((1, sp, bk), lambda i, j, k, ids: (i, 0, k)),
            pl.BlockSpec(bwd, lambda i, j, k, ids: (k, j)),
            (pl.BlockSpec(bws, lambda i, j, k, ids: (0, j)) if bits == 8
             else pl.BlockSpec(bws, lambda i, j, k, ids: (k, j))),
            pl.BlockSpec((1, r, bk), lambda i, j, k, ids: (ids[i], 0, k)),
            pl.BlockSpec((1, bn, r), lambda i, j, k, ids: (ids[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((1, sp, bn), lambda i, j, k, ids: (i, 0, j)),
        scratch_shapes=[pltpu.VMEM((sp, a.shape[1]), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_bgmv_kernel_q, nk=nk, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, sp, np_), jnp.float32),
        interpret=interpret,
    )(ids, x, wd, ws, a, b)
    if sp != s or np_ != n:
        y = y[:, :s, :n]
    return y


def bgmv_gemv_quant(x, wd, ws, a, b, ids, *, bits, interpret: bool = False):
    """Single-token packed-base variant: x (B, k) -> (B, n) fp32."""
    bsz, kdim = x.shape
    n = wd.shape[-1]
    r = a.shape[1]
    wd, ws, a, b, bn, bk, kp, np_ = _pad_quant_operands(
        wd, ws, a, b, bits, kdim, n, r)
    r = a.shape[1]
    if kp != kdim:
        x = jnp.pad(x, ((0, 0), (0, kp - kdim)))
    ids = jnp.asarray(ids, jnp.int32)
    gsize = 0 if bits == 8 else kp // ws.shape[-2]
    bwd, bws = _quant_w_shapes(bits, gsize, bk, bn)
    nn, nk = np_ // bn, kp // bk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda i, j, k, ids: (i, k)),
            pl.BlockSpec(bwd, lambda i, j, k, ids: (k, j)),
            (pl.BlockSpec(bws, lambda i, j, k, ids: (0, j)) if bits == 8
             else pl.BlockSpec(bws, lambda i, j, k, ids: (k, j))),
            pl.BlockSpec((1, r, bk), lambda i, j, k, ids: (ids[i], 0, k)),
            pl.BlockSpec((1, bn, r), lambda i, j, k, ids: (ids[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j, k, ids: (i, j)),
        scratch_shapes=[pltpu.VMEM((1, a.shape[1]), jnp.float32)],
    )
    y = pl.pallas_call(
        functools.partial(_bgmv_gemv_kernel_q, nk=nk, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, np_), jnp.float32),
        interpret=interpret,
    )(ids, x, wd, ws, a, b)
    if np_ != n:
        y = y[:, :n]
    return y


def bgmv_reference(x, w, a, b, ids):
    """Pure-jnp oracle: gather + batched einsum — operation-for-operation the
    pre-kernel materialized path, so the reference tier stays bit-identical
    to what shipped before the fused tier existed."""
    y = x @ w
    xa = jnp.einsum("bsk,brk->bsr", x, jnp.take(a, ids, axis=0))  # lint: disable=R5 -- oracle runs under trace; ids validated at the serve host boundary (check_adapter_ids)
    return y + jnp.einsum("bsr,bor->bso", xa, jnp.take(b, ids, axis=0))  # lint: disable=R5 -- same host-boundary check as the gather above

"""Pallas-TPU paged-attention decode kernel.

Continuous batching stores KV state in a SHARED block pool
(num_blocks, block_size, kh, hd) instead of per-request ring buffers; each
request's blocks are named by a row of the block table (B, blocks_per_req).
The reference tier materializes a request's view with an XLA gather
(``models/attention.py::paged_gather``) — an HBM copy of the whole working
set every decode step.  This kernel never materializes it: the K/V/pos
BlockSpecs index the POOL through the block table via scalar prefetch,

    grid (B, blocks_per_req), j innermost
    k_pool block (1, bs, kh, hd) at index (table[i, j], 0, 0, 0)

— the same ids-indexed DMA-schedule trick the BGMV kernels use for the
adapter bank, applied to cache blocks instead of adapter pages.  Softmax
runs as a flash-style running (m, l, acc) accumulation across a request's
blocks in VMEM scratch, so the per-step working set is one block, not the
virtual ring.

Numerics: the streaming accumulation is mathematically exact but not
bit-identical to the one-shot softmax of the gather path, so the engine
routes here only on the compiled ``pallas`` tier (``dispatch.resolve_mode``)
— interpret/reference-tier serving keeps the gather path, which is what the
scheduled-vs-fixed-batch token-identity guarantee is stated over.  Parity
with :func:`repro.kernels.ref.paged_attention_ref` is asserted to fp32
tolerance in tests/test_paged.py.

Validity masking needs no extra operand: the pos pool rides along as a
third table-indexed input, and an entry is attendable iff
``0 <= pos <= qpos`` (and within the sliding window) — exactly the ring
cache's mask formula.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(table_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                       out_ref, acc_ref, m_ref, l_ref, *, mb, window,
                       softcap):
    """One (request, block) cell per grid step; j innermost streams request
    i's blocks through VMEM while (acc, m, l) carry the running softmax."""
    del table_ref  # consumed by the index_maps, not the body
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kh, g, hd = acc_ref.shape[0], acc_ref.shape[1], acc_ref.shape[2]
    h = kh * g
    qp = qpos_ref[i]
    q = q_ref[...].astype(jnp.float32).reshape(kh, g, hd)      # (kh, g, hd)
    k = k_ref[0].astype(jnp.float32)                           # (bs, kh, hd)
    v = v_ref[0].astype(jnp.float32)
    pos = pos_ref[0]                                           # (bs,)

    scores = jnp.einsum("kgd,skd->kgs", q, k) * hd ** -0.5
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    valid = (pos >= 0) & (pos <= qp)
    if window is not None:
        valid &= qp - pos < window
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.where(scores <= NEG_INF / 2, 0.0,
                  jnp.exp(scores - m_new[..., None]))
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("kgs,skd->kgd", p, v))

    @pl.when(j == mb - 1)
    def _final():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[...] = out.reshape(1, h, hd).astype(out_ref.dtype)


def paged_attention(q, k_pool, v_pool, pos_pool, table, qpos, *, window=None,
                    softcap=None, interpret: bool = False):
    """One-token paged attention: q (B, h, hd), k_pool/v_pool
    (P, bs, kh, hd), pos_pool (P, bs) int32, table (B, mb) int32, qpos (B,)
    int32.  Returns (B, h, hd) in q.dtype.

    The pool blocks a request never owns are never touched: the grid visits
    (i, j) -> pool block table[i, j] only.  Production shapes keep hd a lane
    multiple and bs a sublane multiple; no padding is applied here."""
    b, h, hd = q.shape
    _, bs, kh, _ = k_pool.shape
    mb = table.shape[1]
    g = h // kh
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # (table, qpos)
        grid=(b, mb),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda i, j, table, qpos: (i, 0, 0)),
            pl.BlockSpec((1, bs, kh, hd),
                         lambda i, j, table, qpos: (table[i, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, kh, hd),
                         lambda i, j, table, qpos: (table[i, j], 0, 0, 0)),
            pl.BlockSpec((1, bs),
                         lambda i, j, table, qpos: (table[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i, j, table, qpos: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((kh, g, hd), jnp.float32),
                        pltpu.VMEM((kh, g), jnp.float32),
                        pltpu.VMEM((kh, g), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, mb=mb, window=window,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(table, jnp.int32), jnp.asarray(qpos, jnp.int32),
      q, k_pool, v_pool, pos_pool)

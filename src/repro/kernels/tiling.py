"""Shared tile geometry for the kernel tiers: fp32 sublane/lane multiples
and the zero-padding helpers every Pallas wrapper uses.  One home, so the
fused single-adapter tier (dispatch.py) and the banked BGMV tier (bgmv.py)
can never disagree about alignment."""
from __future__ import annotations

import jax.numpy as jnp

SUBLANE, LANE = 8, 128   # fp32 TPU tiling: (8, 128) min tile


def round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def block(dim: int, default: int, align: int) -> int:
    """Block size for ``dim``: the default, or the whole (aligned) dim when
    smaller — so small operands stay single-block instead of over-padding."""
    return min(default, round_up(dim, align))


def pad_last2(arr, rows: int, cols: int):
    """Zero-pad the LAST TWO dims up to (rows, cols); leading dims ride
    along untouched.  Zero rows/cols are exact for every GEMM here."""
    pr, pc = rows - arr.shape[-2], cols - arr.shape[-1]
    if pr or pc:
        arr = jnp.pad(arr, ((0, 0),) * (arr.ndim - 2) + ((0, pr), (0, pc)))
    return arr

"""Flash-attention Pallas-TPU kernel (forward): online-softmax over KV blocks
with causal and sliding-window masking.

Grid (b*h, nq, nk), kv innermost; running (acc, m, l) live in VMEM scratch so
the (s, t) score matrix never exists.  BlockSpec tiles are MXU-aligned
(bq x d and bk x d with d a multiple of 128 in the full configs).  This is the
TPU adaptation of the paper's attention hot spot; the pure-JAX blockwise path
in repro/models/attention.py mirrors it for autodiff/CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, bq, bk, nk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    sc = (q @ k.T) * scale                      # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    sc = jnp.where(mask, sc, NEG_INF)

    m_prev = m_ref[...]                         # (bq, 1)
    m_new = jnp.maximum(m_prev, sc.max(axis=1, keepdims=True))
    p = jnp.where(sc <= NEG_INF / 2, 0.0, jnp.exp(sc - m_new))
    corr = jnp.exp(m_prev - m_new)              # (bq, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, bq=512, bk=512,
                    interpret=False):
    """q (bh, s, d), k/v (bh, t, d) -> (bh, s, d).  Head folding and GQA
    expansion happen in ops.flash_mha."""
    bh, s, d = q.shape
    t = k.shape[1]
    bq, bk = min(bq, s), min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nk = s // bq, t // bk

    return pl.pallas_call(
        functools.partial(_kernel, scale=d ** -0.5, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)

"""RG-LRU diagonal linear recurrence Pallas-TPU kernel:
h_t = a_t * h_{t-1} + b_t  over the sequence axis.

TPU adaptation of the Griffin GPU scan: the grid iterates sequence blocks in
order (TPU grids execute sequentially per core), carrying the running hidden
state in VMEM scratch; within a block the time loop is a fori_loop of VPU
elementwise ops over (batch, d) tiles.  This keeps HBM traffic at exactly one
read of (a, b) and one write of h — the op is bandwidth-bound, so that is the
roofline optimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bs):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)        # (bt, bs, d)
    b = b_ref[...].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[:, t] * h + b[:, t]
        o_ref[:, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_ref[...])
    h_ref[...] = h


def rglru_scan_pallas(a, b, *, block_seq=128, interpret=False):
    """a, b (bt, s, d) -> h (bt, s, d); h_0 = 0 carried across seq blocks."""
    bt, s, d = a.shape
    bs = min(block_seq, s)
    assert s % bs == 0, (s, bs)
    ns = s // bs

    return pl.pallas_call(  # lint: disable=R6 -- bt/d are runtime-sized (seq is tiled via block_seq); bench shapes stay <= ~8x128x512 ≈ 13 MiB double-buffered
        functools.partial(_kernel, bs=bs),
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((bt, bs, d), lambda i: (0, i, 0)),
            pl.BlockSpec((bt, bs, d), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bs, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(a, b)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, gamma):
    """y = x @ w + gamma * (x @ a.T) @ b.T
    x (m, k), w (k, n), a (r, k), b (n, r)."""
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    p = xf @ a.astype(jnp.float32).T
    return (y + gamma * (p @ b.astype(jnp.float32).T)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q (b, s, h, d), k/v (b, t, h, d) (same head count — GQA expansion is
    the wrapper's job).  Returns (b, s, h, d)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    pq = jnp.arange(s)[:, None]
    pk = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= pk <= pq
    if window is not None:
        mask &= pq - pk < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, pos_pool, table, qpos, *,
                        window=None, softcap=None):
    """Exact-softmax oracle for the paged-attention decode kernel.

    q (b, h, hd) one token per request; k_pool/v_pool (P, bs, kh, hd);
    pos_pool (P, bs) int32 (-1 == never written); table (b, mb) int32 maps
    request i's virtual block j to a pool block; qpos (b,) absolute query
    positions.  Returns (b, h, hd) in q.dtype."""
    b, h, hd = q.shape
    _, bs, kh, _ = k_pool.shape
    mb = table.shape[1]
    g = h // kh
    k = k_pool[table].reshape(b, mb * bs, kh, hd).astype(jnp.float32)
    v = v_pool[table].reshape(b, mb * bs, kh, hd).astype(jnp.float32)
    pos = pos_pool[table].reshape(b, mb * bs)
    qg = q.reshape(b, kh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k) * hd ** -0.5
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    valid = (pos >= 0) & (pos <= qpos[:, None])
    if window is not None:
        valid &= qpos[:, None] - pos < window
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(b, h, hd).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """Sequential reference for h_t = a_t * h_{t-1} + b_t.  a, b (bt, s, d)."""
    bt, s, d = a.shape
    h = jnp.zeros((bt, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    out = []
    for tstep in range(s):
        h = a[:, tstep].astype(jnp.float32) * h + b[:, tstep].astype(jnp.float32)
        out.append(h)
    return jnp.stack(out, axis=1).astype(a.dtype)

# Pallas kernels for the paper's compute hot-spots, plus the dispatch layer
# (repro/kernels/dispatch.py) that routes the model stack's LoRA projections
# to compiled-Mosaic / interpreter / pure-jnp tiers per backend and per the
# model config's `use_pallas` flag.  ref.py holds the correctness oracles.

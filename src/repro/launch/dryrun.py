"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices and record memory / cost / collective
statistics for the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
      --shape train_4k [--multi-pod] [--rank 64] [--out EXPERIMENTS/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every combination
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init.  512 placeholder host devices cover both the 16x16 pod and
# the 2x16x16 multi-pod mesh.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, INPUT_SHAPES, LoRAConfig,
                           OptimizerConfig, config_for_shape, supports_shape)
from repro.core.federated import make_run_chunk
from repro.core.lora import AdapterSet, init_lora
from repro.launch.mesh import make_production_mesh, num_clients
from repro.models.api import build_model
from repro.sharding import rules
from repro.sharding.specs import use_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str):
    """Sum result-tensor bytes of every collective op in (post-SPMD) HLO.

    Convention: the result size is the per-op data volume proxy (all-reduce:
    operand==result; all-gather: result==full gathered tensor ~ moved bytes).
    """
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    # e.g.:  %ag = bf16[4,1024]{1,0} all-gather(%p), ...
    pat = re.compile(
        r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    tup_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(hlo_text):
        ty, op = m.group(1), m.group(2)
        total = 0
        for dt, dims in tup_pat.findall(ty):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[op] += total
        counts[op] += 1
    return out, counts


def _build(arch: str, shape_name: str, mesh, rank: int, alpha: float,
           num_layers=None):
    """Returns (fn, in_specs tuple of ShapeDtypeStructs, in_shardings).

    ``num_layers`` overrides the depth (used by the unit-calibration passes
    that derive exact per-layer costs — see run_one)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(arch, shape_name)
    cfg = dataclasses.replace(cfg, dtype="bfloat16", param_dtype="bfloat16")
    if num_layers is not None:
        over = {"num_layers": num_layers}
        if cfg.encoder_layers:
            over["encoder_layers"] = num_layers
        cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    lcfg = LoRAConfig(rank=rank, alpha=alpha, scaling="sfedlora",
                      targets=cfg.lora_targets)

    if shape.kind == "train":
        n = num_clients(mesh)
        opt_cfg = OptimizerConfig(name="sgd", lr=5e-3)
        # the REAL trainer engine (core/federated.py run_chunk), lowered with
        # explicit shardings — one scanned round per chunk for compile parity
        step = make_run_chunk(model, strategy="fedsa", opt_cfg=opt_cfg,
                              jit=False)

        def make_state():
            from repro.optim.optimizers import make_optimizer
            params = model.init(jax.random.key(0))
            l1 = init_lora(params, jax.random.key(1), lcfg)
            lora = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape), l1)
            opt1 = make_optimizer(opt_cfg)[0](l1)
            opt = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape), opt1)
            return params, lora, opt

        params_s, lora_tree_s, opt_s = jax.eval_shape(make_state)
        # the engine state is an AdapterSet: the scaling factor is static
        # treedef config derived from the LoRAConfig, so shape-level specs
        # only wrap the A/B tree
        lora_s = AdapterSet.from_config(lcfg, n_clients=n, lora=lora_tree_s)
        batch = model.input_specs(shape, n_clients=n)
        # (chunk_rounds=1, N, local_steps=1, per-client batch, ...)
        batch = {k: jax.ShapeDtypeStruct((1, v.shape[0], 1) + v.shape[1:],
                                         v.dtype) for k, v in batch.items()}
        key_s = jax.eval_shape(lambda: jax.random.key(0))
        ridx = jax.ShapeDtypeStruct((), jnp.int32)
        in_specs = (params_s, lora_s, opt_s, key_s, ridx, batch)
        repl = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        in_shard = (rules.params_sharding(params_s, mesh),
                    rules.lora_sharding(lora_s, mesh),
                    rules.lora_sharding(opt_s, mesh),
                    repl, repl,
                    rules.chunked_inputs_sharding(batch, mesh))
        return step, in_specs, in_shard

    if shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        params_s = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        batch = model.input_specs(shape)
        in_shard = (rules.params_sharding(params_s, mesh),
                    rules.inputs_sharding(batch, mesh))
        return prefill, (params_s, batch), in_shard

    # decode
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    params_s = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    spec = model.input_specs(shape)
    in_shard = (rules.params_sharding(params_s, mesh),
                rules.cache_sharding(spec["cache"], mesh),
                rules.inputs_sharding(spec["token"], mesh),
                rules.inputs_sharding(spec["pos"], mesh))
    return (serve_step, (params_s, spec["cache"], spec["token"], spec["pos"]),
            in_shard)


def _compile_stats(arch, shape_name, mesh, rank, alpha, *, num_layers=None,
                   unroll=False):
    import repro.models
    from repro.models import attention as attn
    prev = repro.models.FULL_UNROLL
    prev_blk = (attn.Q_BLOCK, attn.KV_BLOCK)
    repro.models.FULL_UNROLL = unroll
    if unroll:
        # calibration passes: bigger attention tiles -> far fewer unrolled
        # bodies (flop/byte counts are tile-size invariant; these modules are
        # never executed and their memory stats are not used)
        attn.Q_BLOCK = attn.KV_BLOCK = 4096
    try:
        t0 = time.monotonic()
        fn, in_specs, in_shard = _build(arch, shape_name, mesh, rank, alpha,
                                        num_layers=num_layers)
        with use_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_shard).lower(*in_specs)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
    finally:
        repro.models.FULL_UNROLL = prev
        attn.Q_BLOCK, attn.KV_BLOCK = prev_blk
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax returns one dict per device program here for some executables
    # (observed on the scanned train shapes); they are identical copies.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    coll, counts = collective_bytes(compiled.as_text())
    rec = {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
        "collective_bytes": coll, "collective_counts": counts,
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            rec[attr] = int(getattr(mem, attr))
    return rec


def run_one(arch: str, shape_name: str, *, multi_pod: bool, rank: int = 64,
            alpha: float = 8.0, verbose: bool = True, calibrate: bool = True):
    """Full-model compile (proof + memory stats) plus a two-point unit
    calibration: XLA's cost analysis counts while-loop bodies once, so the
    scanned full model under-reports loop work.  Compiling unrolled variants
    at 1x and 2x pattern length gives exact per-layer-group costs:
      per_group = stats(2) - stats(1);  outside = stats(1) - per_group;
      corrected = outside + per_group * (num_layers / pattern_len).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = _compile_stats(arch, shape_name, mesh, rank, alpha)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": int(mesh.devices.size), **rec}

    if calibrate:
        cfg = config_for_shape(arch, shape_name)
        plen = len(cfg.block_pattern)
        u1 = _compile_stats(arch, shape_name, mesh, rank, alpha,
                            num_layers=plen, unroll=True)
        u2 = _compile_stats(arch, shape_name, mesh, rank, alpha,
                            num_layers=2 * plen, unroll=True)
        groups = cfg.num_layers / plen

        def corr(f1, f2):
            per = max(f2 - f1, 0.0)
            outside = max(f1 - per, 0.0)
            return outside + per * groups

        rec["corrected"] = {
            "flops": corr(u1["flops"], u2["flops"]),
            "bytes_accessed": corr(u1["bytes_accessed"],
                                   u2["bytes_accessed"]),
            "collective_bytes": {
                k: corr(u1["collective_bytes"][k], u2["collective_bytes"][k])
                for k in u1["collective_bytes"]},
            "layer_groups": groups,
        }
        rec["unit_compile_s"] = round(u1["compile_s"] + u2["compile_s"], 1)
    if verbose:
        print(json.dumps(rec))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--out", default="EXPERIMENTS/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma-separated beyond-paper opts (sharding/opts.py)")
    args = ap.parse_args()

    if args.opts:
        from repro.sharding.opts import set_opts
        set_opts([o for o in args.opts.split(",") if o])

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for arch in ASSIGNED:
            for shape in INPUT_SHAPES:
                for mp in (False, True):
                    combos.append((arch, shape, mp))
    else:
        combos.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in combos:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"SKIP(done) {tag}")
            continue
        if not supports_shape(arch, shape):
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "skipped": "full-attention arch: long_500k requires "
                              "sub-quadratic attention (DESIGN.md §5)"}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"SKIP(policy) {tag}")
            continue
        try:
            rec = run_one(arch, shape, multi_pod=mp, rank=args.rank)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "error": str(e),
                   "trace": traceback.format_exc()[-2000:]}
            print(f"FAIL {tag}: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()

"""Federated LoRA fine-tuning launcher.

CPU-scale (this container):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --rank 64 --scaling sfedlora --clients 4 --rounds 30

On a TPU mesh the same entry point builds the production mesh and shards the
client dim over ("pod","data") — see launch/dryrun.py for the compile-only
proof of that path.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.checkpoint.io import save_federated_state
from repro.configs import ARCHS, get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU)")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--scaling", default="sfedlora",
                    choices=("lora", "rslora", "sfedlora", "za", "zb"))
    ap.add_argument("--strategy", default="fedsa",
                    choices=("fedit", "ffa", "fedsa", "rolora"))
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--optimizer", default="sgd", choices=("sgd", "adamw"))
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--partition", default="iid",
                    choices=("iid", "dirichlet"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    ds = FederatedDataset(cfg.vocab_size, args.clients, seq_len=args.seq,
                          batch_per_client=args.batch_per_client,
                          partition=args.partition, seed=args.seed)
    tr = FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=args.rank, alpha=args.alpha,
                            scaling=args.scaling, targets=cfg.lora_targets),
        fed_cfg=FederatedConfig(num_clients=args.clients,
                                local_steps=args.local_steps,
                                rounds=args.rounds,
                                aggregation=args.strategy,
                                partition=args.partition),
        opt_cfg=OptimizerConfig(name=args.optimizer, lr=args.lr),
        seed=args.seed)
    print(f"# {args.arch}{' (reduced)' if args.reduced else ''}  "
          f"strategy={args.strategy} scaling={args.scaling} "
          f"gamma={tr.gamma:.4f} rank={args.rank} N={args.clients}")
    tr.run(args.rounds, log_every=max(1, args.rounds // 10))
    ppl = tr.eval_perplexity()
    print(f"# final held-out perplexity: {ppl:.3f}")
    if args.save:
        save_federated_state(args.save, tr.base, tr.lora, tr.opt_state,
                             tr.round_idx)
        print(f"# saved -> {args.save}")
    return tr


if __name__ == "__main__":
    main()

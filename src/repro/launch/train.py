"""Federated LoRA fine-tuning launcher.

CPU-scale (this container):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --rank 64 --scaling sfedlora --clients 4 --rounds 30 --chunk-rounds 10

On a mesh the same entry point shards the client dim over the mesh's client
axes ("pod","data") and runs the compiled scan engine:
  ... --mesh 4x2 --clients 8 --chunk-rounds 10 --data-mode device
(see launch/dryrun.py for the compile-only proof of the production meshes).

Heterogeneous clients (per-client ranks + per-client gamma_i, Dirichlet
non-IID sizes, size-weighted aggregation):
  ... --clients 4 --ranks 4,8,16,16 --partition dirichlet \
      --dirichlet-alpha 0.3 --weight-by-size
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.aggregation import STRATEGIES
from repro.core.federated import FederatedTrainer
from repro.core.quant import apply_quant_flag, quantize_tree
from repro.data.synthetic import FederatedDataset
from repro.launch.mesh import mesh_from_spec
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU)")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--ranks", default="",
                    help="comma-separated per-client ranks (heterogeneous "
                         "clients), e.g. 4,8,16,16; overrides --rank — "
                         "clients pad to max(ranks) with a rank mask and "
                         "train with their own gamma_i")
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--scaling", default="sfedlora",
                    choices=("lora", "rslora", "sfedlora", "za", "zb"))
    ap.add_argument("--strategy", default="fedsa", choices=STRATEGIES)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per round")
    ap.add_argument("--optimizer", default="sgd", choices=("sgd", "adamw"))
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--partition", default="iid",
                    choices=("iid", "dirichlet"))
    ap.add_argument("--dirichlet-alpha", type=float, default=0.5,
                    help="Dir(alpha) concentration for the non-IID "
                         "partition (topic mixtures AND client sizes)")
    ap.add_argument("--weight-by-size", action="store_true",
                    help="weight the server aggregate by per-client "
                         "example counts instead of a plain mean")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-rounds", type=int, default=0,
                    help="rounds per compiled scan chunk (0: one chunk per "
                         "log stride)")
    ap.add_argument("--data-mode", default="host", choices=("host", "device"),
                    help="host: stage dataset batches per chunk; device: "
                         "synthesize batches inside the scan via jax.random")
    ap.add_argument("--mesh", default="",
                    help="mesh spec: 'DxM'/'PxDxM' (e.g. 4x2, 2x16x16), "
                         "'pod', 'multipod'; empty = no mesh")
    ap.add_argument("--quant", default="none", choices=("none", "int8", "int4"),
                    help="store the frozen base quantized (int8 per-channel "
                         "/ int4 grouped); LoRA state stays fp — kernels "
                         "dequantize per-tile in VMEM (core/quant.py)")
    ap.add_argument("--quant-group", type=int, default=64,
                    help="int4 group size (power of two <= 128)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault injection spec, e.g. "
                         "'dropout=0.1,straggle=geom:0.3,corrupt=0.01' "
                         "(see repro.core.faults.parse_faults); implies the "
                         "async buffered engine")
    ap.add_argument("--buffer", type=int, default=None, metavar="M",
                    help="async buffered aggregation: cap the per-round "
                         "buffer at M accepted uploads (0 = no cap, M = N "
                         "— bit-identical to the synchronous engine at "
                         "zero faults)")
    ap.add_argument("--staleness-beta", type=float, default=0.5,
                    help="staleness discount exponent: an upload tau "
                         "rounds old aggregates with weight (1+tau)^-beta")
    ap.add_argument("--no-screen", action="store_true",
                    help="disable server-side screening of non-finite / "
                         "norm-outlier uploads before aggregation")
    ap.add_argument("--screen-mult", type=float, default=10.0,
                    help="reject finite uploads whose norm exceeds this "
                         "multiple of the round median")
    ap.add_argument("--watchdog", type=int, default=None, metavar="RETRIES",
                    help="collapse watchdog: judge every chunk against the "
                         "Theorem 4.2 sentinel; on a failed verdict roll "
                         "back to the chunk-start snapshot and retry "
                         "(rescale gamma / back off participation) up to "
                         "RETRIES times before raising")
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint to restore (incl. PRNG key + round, so "
                         "the run continues bit-exactly)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    ranks = (tuple(int(r) for r in args.ranks.split(","))
             if args.ranks else None)
    ds = FederatedDataset(cfg.vocab_size, args.clients, seq_len=args.seq,
                          batch_per_client=args.batch_per_client,
                          partition=args.partition,
                          dirichlet_alpha=args.dirichlet_alpha,
                          seed=args.seed)
    mesh = mesh_from_spec(args.mesh)
    base_params = None
    if args.quant != "none":
        if mesh is not None:
            raise SystemExit("--quant is single-host for now (packed leaves "
                             "carry no sharding annotations); drop --mesh")
        # replicate the trainer's base-init split so the packed tree
        # quantizes the *identical* fp base the fp run would have trained on
        import jax
        kb, _ = jax.random.split(jax.random.key(args.seed))
        base_params = quantize_tree(model.init(kb), args.quant,
                                    args.quant_group)
    faults = None
    if args.faults:
        from repro.core.faults import parse_faults
        faults = parse_faults(args.faults)
    watchdog = None
    if args.watchdog is not None:
        from repro.core.federated import WatchdogConfig
        watchdog = WatchdogConfig(max_retries=args.watchdog)
    tr = FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=args.rank, ranks=ranks, alpha=args.alpha,
                            scaling=args.scaling, targets=cfg.lora_targets),
        fed_cfg=FederatedConfig(num_clients=args.clients,
                                local_steps=args.local_steps,
                                rounds=args.rounds,
                                aggregation=args.strategy,
                                partition=args.partition,
                                dirichlet_alpha=args.dirichlet_alpha,
                                participation=args.participation,
                                weight_by_size=args.weight_by_size,
                                buffer_size=args.buffer,
                                staleness_beta=args.staleness_beta,
                                screen_updates=not args.no_screen,
                                screen_norm_mult=args.screen_mult,
                                faults=faults),
        opt_cfg=OptimizerConfig(name=args.optimizer, lr=args.lr),
        seed=args.seed, base_params=base_params, data_mode=args.data_mode,
        chunk_rounds=args.chunk_rounds, mesh=mesh, watchdog=watchdog)
    if args.resume:
        tr.restore(args.resume)
        # an fp checkpoint restored under --quant is packed once here; a
        # packed checkpoint under a mismatched flag is a hard error
        tr.base = apply_quant_flag(tr.base, args.quant, args.quant_group,
                                   source=f"checkpoint '{args.resume}'")
        print(f"# resumed from {args.resume} at round {tr.round_idx}")
    aset = tr.adapters     # scaling factors travel with the state
    gamma_str = (f"gamma={aset.gamma:.4f} rank={args.rank}" if ranks is None
                 else "gammas=" + ",".join(f"{g:.3f}" for g in tr.gammas)
                 + f" ranks={args.ranks}")
    print(f"# {args.arch}{' (reduced)' if args.reduced else ''}  "
          f"strategy={args.strategy} scaling={args.scaling} "
          f"{gamma_str} N={args.clients}"
          + (" weight-by-size" if args.weight_by_size else "")
          + (f" mesh={args.mesh}" if args.mesh else "")
          + (f" quant={args.quant}" if args.quant != "none" else "")
          + (f" buffer={'N' if args.buffer == 0 else args.buffer}"
             if tr.async_mode else "")
          + (f" faults[{args.faults}]" if args.faults else "")
          + (f" watchdog(retries={args.watchdog})" if watchdog else ""))
    tr.run(args.rounds, log_every=max(1, args.rounds // 10))
    if tr.async_mode:
        last = tr.history[-1]
        print(f"# async: gamma_eff={tr.gamma_eff:.4f} "
              f"n_eff={last['n_eff']:.2f} delivered={last['delivered']:.0f} "
              f"rejected={last['rejected']:.0f} stale={last['stale']:.0f}")
    for ev in tr.watchdog_events:
        print(f"# watchdog: round {ev['round']} verdict={ev['verdict']} "
              f"-> {ev['action']} ({ev['detail']}, retry {ev['retry']})")
    ppl = tr.eval_perplexity()
    print(f"# final held-out perplexity: {ppl:.3f}")
    if args.save:
        tr.save(args.save)
        print(f"# saved -> {args.save}")
    return tr


if __name__ == "__main__":
    main()

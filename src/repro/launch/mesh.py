"""Production mesh construction (TPU v5e target).

Single pod : (16, 16)    axes ("data", "model")           = 256 chips
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model")    = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax import).
"""
from __future__ import annotations

import jax

# v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device unit tests (run in subprocesses with
    --xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)


def mesh_from_spec(spec: str):
    """Build a mesh from a CLI spec string.

    ``""``/``"none"`` -> no mesh;  ``"pod"``/``"multipod"`` -> the production
    meshes;  ``"DxM"`` / ``"PxDxM"`` (e.g. ``"4x2"``, ``"2x16x16"``) ->
    explicit shapes with axes ("data","model") / ("pod","data","model").
    """
    if not spec or spec == "none":
        return None
    if spec == "pod":
        return make_production_mesh()
    if spec == "multipod":
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(d) for d in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}.get(len(dims))
    if axes is None:
        raise ValueError(f"mesh spec '{spec}': expected 1-3 'x'-joined dims")
    return jax.make_mesh(dims, axes)


def client_axes(mesh) -> tuple:
    """Mesh axes that carry the federated client dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_clients(mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n

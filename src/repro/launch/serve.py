"""Multi-tenant batched LoRA serving from an AdapterBank.

One compiled decode step serves every tenant at once: each request carries an
adapter id, the step gathers that request's (padded, scale-folded) adapter
from the bank on device, and the batched dispatch path applies one adapter
per batch row — heterogeneous-rank adapters from N federated clients decode
in a single batch, no per-tenant recompiles, no weight merging.

  # fresh random adapters (API smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --steps 16 --batch 8 --clients 4

  # serve a TRAINED federated checkpoint (every client becomes a tenant):
  PYTHONPATH=src python -m repro.launch.train --reduced --save /tmp/ck.npz ...
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --resume /tmp/ck.npz --steps 16 --batch 8

The classic zero-overhead single-tenant path (merge one client's adapters
into the base weights) remains available via ``--merge CLIENT``.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_adapter_state
from repro.configs import ARCHS, get_config
from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, AdapterSet, init_adapter_set
from repro.models.api import build_model


@functools.lru_cache(maxsize=None)
def _jit_decode_step(model):
    """One jitted decode step per Model instance: ``model.decode_step`` is
    a fresh bound-method object on every attribute access, so an inline
    ``jax.jit(model.decode_step)`` would build a new executable cache per
    call and recompile every time the generator is re-entered."""
    return jax.jit(model.decode_step)


@functools.lru_cache(maxsize=None)
def _jit_banked_step(model):
    """One jitted bank-gathering decode step per Model instance."""
    @jax.jit
    def step(params, cache, tok, pos, bank, ids):
        return model.decode_step(params, cache, tok, pos,
                                 adapters=bank.gather(ids))
    return step


def generate(model, params, prompt, steps: int, max_len: int, adapters=None):
    """Greedy decode ``steps`` tokens after the prompt (prefill via decode).

    ``adapters``: None (base / merged weights), a single AdapterSet, or a
    ``batched`` one from ``AdapterBank.gather`` — the signature is uniform
    because the adapters travel as one value."""
    b, p = prompt.shape
    cache = model.init_cache(b, max_len)
    step = _jit_decode_step(model)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        logits, cache = step(params, cache, tok, jnp.full((b,), t),
                             adapters)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


def generate_banked(model, params, bank: AdapterBank, adapter_ids, prompt,
                    steps: int, max_len: int):
    """Multi-tenant greedy decode: row i of ``prompt`` is served with
    adapter ``adapter_ids[i]``.  The gather happens INSIDE the compiled
    step, so one executable covers every tenant mix (ids are traced)."""
    b, p = prompt.shape
    cache = model.init_cache(b, max_len)
    step = _jit_banked_step(model)
    ids = jnp.asarray(adapter_ids, jnp.int32)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        logits, cache = step(params, cache, tok, jnp.full((b,), t), bank, ids)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


def build_bank(args, cfg, model):
    """AdapterBank from a checkpoint (``--resume``) or fresh random sets.

    Returns (base_params, bank).  With ``--resume`` the bank registers the
    TRAINED stacked AdapterSet — per-client gammas fold into B, rank masks
    carry over — so serving uses exactly what training produced (and the
    checkpoint's base weights serve; nothing is initialized from scratch)."""
    if args.resume:
        lcfg = LoRAConfig(rank=args.rank, alpha=args.alpha,
                          scaling=args.scaling, targets=cfg.lora_targets)
        base, aset = load_adapter_state(args.resume, lora_cfg=lcfg)
        return base, AdapterBank.from_adapter_set(aset)
    params = model.init(jax.random.key(0))
    ranks = ([int(r) for r in args.ranks.split(",")] if args.ranks
             else [args.rank] * args.clients)
    sets = [init_adapter_set(
        params, jax.random.fold_in(jax.random.key(1), k),
        LoRAConfig(rank=r, alpha=args.alpha, scaling=args.scaling,
                   targets=cfg.lora_targets),
        n_clients=len(ranks)) for k, r in enumerate(ranks)]
    return params, AdapterBank.from_sets(sets)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--ranks", default="",
                    help="comma-separated per-tenant ranks for a fresh "
                         "mixed-rank bank, e.g. 4,8,16")
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--scaling", default="sfedlora",
                    choices=("lora", "rslora", "sfedlora", "za", "zb"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4,
                    help="tenant count for a fresh bank (ignored with "
                         "--resume: every checkpointed client serves)")
    ap.add_argument("--resume", default=None,
                    help="federated checkpoint (.npz) to serve: restores "
                         "the trained AdapterSet — gammas and rank mask "
                         "included — and registers every client in the bank")
    ap.add_argument("--merge", type=int, default=None, metavar="CLIENT",
                    help="classic single-tenant path: merge this client's "
                         "adapters into the base weights (zero serving "
                         "overhead) instead of banked decode")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    base, bank = build_bank(args, cfg, model)
    prompt = jax.random.randint(jax.random.key(2), (args.batch, 4), 0,
                                cfg.vocab_size)
    max_len = 4 + args.steps

    if args.merge is not None:
        merged = bank.adapter(args.merge).merge(base)
        t0 = time.time()
        seq = generate(model, merged, prompt, args.steps, max_len)
        dt = time.time() - t0
        print(f"# {args.arch} merged tenant {args.merge}: "
              f"batch={args.batch} steps={args.steps}  "
              f"{dt*1000/args.steps:.1f} ms/token")
        print(seq[:, :12])
        return seq

    ids = jnp.arange(args.batch) % bank.size
    t0 = time.time()
    seq = generate_banked(model, base, bank, ids, prompt, args.steps, max_len)
    dt = time.time() - t0
    print(f"# {args.arch} banked decode: {bank.size} tenants "
          f"(ranks {','.join(str(r) for r in bank.ranks)}), "
          f"batch={args.batch} steps={args.steps}  "
          f"{dt*1000/args.steps:.1f} ms/token")
    print(seq[:, :12])
    return seq


if __name__ == "__main__":
    main()

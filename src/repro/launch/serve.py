"""Multi-tenant batched LoRA serving from an AdapterBank.

Generation is a DEVICE-RESIDENT engine: one ``model.prefill`` fills the KV
cache over the whole prompt in a single batched forward, then a ``lax.scan``
decode loop carries (cache, token, PRNG key) entirely on device — greedy and
temperature sampling happen inside the scan, so a whole generation is ONE
host dispatch instead of one per token.  The signature is uniform across the
base / single-adapter / bank paths because the adapters travel as one value.

The bank path uses ``AdapterBank.requests(ids)`` — the LAZY per-request
view: adapter leaves stay tenant-stacked and each projection gathers its own
rows (in-kernel via the BGMV tier's ids-indexed BlockSpecs on fused tiers),
so serving K heterogeneous-rank tenants never materializes per-request
copies of the bank.

  # fresh random adapters (API smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --steps 16 --batch 8 --clients 4

  # serve a TRAINED federated checkpoint (every client becomes a tenant):
  PYTHONPATH=src python -m repro.launch.train --reduced --save /tmp/ck.npz ...
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --resume /tmp/ck.npz --steps 16 --batch 8

The classic zero-overhead single-tenant path (merge one client's adapters
into the base weights) remains available via ``--merge CLIENT``.  The old
token-by-token host loop survives as ``generate_hostloop`` — the parity
oracle the compiled engine is tested against, and serve_bench's baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_adapter_state
from repro.configs import ARCHS, get_config
from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, AdapterSet, init_adapter_set
from repro.models.api import build_model

# Host->device dispatch meter: every jitted call the generation helpers make
# increments this (serve_bench reports it; a compiled generate is exactly 1).
host_dispatches = 0


def reset_dispatch_meter() -> None:
    global host_dispatches
    host_dispatches = 0


def _count_dispatch(n: int = 1) -> None:
    global host_dispatches
    host_dispatches += n


def _model_jit(model, name: str, builder):
    """Per-model jit cache stored ON the model object itself.

    The previous ``functools.lru_cache(maxsize=None)`` keyed on Model
    instances pinned every model (and its compiled executables) for process
    lifetime.  An attribute cache makes the model own its executables: the
    model <-> jitted-fn reference cycle is ordinary gc-collectable garbage,
    so dropping the model frees everything (regression-tested)."""
    cache = model.__dict__.setdefault("_serve_jit_cache", {})
    fn = cache.get(name)
    if fn is None:
        fn = builder(model)
        cache[name] = fn
    return fn


def _jit_decode_step(model):
    """One jitted decode step per Model instance: ``model.decode_step`` is
    a fresh bound-method object on every attribute access, so an inline
    ``jax.jit(model.decode_step)`` would build a new executable cache per
    call and recompile every time the generator is re-entered."""
    return _model_jit(model, "decode_step",
                     lambda m: jax.jit(m.decode_step))


def _jit_banked_step(model):
    """One jitted bank-gathering decode step per Model instance (the
    host-loop oracle's banked path; the compiled engine gathers lazily)."""
    def build(m):
        @jax.jit
        def step(params, cache, tok, pos, bank, ids):
            return m.decode_step(params, cache, tok, pos,
                                 adapters=bank.gather(ids))
        return step
    return _model_jit(model, "banked_step", build)


# ------------------------------------------------------------ compiled engine

def _sample(logits, key, temperature: float, vocab: int):
    """One next token per row from (b, V) logits.  ``temperature`` is a
    static float: 0.0 compiles to pure greedy (no RNG ops in the graph).
    Both branches slice off the padded vocab rows (``V`` is ``vocab_padded``
    and the untrained padding logits are nonzero — random-normal embed
    init), so emitted ids are always real tokens; the host-loop oracle
    slices identically, keeping the engines bit-comparable."""
    logits = logits[..., :vocab]
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature).astype(jnp.int32)


def _compiled_generate(model):
    """The device-resident generation program, jitted once per model:
    prefill over the prompt, then a lax.scan decode loop whose carry
    (cache, token, key) never leaves the device."""
    def build(m):
        def run(params, prompt, adapters, key, *, steps, max_len,
                temperature):
            b, p = prompt.shape
            vocab = m.cfg.vocab_size
            # Prepare the adapter tree ONCE per generation: gamma folds,
            # rank masking, the bank's per-request gather, and the
            # (K, layers) -> (layers, K) scan relayout are all
            # loop-invariant, but left inside decode_step they re-run EVERY
            # token (XLA does not hoist the relayout transposes or gathers
            # out of the scan — together ~2MB of copies per step at bench
            # scale).  The ids are fixed for the whole call, so the lazy
            # bank view materializes its request rows here — one (B, ...)
            # gather per generation; decode_step then consumes a prepared
            # pass-through tree.  (The in-kernel BGMV gather still serves
            # direct decode_step/prefill callers, where ids change per
            # step.)
            if (adapters is not None and adapters.batched
                    and adapters.ids is not None):
                adapters = dataclasses.replace(
                    adapters,
                    lora=jax.tree.map(lambda x: x[adapters.ids],
                                      adapters.lora),
                    ids=None)
            tree = m._stack_adapters(adapters)
            adapters = None if tree is None else AdapterSet(
                lora={"stack": tree})
            cache = m.init_cache(b, max_len)
            logits, cache = m.prefill(params, cache, prompt, adapters,
                                      last_only=True)
            key, k0 = jax.random.split(key)
            tok = _sample(logits[:, -1], k0, temperature, vocab)[:, None]

            def step(carry, pos):
                cache, tok, key = carry
                lg, cache = m.decode_step(params, cache, tok,
                                          jnp.full((b,), pos), adapters)
                key, kt = jax.random.split(key)
                nxt = _sample(lg[:, -1], kt, temperature, vocab)[:, None]
                return (cache, nxt, key), nxt[:, 0]

            (cache, _, _), rest = jax.lax.scan(
                step, (cache, tok, key),
                jnp.arange(p, p + steps - 1, dtype=jnp.int32))
            return jnp.concatenate(
                [prompt.astype(jnp.int32), tok, rest.T], axis=1)
        return jax.jit(run, static_argnames=("steps", "max_len",
                                             "temperature"))
    return _model_jit(model, "generate", build)


def generate(model, params, prompt, steps: int, max_len: int, adapters=None,
             *, temperature: float = 0.0, key=None):
    """Compiled generation: ``steps`` tokens after the prompt in ONE host
    dispatch (batched prefill + on-device scan decode).

    ``adapters``: None (base / merged weights), a single AdapterSet, or a
    banked per-request set (``AdapterBank.requests``/``gather``) — the
    signature is uniform because the adapters travel as one value.
    ``temperature`` 0.0 decodes greedily; > 0.0 samples inside the scan
    from ``key`` (defaults to a fixed key for reproducibility).
    Returns the (b, p + steps) sequence, prompt included."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    prompt = jnp.asarray(prompt)
    if key is None:
        key = jax.random.key(0)
    run = _compiled_generate(model)
    _count_dispatch()
    return run(params, prompt, adapters, key, steps=int(steps),
               max_len=int(max_len), temperature=float(temperature))


def generate_banked(model, params, bank: AdapterBank, adapter_ids, prompt,
                    steps: int, max_len: int, *, temperature: float = 0.0,
                    key=None):
    """Multi-tenant compiled generation: row i of ``prompt`` is served with
    adapter ``adapter_ids[i]``.  The ids are traced, so one executable
    covers every tenant mix; the bank leaves stay stacked and each
    projection (or the BGMV kernel) gathers its own request rows."""
    return generate(model, params, prompt, steps, max_len,
                    adapters=bank.requests(adapter_ids),
                    temperature=temperature, key=key)


# ---------------------------------------------------------- host-loop oracle

def generate_hostloop(model, params, prompt, steps: int, max_len: int,
                      adapters=None):
    """The pre-engine token-by-token loop (one jitted dispatch per token,
    prompt fed through single-token decode steps) — kept as the parity
    oracle for the compiled engine and as serve_bench's baseline.  Greedy
    argmax slices to the real vocab exactly like the compiled engine, so
    the two stay bit-comparable AND neither emits padded-vocab ids."""
    b, p = prompt.shape
    vocab = model.cfg.vocab_size
    cache = model.init_cache(b, max_len)
    step = _jit_decode_step(model)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        _count_dispatch()
        logits, cache = step(params, cache, tok, jnp.full((b,), t),
                             adapters)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:, :vocab],
                               -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


def generate_banked_hostloop(model, params, bank: AdapterBank, adapter_ids,
                             prompt, steps: int, max_len: int):
    """Host-loop oracle for the bank path (materialized per-step gather)."""
    b, p = prompt.shape
    vocab = model.cfg.vocab_size
    cache = model.init_cache(b, max_len)
    step = _jit_banked_step(model)
    ids = jnp.asarray(adapter_ids, jnp.int32)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        _count_dispatch()
        logits, cache = step(params, cache, tok, jnp.full((b,), t), bank, ids)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:, :vocab],
                               -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


# ------------------------------------------------------------------ CLI

def build_bank(args, cfg, model):
    """AdapterBank from a checkpoint (``--resume``) or fresh random sets.

    Returns (base_params, bank).  With ``--resume`` the bank registers the
    TRAINED stacked AdapterSet — per-client gammas fold into B, rank masks
    carry over — so serving uses exactly what training produced (and the
    checkpoint's base weights serve; nothing is initialized from scratch)."""
    if args.resume:
        lcfg = LoRAConfig(rank=args.rank, alpha=args.alpha,
                          scaling=args.scaling, targets=cfg.lora_targets)
        base, aset = load_adapter_state(args.resume, lora_cfg=lcfg)
        return base, AdapterBank.from_adapter_set(aset)
    params = model.init(jax.random.key(0))
    ranks = ([int(r) for r in args.ranks.split(",")] if args.ranks
             else [args.rank] * args.clients)
    sets = [init_adapter_set(
        params, jax.random.fold_in(jax.random.key(1), k),
        LoRAConfig(rank=r, alpha=args.alpha, scaling=args.scaling,
                   targets=cfg.lora_targets),
        n_clients=len(ranks)) for k, r in enumerate(ranks)]
    return params, AdapterBank.from_sets(sets)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--ranks", default="",
                    help="comma-separated per-tenant ranks for a fresh "
                         "mixed-rank bank, e.g. 4,8,16")
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--scaling", default="sfedlora",
                    choices=("lora", "rslora", "sfedlora", "za", "zb"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples inside the compiled scan")
    ap.add_argument("--clients", type=int, default=4,
                    help="tenant count for a fresh bank (ignored with "
                         "--resume: every checkpointed client serves)")
    ap.add_argument("--resume", default=None,
                    help="federated checkpoint (.npz) to serve: restores "
                         "the trained AdapterSet — gammas and rank mask "
                         "included — and registers every client in the bank")
    ap.add_argument("--merge", type=int, default=None, metavar="CLIENT",
                    help="classic single-tenant path: merge this client's "
                         "adapters into the base weights (zero serving "
                         "overhead) instead of banked decode")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    base, bank = build_bank(args, cfg, model)
    prompt = jax.random.randint(jax.random.key(2), (args.batch, 4), 0,
                                cfg.vocab_size)
    max_len = 4 + args.steps

    if args.merge is not None:
        merged = bank.adapter(args.merge).merge(base)
        seq = generate(model, merged, prompt, args.steps, max_len,
                       temperature=args.temperature)  # warm-up + compile
        t0 = time.time()
        seq = jax.block_until_ready(
            generate(model, merged, prompt, args.steps, max_len,
                     temperature=args.temperature))
        dt = time.time() - t0
        print(f"# {args.arch} merged tenant {args.merge}: "
              f"batch={args.batch} steps={args.steps}  "
              f"{dt*1000/args.steps:.1f} ms/token (compiled engine)")
        print(seq[:, :12])
        return seq

    ids = jnp.arange(args.batch) % bank.size
    seq = generate_banked(model, base, bank, ids, prompt, args.steps,
                          max_len, temperature=args.temperature)
    t0 = time.time()
    seq = jax.block_until_ready(
        generate_banked(model, base, bank, ids, prompt, args.steps, max_len,
                        temperature=args.temperature))
    dt = time.time() - t0
    print(f"# {args.arch} banked decode: {bank.size} tenants "
          f"(ranks {','.join(str(r) for r in bank.ranks)}), "
          f"batch={args.batch} steps={args.steps}  "
          f"{dt*1000/args.steps:.1f} ms/token (compiled engine, "
          f"1 dispatch/call)")
    print(seq[:, :12])
    return seq


if __name__ == "__main__":
    main()

"""Multi-tenant batched LoRA serving from an AdapterBank.

Generation is a DEVICE-RESIDENT engine: one ``model.prefill`` fills the KV
cache over the whole prompt in a single batched forward, then a ``lax.scan``
decode loop carries (cache, token, PRNG key) entirely on device — greedy and
temperature sampling happen inside the scan, so a whole generation is ONE
host dispatch instead of one per token.  The signature is uniform across the
base / single-adapter / bank paths because the adapters travel as one value.

The bank path uses ``AdapterBank.requests(ids)`` — the LAZY per-request
view: adapter leaves stay tenant-stacked and each projection gathers its own
rows (in-kernel via the BGMV tier's ids-indexed BlockSpecs on fused tiers),
so serving K heterogeneous-rank tenants never materializes per-request
copies of the bank.

  # fresh random adapters (API smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --steps 16 --batch 8 --clients 4

  # serve a TRAINED federated checkpoint (every client becomes a tenant):
  PYTHONPATH=src python -m repro.launch.train --reduced --save /tmp/ck.npz ...
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --resume /tmp/ck.npz --steps 16 --batch 8

The classic zero-overhead single-tenant path (merge one client's adapters
into the base weights) remains available via ``--merge CLIENT``.  The old
token-by-token host loop survives as ``generate_hostloop`` — the parity
oracle the compiled engine is tested against, and serve_bench's baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hostcheck import check_adapter_ids
from repro.analysis.sanitizers import guard_transfers
from repro.checkpoint.io import load_adapter_state
from repro.configs import ARCHS, get_config
from repro.configs.base import LoRAConfig
from repro.core.lora import (AdapterBank, AdapterSet, LiveAdapterBank,
                             init_adapter_set)
from repro.core.quant import (apply_quant_flag, dequantize_tree,
                              has_quantized, requantize_merged)
from repro.kernels import dispatch
from repro.models.api import build_model
from repro.models.transformer import (merge_paged_cache, paged_prefill_view,
                                      reset_paged_blocks)

# Host->device dispatch meter: every jitted call the generation helpers make
# increments this (serve_bench reports it; a compiled generate is exactly 1).
host_dispatches = 0


def reset_dispatch_meter() -> None:
    global host_dispatches
    host_dispatches = 0


def _count_dispatch(n: int = 1) -> None:
    global host_dispatches
    host_dispatches += n


# requests evicted at a chunk boundary for exceeding their deadline_steps
# (graceful degradation under load; truncated, not failed)
timeouts = 0


def reset_timeout_meter() -> None:
    global timeouts
    timeouts = 0


def _count_timeout(n: int = 1) -> None:
    global timeouts
    timeouts += n


def _model_jit(model, name: str, builder):
    """Per-model jit cache stored ON the model object itself.

    The previous ``functools.lru_cache(maxsize=None)`` keyed on Model
    instances pinned every model (and its compiled executables) for process
    lifetime.  An attribute cache makes the model own its executables: the
    model <-> jitted-fn reference cycle is ordinary gc-collectable garbage,
    so dropping the model frees everything (regression-tested)."""
    cache = model.__dict__.setdefault("_serve_jit_cache", {})
    fn = cache.get(name)
    if fn is None:
        fn = builder(model)
        cache[name] = fn
    return fn


def _jit_decode_step(model):
    """One jitted decode step per Model instance: ``model.decode_step`` is
    a fresh bound-method object on every attribute access, so an inline
    ``jax.jit(model.decode_step)`` would build a new executable cache per
    call and recompile every time the generator is re-entered."""
    return _model_jit(model, "decode_step",
                     lambda m: jax.jit(m.decode_step))


def _jit_banked_step(model):
    """One jitted bank-gathering decode step per Model instance (the
    host-loop oracle's banked path; the compiled engine gathers lazily)."""
    def build(m):
        @jax.jit
        def step(params, cache, tok, pos, bank, ids):
            return m.decode_step(params, cache, tok, pos,
                                 adapters=bank.gather(ids))
        return step
    return _model_jit(model, "banked_step", build)


# ------------------------------------------------------------ compiled engine

def _prepare_base(m, params):
    """Loop-invariant handling of a packed frozen base (core/quant.py).

    On the REFERENCE tier the policy is dequantize-up-front: doing it here,
    once per compiled call, makes the fp view scan-invariant — XLA
    materializes it once instead of re-dequantizing every decode step
    (mirrors the federated engine's run_chunk hoist).  Fused tiers return
    the params untouched: the kernels dequantize per-tile in VMEM and the
    packed bytes are exactly what keeps decode bandwidth-cheap."""
    if not has_quantized(params):
        return params
    with dispatch.scope(m.cfg.use_pallas):
        if dispatch.resolve_mode() == "reference":
            return dequantize_tree(params)
    return params


def _prepare_adapters(m, adapters):
    """Loop-invariant adapter preparation, shared by every compiled engine
    entry point: gamma folds, rank masking, the bank's per-request gather,
    and the (K, layers) -> (layers, K) scan relayout all run ONCE per
    compiled call — left inside decode_step they re-run EVERY token (XLA
    does not hoist the relayout transposes or gathers out of a scan;
    together ~2MB of copies per step at bench scale).  The ids are fixed
    for the whole call, so the lazy bank view materializes its request rows
    here — one (B, ...) gather; decode_step then consumes a prepared
    pass-through tree.  (The in-kernel BGMV gather still serves direct
    decode_step/prefill callers, where ids change per step.)"""
    if (adapters is not None and adapters.batched
            and adapters.ids is not None):
        adapters = dataclasses.replace(
            adapters,
            lora=jax.tree.map(lambda x: x[adapters.ids], adapters.lora),
            ids=None)
    tree = m._stack_adapters(adapters)
    return None if tree is None else AdapterSet(lora={"stack": tree})


def _sample(logits, key, temperature: float, vocab: int):
    """One next token per row from (b, V) logits.  ``temperature`` is a
    static float: 0.0 compiles to pure greedy (no RNG ops in the graph).
    Both branches slice off the padded vocab rows (``V`` is ``vocab_padded``
    and the untrained padding logits are nonzero — random-normal embed
    init), so emitted ids are always real tokens; the host-loop oracle
    slices identically, keeping the engines bit-comparable."""
    logits = logits[..., :vocab]
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature).astype(jnp.int32)


def _compiled_generate(model):
    """The device-resident generation program, jitted once per model:
    prefill over the prompt, then a lax.scan decode loop whose carry
    (cache, token, key) never leaves the device."""
    def build(m):
        def run(params, prompt, adapters, key, *, steps, max_len,
                temperature):
            b, p = prompt.shape
            vocab = m.cfg.vocab_size
            params = _prepare_base(m, params)
            adapters = _prepare_adapters(m, adapters)
            cache = m.init_cache(b, max_len)
            logits, cache = m.prefill(params, cache, prompt, adapters,
                                      last_only=True)
            key, k0 = jax.random.split(key)
            tok = _sample(logits[:, -1], k0, temperature, vocab)[:, None]

            def step(carry, pos):
                cache, tok, key = carry
                lg, cache = m.decode_step(params, cache, tok,
                                          jnp.full((b,), pos), adapters)
                key, kt = jax.random.split(key)
                nxt = _sample(lg[:, -1], kt, temperature, vocab)[:, None]
                return (cache, nxt, key), nxt[:, 0]

            (cache, _, _), rest = jax.lax.scan(
                step, (cache, tok, key),
                jnp.arange(p, p + steps - 1, dtype=jnp.int32))
            return jnp.concatenate(
                [prompt.astype(jnp.int32), tok, rest.T], axis=1)
        return jax.jit(run, static_argnames=("steps", "max_len",
                                             "temperature"))
    return _model_jit(model, "generate", build)


def generate(model, params, prompt, steps: int, max_len: int, adapters=None,
             *, temperature: float = 0.0, key=None):
    """Compiled generation: ``steps`` tokens after the prompt in ONE host
    dispatch (batched prefill + on-device scan decode).

    ``adapters``: None (base / merged weights), a single AdapterSet, or a
    banked per-request set (``AdapterBank.requests``/``gather``) — the
    signature is uniform because the adapters travel as one value.
    ``temperature`` 0.0 decodes greedily; > 0.0 samples inside the scan
    from ``key`` (defaults to a fixed key for reproducibility).
    Returns the (b, p + steps) sequence, prompt included."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    prompt = jnp.asarray(prompt)
    if key is None:
        key = jax.random.key(0)
    run = _compiled_generate(model)
    _count_dispatch()
    return run(params, prompt, adapters, key, steps=int(steps),
               max_len=int(max_len), temperature=float(temperature))


# Host-boundary validation of request->tenant ids against a bank of ``size``
# tenants (shared with AdapterBank.gather/requests; traced ids pass through).
_check_adapter_ids = check_adapter_ids


def generate_banked(model, params, bank: AdapterBank, adapter_ids, prompt,
                    steps: int, max_len: int, *, temperature: float = 0.0,
                    key=None):
    """Multi-tenant compiled generation: row i of ``prompt`` is served with
    adapter ``adapter_ids[i]``.  The ids are traced, so one executable
    covers every tenant mix; the bank leaves stay stacked and each
    projection (or the BGMV kernel) gathers its own request rows."""
    _check_adapter_ids(adapter_ids, bank.size)
    return generate(model, params, prompt, steps, max_len,
                    adapters=bank.requests(adapter_ids),
                    temperature=temperature, key=key)


# ---------------------------------------------------------- host-loop oracle

def generate_hostloop(model, params, prompt, steps: int, max_len: int,
                      adapters=None):
    """The pre-engine token-by-token loop (one jitted dispatch per token,
    prompt fed through single-token decode steps) — kept as the parity
    oracle for the compiled engine and as serve_bench's baseline.  Greedy
    argmax slices to the real vocab exactly like the compiled engine, so
    the two stay bit-comparable AND neither emits padded-vocab ids."""
    b, p = prompt.shape
    vocab = model.cfg.vocab_size
    cache = model.init_cache(b, max_len)
    step = _jit_decode_step(model)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        _count_dispatch()
        logits, cache = step(params, cache, tok, jnp.full((b,), t),
                             adapters)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:, :vocab],
                               -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


def generate_banked_hostloop(model, params, bank: AdapterBank, adapter_ids,
                             prompt, steps: int, max_len: int):
    """Host-loop oracle for the bank path (materialized per-step gather)."""
    _check_adapter_ids(adapter_ids, bank.size)
    b, p = prompt.shape
    vocab = model.cfg.vocab_size
    cache = model.init_cache(b, max_len)
    step = _jit_banked_step(model)
    ids = jnp.asarray(adapter_ids, jnp.int32)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        _count_dispatch()
        logits, cache = step(params, cache, tok, jnp.full((b,), t), bank, ids)
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:, :vocab],
                               -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


# ----------------------------------------------- continuous-batching scheduler
#
# The fixed-batch engine above serves ONE batch per compiled call: every
# request in the batch starts together, decodes in lockstep, and the whole
# batch holds its ring-buffer KV cache until the LAST request finishes.  At
# mixed lengths / staggered arrivals that is the classic head-of-line
# problem: a request arriving just after a batch launched waits a full
# generation, and a short request pins its cache rows while long neighbors
# drag on.
#
# The scheduler below serves a STREAM of requests through a paged engine:
#
#   * KV state lives in per-layer SHARED block pools (model.init_paged_cache)
#     addressed through a per-slot block table — BlockPool hands blocks out
#     and takes them back on the host, so a finished request's memory is
#     reusable the moment it completes, not when its batch drains.
#   * Decode runs in CHUNKS: one jitted lax.scan of `chunk` steps over all
#     engine slots (active or not — idle slots' table rows point at the
#     reserved null block 0, so their discarded writes land where no live
#     request ever looks).  Between chunks the host admits newly-arrived
#     requests into free slots and evicts finished ones.
#   * Admission is one jitted prefill per same-length newcomer group on a
#     VIEW whose pools ARE the engine pools and whose per-slot state is
#     fresh (transformer.paged_prefill_view); merging scatters the
#     newcomers' slot state back without touching continuing requests.
#
# At a static schedule (every request present at t=0, uniform shapes) the
# admission group IS the fixed-engine batch and every chunk step runs the
# same program on the same shapes, so scheduled greedy decode is
# token-identical to `generate` on the gather tiers (tests/test_paged.py);
# under staggered arrivals it trades nothing for the latency win that
# benchmarks/serve_bench.py measures.


class BlockPool:
    """Host-side free-list allocator over the paged cache's block axis.

    Block 0 is the NULL block: idle engine slots' table rows point at it,
    so their discarded decode writes land in a block no live request owns.
    It is never handed out — `alloc` serves blocks 1..num_blocks-1 only."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))
        self._held = set()

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int):
        """n blocks, or None if the pool can't cover them (caller defers
        admission — nothing is partially allocated)."""
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._held.update(blocks)
        return blocks

    def free(self, blocks) -> None:
        blocks = list(blocks)
        bad = [b for b in blocks if b not in self._held]
        if bad or len(set(blocks)) != len(blocks):
            raise ValueError(f"freeing blocks not held (double free?): "
                             f"{bad or blocks}")
        for b in blocks:
            self._held.discard(b)
            self._free.append(b)


@dataclasses.dataclass
class Request:
    """One generation request for the scheduler.  ``steps`` counts generated
    tokens (prompt excluded), matching `generate`; ``arrival`` is seconds
    from scheduler start.  ``adapter_id`` is the TENANT identity — a row of
    a static AdapterBank, or a store tenant of a LiveAdapterBank (which may
    live in host RAM until this request promotes it); it is validated at
    the host boundary, never clamped.  The scheduler fills the bookkeeping
    fields: ``tokens`` (the generated ids, first token included),
    ``t_first`` / ``t_done`` (completion-relative timestamps for latency
    metrics).

    ``deadline_steps`` caps how many tokens the scheduler will spend on
    this request before evicting it at the next chunk boundary (graceful
    degradation under load): a request that hits the cap finishes with
    its tokens truncated, ``timed_out`` set, and the module ``timeouts``
    counter bumped — its slot and blocks recycle immediately."""
    rid: int
    prompt: np.ndarray
    steps: int
    adapter_id: int = 0
    arrival: float = 0.0
    deadline_steps: int | None = None
    slot: int = -1
    blocks: list = dataclasses.field(default_factory=list)
    tokens: list = dataclasses.field(default_factory=list)
    t_first: float | None = None
    t_done: float | None = None
    timed_out: bool = False


def _jit_paged_admit(model):
    """Jitted admission program: invalidate the newcomers' (possibly
    recycled) blocks, prefill the same-length group on the shared-pool
    view, scatter its per-slot state into the engine slots, and emit each
    newcomer's first token.  One executable per (group, prompt) shape."""
    def build(m):
        def admit(params, cache, prompts, table_rows, slots, blocks,
                  adapters):
            g, _ = prompts.shape
            vocab = m.cfg.vocab_size
            params = _prepare_base(m, params)
            adapters = _prepare_adapters(m, adapters)
            cache = reset_paged_blocks(cache, blocks)
            cross = (m.cfg.encoder_frames if m.cfg.family == "audio" else 0)
            view = paged_prefill_view(m.cfg, cache, g,
                                      jnp.dtype(m.cfg.dtype),
                                      cross_len=cross)
            logits, view = m.prefill(params, view, prompts, adapters,
                                     last_only=True, table=table_rows)
            cache = merge_paged_cache(cache, view, slots)
            tok = jnp.argmax(logits[:, -1, :vocab], -1).astype(jnp.int32)
            return cache, tok
        return jax.jit(admit)
    return _model_jit(model, "paged_admit", build)


def _jit_paged_chunk(model):
    """Jitted decode chunk: ``steps`` greedy tokens for every engine slot
    in one lax.scan.  ``active`` gates token emission and position
    advance; inactive slots still run (static shapes) but write into the
    null block and their outputs are discarded host-side."""
    def build(m):
        def chunk_run(params, cache, tok, pos, active, table, adapters, *,
                      steps):
            vocab = m.cfg.vocab_size
            params = _prepare_base(m, params)
            adapters = _prepare_adapters(m, adapters)

            def step(carry, _):
                cache, tok, pos = carry
                lg, cache = m.decode_step(params, cache, tok, pos, adapters,
                                          table=table)
                nxt = jnp.argmax(lg[:, -1, :vocab], -1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, 0)
                pos = jnp.where(active, pos + 1, pos)
                return (cache, nxt[:, None], pos), nxt

            (cache, tok, pos), toks = jax.lax.scan(
                step, (cache, tok, pos), None, length=steps)
            return cache, tok, pos, toks.T
        return jax.jit(chunk_run, static_argnames=("steps",))
    return _model_jit(model, "paged_chunk", build)


def serve_scheduled(model, params, requests, *, bank=None, max_batch=4,
                    block_size=8, chunk=8, max_len=None, wait=True,
                    on_boundary=None, guard=None, transfer_guard=False):
    """Continuous-batching serve loop: admit / decode-chunk / evict until
    every request completes.  Returns the requests (mutated in place —
    ``tokens``, ``t_first``, ``t_done`` filled) sorted by rid.

    ``requests``: Request list; arrivals are seconds from loop start and
    are honored against the wall clock (``wait=False`` treats every
    request as already arrived — deterministic tests).  ``bank``: optional
    AdapterBank (each request's ``adapter_id`` indexes a bank row) or
    :class:`~repro.core.lora.LiveAdapterBank` (``adapter_id`` names a
    store tenant; non-resident tenants are LRU-promoted into hot slots at
    admission, slots gathered by running requests stay pinned, and
    publishes land between chunks with zero recompiles).
    ``max_len`` bounds prompt+steps per request and sizes the per-request
    block count; the pool holds exactly ``max_batch`` requests' worth of
    blocks plus the null block, so admission can never deadlock behind
    block starvation with a free slot.

    ``on_boundary(i)``: optional hook called at every scheduler boundary
    (before admission, between decode chunks) with a running boundary
    index — the adapter-lifecycle swap window: publishing into a live bank
    here is atomic with respect to decode chunks (the chunk already
    dispatched gathered the old slots; the next gathers the new).

    ``guard``: optional :class:`repro.analysis.sanitizers.RecompileGuard`
    — the admit/chunk engines are wrapped so any executable-cache growth
    on an already-served signature (e.g. a publish that churns the bank
    treedef) raises with the offending avals.  ``transfer_guard=True``
    additionally runs both engines under
    ``jax.transfer_guard("disallow")``; enable it on warmed shapes with
    device-resident params (tracing/compiling under the guard would trip
    on legitimate staging transfers)."""
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    if not reqs:
        return []
    live = bank if isinstance(bank, LiveAdapterBank) else None
    if bank is not None and live is None:
        # host-boundary id validation: an out-of-range id would be silently
        # clamp-gathered to the LAST tenant's adapter.  A live bank's store
        # may legitimately grow mid-run (a publish from on_boundary), so
        # its tenants are checked at admission time instead.
        for r in reqs:
            _check_adapter_ids([r.adapter_id], bank.size,
                               what=f"request rid={r.rid}: adapter_id")
    need = max(len(r.prompt) + r.steps for r in reqs)
    max_len = max_len or need
    win = model.cfg.attn_window
    # a sliding-window model may wrap its virtual ring (vlen = blocks *
    # block_size) exactly like the fixed engine's ring cache, as long as
    # the ring still covers the window
    if need > max_len and (win is None or max_len < win):
        raise ValueError(f"request needs {need} positions > max_len "
                         f"{max_len}")
    # per-request virtual ring sized exactly like the fixed engine's ring
    # cache (window-bounded), so the paged layout stays element-identical
    ring = min(max_len, win) if win else max_len
    mb = -(-ring // block_size)
    pool = BlockPool(1 + max_batch * mb)
    cache = model.init_paged_cache(pool.num_blocks, block_size, max_batch)
    table = jnp.zeros((max_batch, mb), jnp.int32)
    tok = jnp.zeros((max_batch, 1), jnp.int32)
    pos = jnp.zeros((max_batch,), jnp.int32)
    active = jnp.zeros((max_batch,), bool)
    ids_arr = np.zeros((max_batch,), np.int32)
    free_slots = list(range(max_batch))
    admit = _jit_paged_admit(model)
    chunk_run = _jit_paged_chunk(model)
    if guard is not None:
        admit = guard.wrap("paged_admit", admit)
        chunk_run = guard.wrap("paged_chunk", chunk_run)
    if transfer_guard:
        admit = guard_transfers(admit)
        chunk_run = guard_transfers(chunk_run)
    t0 = time.monotonic()
    clock = ((lambda: time.monotonic() - t0) if wait
             else (lambda: float("inf")))
    pending, running = list(reqs), []

    cur_bank = (lambda: live.bank) if live is not None else (lambda: bank)

    def finish(r, now):
        r.t_done = now
        running.remove(r)
        free_slots.append(r.slot)
        free_slots.sort()
        pool.free(r.blocks)
        nonlocal active, table
        active = active.at[r.slot].set(False)
        table = table.at[r.slot].set(0)         # back to the null block
        # reset the slot's tenant id: a stale id would keep being gathered
        # for the idle slot every chunk (harmless to outputs — the slot is
        # inactive — but it corrupts LRU/residency accounting, which keys
        # promotion and slot pinning on the observed ids)
        ids_arr[r.slot] = 0

    boundary = 0
    while pending or running:
        if on_boundary is not None:
            # the swap window: between decode chunks / admission groups
            on_boundary(boundary)
        boundary += 1
        now = clock()
        # ---- admission: FIFO same-length groups into free slots.  The
        # head of the queue is never overtaken (a shorter-prompt request
        # behind it cannot jump ahead), which keeps the loop deterministic
        # and starvation-free.
        while pending and free_slots and pending[0].arrival <= now:
            plen = len(pending[0].prompt)
            group = []
            for r in pending:
                if (r.arrival <= now and len(r.prompt) == plen
                        and len(group) < len(free_slots)
                        and pool.available >= mb * (len(group) + 1)):
                    group.append(r)
                else:
                    break
            slot_map = None
            if group and live is not None:
                for r in group:
                    if not live.has(r.adapter_id):
                        raise ValueError(
                            f"request rid={r.rid}: unknown tenant "
                            f"{r.adapter_id} (store holds {live.tenants})")
                # hot slots gathered by running requests are pinned; shrink
                # the group from the tail (head keeps FIFO priority) until
                # its distinct tenants fit the unpinned hot set, deferring
                # admission entirely when even the head cannot be promoted
                pinned = {int(ids_arr[r.slot]) for r in running}
                while group:
                    slot_map = live.acquire(
                        [r.adapter_id for r in group], pinned)
                    if slot_map is not None:
                        break
                    group.pop()
            if not group:
                break
            for r in group:
                pending.remove(r)
            slots = [free_slots.pop(0) for _ in group]
            rows = np.zeros((len(group), mb), np.int32)
            gather_ids = np.zeros((len(group),), np.int32)
            for i, (r, s) in enumerate(zip(group, slots)):
                r.slot, r.blocks = s, pool.alloc(mb)
                rows[i] = r.blocks
                gather_ids[i] = (slot_map[int(r.adapter_id)]
                                 if live is not None else r.adapter_id)
                ids_arr[s] = gather_ids[i]
            sl = jnp.asarray(slots, jnp.int32)
            table = table.at[sl].set(jnp.asarray(rows))
            prompts = jnp.asarray(np.stack([r.prompt for r in group]),
                                  jnp.int32)
            adapters = (cur_bank().requests(jnp.asarray(gather_ids))
                        if bank is not None else None)
            _count_dispatch()
            cache, first = admit(params, cache, prompts, jnp.asarray(rows),
                                 sl, jnp.asarray(rows.reshape(-1)), adapters)
            tok = tok.at[sl, 0].set(first)
            pos = pos.at[sl].set(plen)
            active = active.at[sl].set(True)
            tnow = clock()
            first_host = np.asarray(first)
            for i, r in enumerate(group):
                r.tokens = [int(first_host[i])]
                r.t_first = None if tnow == float("inf") else tnow
                running.append(r)
            for r in [r for r in group if r.steps <= 1]:
                finish(r, r.t_first)
            for r in [r for r in group
                      if r in running and r.deadline_steps is not None
                      and len(r.tokens) >= r.deadline_steps]:
                r.timed_out = True
                _count_timeout()
                finish(r, r.t_first)

        # ---- decode chunk + eviction
        if running:
            if live is not None:
                # recency driven by the ids flowing through the scheduler
                live.touch([r.adapter_id for r in running])
            adapters = (cur_bank().requests(jnp.asarray(ids_arr))
                        if bank is not None else None)
            _count_dispatch()
            cache, tok, pos, toks = chunk_run(params, cache, tok, pos,
                                              active, table, adapters,
                                              steps=chunk)
            toks = np.asarray(toks)
            tnow = clock()
            for r in list(running):
                # a deadline caps how many tokens this request may consume;
                # the prefix generated up to the cap is identical to an
                # un-deadlined run (eviction happens between chunks, never
                # inside one)
                cap = (r.steps if r.deadline_steps is None
                       else min(r.steps, r.deadline_steps))
                take = max(0, min(chunk, cap - len(r.tokens)))
                r.tokens.extend(int(t) for t in toks[r.slot, :take])
                if len(r.tokens) >= r.steps:
                    finish(r, None if tnow == float("inf") else tnow)
                elif len(r.tokens) >= cap:
                    r.timed_out = True
                    _count_timeout()
                    finish(r, None if tnow == float("inf") else tnow)
        elif pending:
            gap = pending[0].arrival - clock()
            if gap > 0:
                time.sleep(min(gap, 0.02))
    return sorted(reqs, key=lambda r: r.rid)


def make_requests(trace, *, prompt_len, steps, tenants, vocab, seed=0,
                  deadline_steps=None):
    """Request list from an arrival trace.

    ``trace`` is either ``poisson:RATE:N`` (N arrivals, RATE req/s, seeded
    exponential inter-arrival gaps — the serve_bench scenario) or a path to
    a JSON list of ``{"arrival": s, "steps": n, "adapter": k, "deadline":
    d}`` records.  Prompts are seeded random ids, round-robin adapters
    unless the trace names them.  ``deadline_steps`` is the default
    per-request token budget (None = no deadline); a trace record's
    ``deadline`` overrides it."""
    rng = np.random.default_rng(seed)
    if trace.startswith("poisson:"):
        _, rate, n = trace.split(":")
        gaps = rng.exponential(1.0 / float(rate), int(n))
        recs = [{"arrival": float(t)} for t in np.cumsum(gaps)]
    else:
        with open(trace) as f:
            recs = json.load(f)
    def _deadline(rec):
        d = rec.get("deadline", deadline_steps)
        return None if d is None else int(d)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, prompt_len).astype(
                        np.int32),
                    steps=int(rec.get("steps", steps)),
                    adapter_id=int(rec.get("adapter", i % max(tenants, 1))),
                    arrival=float(rec.get("arrival", 0.0)),
                    deadline_steps=_deadline(rec))
            for i, rec in enumerate(recs)]
    for r in reqs:   # a bad trace record must fail here, not serve tenant N-1
        if not 0 <= r.adapter_id < tenants:
            raise ValueError(
                f"request rid={r.rid}: adapter {r.adapter_id} out of range "
                f"for {tenants} tenants (trace record names a tenant the "
                "bank does not hold)")
        if r.deadline_steps is not None and r.deadline_steps < 1:
            raise ValueError(
                f"request rid={r.rid}: deadline_steps={r.deadline_steps} "
                "must be >= 1 (the admission prefill always emits the "
                "first token)")
    return reqs


# ------------------------------------------------------------------ CLI

def build_bank(args, cfg, model):
    """AdapterBank from a checkpoint (``--resume``) or fresh random sets.

    Returns (base_params, bank).  With ``--resume`` the bank registers the
    TRAINED stacked AdapterSet — per-client gammas fold into B, rank masks
    carry over — so serving uses exactly what training produced (and the
    checkpoint's base weights serve; nothing is initialized from scratch)."""
    if args.resume:
        lcfg = LoRAConfig(rank=args.rank, alpha=args.alpha,
                          scaling=args.scaling, targets=cfg.lora_targets)
        base, aset = load_adapter_state(args.resume, lora_cfg=lcfg)
        return base, AdapterBank.from_adapter_set(aset)
    params = model.init(jax.random.key(0))
    ranks = ([int(r) for r in args.ranks.split(",")] if args.ranks
             else [args.rank] * args.clients)
    sets = [init_adapter_set(
        params, jax.random.fold_in(jax.random.key(1), k),
        LoRAConfig(rank=r, alpha=args.alpha, scaling=args.scaling,
                   targets=cfg.lora_targets),
        n_clients=len(ranks)) for k, r in enumerate(ranks)]
    return params, AdapterBank.from_sets(sets)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--ranks", default="",
                    help="comma-separated per-tenant ranks for a fresh "
                         "mixed-rank bank, e.g. 4,8,16")
    ap.add_argument("--alpha", type=float, default=8.0)
    ap.add_argument("--scaling", default="sfedlora",
                    choices=("lora", "rslora", "sfedlora", "za", "zb"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples inside the compiled scan")
    ap.add_argument("--clients", type=int, default=4,
                    help="tenant count for a fresh bank (ignored with "
                         "--resume: every checkpointed client serves)")
    ap.add_argument("--resume", default=None,
                    help="federated checkpoint (.npz) to serve: restores "
                         "the trained AdapterSet — gammas and rank mask "
                         "included — and registers every client in the bank")
    ap.add_argument("--quant", default="none", choices=("none", "int8", "int4"),
                    help="serve from a quantized frozen base: one-shot "
                         "post-load quantization of the eligible GEMM "
                         "weights (int8 per-channel / int4 grouped); "
                         "adapters stay fp, kernels dequant in VMEM")
    ap.add_argument("--quant-group", type=int, default=64,
                    help="int4 group size (power of two <= 128)")
    ap.add_argument("--merge", type=int, default=None, metavar="CLIENT",
                    help="classic single-tenant path: merge this client's "
                         "adapters into the base weights (zero serving "
                         "overhead) instead of banked decode")
    ap.add_argument("--arrival-trace", default=None,
                    help="serve a request STREAM through the continuous-"
                         "batching scheduler instead of one fixed batch: "
                         "'poisson:RATE:N' (seeded Poisson arrivals) or a "
                         "JSON trace file of {arrival, steps, adapter} "
                         "records")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="scheduler engine slots (concurrent requests)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="KV tokens per pool block (paged cache)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per scheduler chunk (admission / "
                         "eviction happen at chunk boundaries)")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request token budget for the scheduler: "
                         "requests still running at this many tokens are "
                         "evicted (truncated) at the next chunk boundary "
                         "and counted as timeouts")
    ap.add_argument("--hot-slots", type=int, default=0,
                    help="serve the bank through a LiveAdapterBank with "
                         "this many device-resident slots; the remaining "
                         "tenants overflow to host RAM and are LRU-promoted "
                         "on demand (0 = whole bank on device, no overflow)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    base, bank = build_bank(args, cfg, model)
    # one-shot post-load quantization (or flag/checkpoint reconciliation: a
    # packed checkpoint under a mismatched --quant is a hard error)
    src = (f"checkpoint '{args.resume}'" if args.resume else "fresh base")
    base = apply_quant_flag(base, args.quant, args.quant_group, source=src)
    prompt = jax.random.randint(jax.random.key(2), (args.batch, 4), 0,
                                cfg.vocab_size)
    max_len = 4 + args.steps

    if args.arrival_trace:
        reqs = make_requests(args.arrival_trace, prompt_len=4,
                             steps=args.steps, tenants=bank.size,
                             vocab=cfg.vocab_size,
                             deadline_steps=args.deadline_steps)
        reset_timeout_meter()
        serve_bank = bank
        if args.hot_slots:
            serve_bank = LiveAdapterBank.from_bank(bank,
                                                   hot_slots=args.hot_slots)
        t0 = time.monotonic()
        done = serve_scheduled(model, base, reqs, bank=serve_bank,
                               max_batch=args.max_batch,
                               block_size=args.block_size, chunk=args.chunk)
        dt = time.monotonic() - t0
        lats = sorted(r.t_done - r.arrival for r in done
                      if r.t_done is not None)
        p50 = lats[len(lats) // 2] if lats else 0.0
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))] if lats else 0.0
        toks = sum(len(r.tokens) for r in done)
        n_to = sum(1 for r in done if r.timed_out)
        print(f"# {args.arch} scheduled serve: {len(done)} requests, "
              f"{bank.size} tenants, max_batch={args.max_batch} "
              f"block={args.block_size} chunk={args.chunk}  "
              f"p50={p50*1000:.0f}ms p99={p99*1000:.0f}ms "
              f"goodput={toks/dt:.1f} tok/s"
              + (f" timeouts={n_to}" if args.deadline_steps else ""))
        if args.hot_slots:
            print(f"# live bank: {serve_bank.hot_slots}/{len(serve_bank.tenants)} "
                  f"slots hot, {serve_bank.promotions} promotions, "
                  f"{serve_bank.demotions} demotions")
        return done

    if args.merge is not None:
        merged = bank.adapter(args.merge).merge(base)
        if has_quantized(base):
            # merge_lora dequantizes packed leaves to fold the adapter in;
            # re-pack onto the checkpoint's grid or --merge --quant would
            # silently serve fp weights and lose the whole footprint win
            merged = requantize_merged(merged, base)
        seq = generate(model, merged, prompt, args.steps, max_len,
                       temperature=args.temperature)  # warm-up + compile
        t0 = time.monotonic()
        seq = jax.block_until_ready(
            generate(model, merged, prompt, args.steps, max_len,
                     temperature=args.temperature))
        dt = time.monotonic() - t0
        print(f"# {args.arch} merged tenant {args.merge}: "
              f"batch={args.batch} steps={args.steps}  "
              f"{dt*1000/args.steps:.1f} ms/token (compiled engine)")
        print(seq[:, :12])
        return seq

    ids = jnp.arange(args.batch) % bank.size
    seq = generate_banked(model, base, bank, ids, prompt, args.steps,
                          max_len, temperature=args.temperature)
    t0 = time.monotonic()
    seq = jax.block_until_ready(
        generate_banked(model, base, bank, ids, prompt, args.steps, max_len,
                        temperature=args.temperature))
    dt = time.monotonic() - t0
    print(f"# {args.arch} banked decode: {bank.size} tenants "
          f"(ranks {','.join(str(r) for r in bank.ranks)}), "
          f"batch={args.batch} steps={args.steps}  "
          f"{dt*1000/args.steps:.1f} ms/token (compiled engine, "
          f"1 dispatch/call)")
    print(seq[:, :12])
    return seq


if __name__ == "__main__":
    main()

"""Batched serving with merged LoRA adapters (zero inference latency — the
paper's deployment property).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --steps 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.configs.base import LoRAConfig
from repro.core.lora import init_lora, merge_lora
from repro.core.scaling import scaling_factor
from repro.models.api import build_model


def generate(model, params, prompt, steps: int, max_len: int):
    """Greedy decode ``steps`` tokens after the prompt (prefill via decode)."""
    b, p = prompt.shape
    cache = model.init_cache(b, max_len)
    step = jax.jit(model.decode_step)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(p + steps - 1):
        logits, cache = step(params, cache, tok, jnp.full((b,), t))
        nxt = (prompt[:, t + 1:t + 2] if t + 1 < p
               else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
        out.append(nxt)
        tok = nxt
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lora = init_lora(params, jax.random.key(1),
                     LoRAConfig(rank=args.rank, targets=cfg.lora_targets))
    gamma = scaling_factor("sfedlora", 8.0, args.rank, args.clients)
    merged = merge_lora(params, lora, gamma)   # deploy-time merge
    prompt = jax.random.randint(jax.random.key(2), (args.batch, 4), 0,
                                cfg.vocab_size)
    t0 = time.time()
    seq = generate(model, merged, prompt, args.steps, 4 + args.steps)
    dt = time.time() - t0
    print(f"# {args.arch} merged-LoRA decode: batch={args.batch} "
          f"steps={args.steps}  {dt*1000/args.steps:.1f} ms/token (CPU)")
    print(seq[:, :12])
    return seq


if __name__ == "__main__":
    main()

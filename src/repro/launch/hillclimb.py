"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

For each selected (arch x shape) pair, re-lowers the step with cumulative
beyond-paper optimization sets (sharding/opts.py) and records the roofline
terms per variant, so each hypothesis -> change -> before/after cycle is one
row.  Usage:

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --pair mistral-nemo-12b:train_4k \
      --variants baseline expand_kv expand_kv+chunked_ce \
      --out EXPERIMENTS/hillclimb
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json

from repro.launch import dryrun
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.sharding import opts


def terms(rec):
    src = rec.get("corrected", rec)
    return {"compute_s": src["flops"] / PEAK_FLOPS_BF16,
            "memory_s": src["bytes_accessed"] / HBM_BW,
            "collective_s": sum(src["collective_bytes"].values()) / ICI_BW,
            "temp_gb": rec.get("temp_size_in_bytes", 0) / 1e9}


def run_variant(arch, shape, variant: str, *, multi_pod=False, rank=64):
    opts.reset()
    names = [] if variant == "baseline" else variant.split("+")
    opts.set_opts(names)
    try:
        rec = dryrun.run_one(arch, shape, multi_pod=multi_pod, rank=rank,
                             verbose=False, calibrate=True)
    finally:
        opts.reset()
    return {"arch": arch, "shape": shape, "variant": variant,
            **terms(rec), "raw": rec}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", required=True,
                    help="arch:shape (repeatable)")
    ap.add_argument("--variants", nargs="+", default=["baseline"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--out", default="EXPERIMENTS/hillclimb")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    print("arch,shape,variant,compute_s,memory_s,collective_s,temp_gb")
    for pair in args.pair:
        arch, shape = pair.split(":")
        for variant in args.variants:
            tag = f"{arch}__{shape}__{variant.replace('+', '_')}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    r = json.load(f)
            else:
                try:
                    r = run_variant(arch, shape, variant,
                                    multi_pod=args.multi_pod, rank=args.rank)
                except Exception as e:
                    print(f"{arch},{shape},{variant},ERROR,{e}")
                    continue
                with open(path, "w") as f:
                    json.dump(r, f, indent=1)
            print(f"{arch},{shape},{variant},{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
                  f"{r['temp_gb']:.2f}")


if __name__ == "__main__":
    main()

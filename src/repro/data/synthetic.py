"""Synthetic federated LM data.

The paper's datasets (Alpaca, GSM8K, GLUE) are not available offline; we
substitute a structured synthetic language whose next-token distribution is
*learnable* (so convergence curves are meaningful) and which supports IID and
Dirichlet non-IID client partitions over "topic" mixtures — the statistic the
paper's heterogeneity experiments vary.
"""
from __future__ import annotations

import json

import numpy as np


class SyntheticLM:
    """Markov-ish token source: K latent topics, each a sparse bigram table."""

    def __init__(self, vocab_size: int, num_topics: int = 8, seed: int = 0,
                 branch: int = 2, noise: float = 0.05):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.num_topics = num_topics
        # per-topic: each token deterministically prefers `branch` successors
        self.succ = rng.integers(0, vocab_size,
                                 size=(num_topics, vocab_size, branch))
        self.noise = noise

    def sample(self, rng, topic: int, batch: int, seq_len: int):
        toks = np.empty((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        succ = self.succ[topic]
        for t in range(1, seq_len):
            choice = rng.integers(0, succ.shape[1], size=batch)
            nxt = succ[toks[:, t - 1], choice]
            noise = rng.random(batch) < self.noise
            nxt = np.where(noise, rng.integers(0, self.vocab, size=batch), nxt)
            toks[:, t] = nxt
        return toks


def client_topic_mixtures(num_clients: int, num_topics: int, *,
                          partition: str = "iid", dirichlet_alpha: float = 0.5,
                          seed: int = 0):
    """Per-client categorical over topics: uniform (IID) or Dir(alpha)."""
    rng = np.random.default_rng(seed)
    if partition == "iid":
        return np.full((num_clients, num_topics), 1.0 / num_topics)
    if partition == "dirichlet":
        return rng.dirichlet(np.full(num_topics, dirichlet_alpha),
                             size=num_clients)
    raise ValueError(partition)


def client_example_counts(num_clients: int, *, total: int = 0,
                          partition: str = "iid",
                          dirichlet_alpha: float = 0.5, seed: int = 0):
    """Per-client example counts n_i (each >= 1, summing to ``total``).

    IID splits the pool evenly; the Dirichlet partition draws client
    proportions ~ Dir(alpha) — small alpha gives the heavy-tailed client
    sizes the paper's heterogeneity experiments vary — and realizes them as
    a multinomial so the counts are integers that sum exactly to ``total``.
    These drive size-weighted aggregation (``FederatedConfig.
    weight_by_size``), where client i's weight in the server mean is
    n_i / sum_j n_j.
    """
    total = int(total) or 512 * num_clients
    if total < num_clients:
        raise ValueError(
            f"total={total} examples cannot give {num_clients} clients "
            ">= 1 example each")
    if partition == "iid":
        base = total // num_clients
        counts = np.full(num_clients, base, np.int64)
        counts[: total - base * num_clients] += 1
        return counts
    if partition == "dirichlet":
        # offset the seed so sizes are not correlated with topic mixtures
        rng = np.random.default_rng(seed + 4242)
        p = rng.dirichlet(np.full(num_clients, dirichlet_alpha))
        return rng.multinomial(total - num_clients, p) + 1
    raise ValueError(partition)


class FederatedDataset:
    """Per-client infinite batch iterator over the synthetic LM."""

    def __init__(self, vocab_size: int, num_clients: int, *, seq_len: int,
                 batch_per_client: int, partition: str = "iid",
                 dirichlet_alpha: float = 0.5, seed: int = 0,
                 num_topics: int = 8, total_examples: int = 0):
        self.lm = SyntheticLM(vocab_size, num_topics, seed=seed)
        self.mix = client_topic_mixtures(num_clients, num_topics,
                                         partition=partition,
                                         dirichlet_alpha=dirichlet_alpha,
                                         seed=seed)
        self.sizes = client_example_counts(num_clients, total=total_examples,
                                           partition=partition,
                                           dirichlet_alpha=dirichlet_alpha,
                                           seed=seed)
        self.num_clients = num_clients
        self.seq_len = seq_len
        self.batch = batch_per_client
        self.rngs = [np.random.default_rng(seed + 1000 + i)
                     for i in range(num_clients)]

    @property
    def size_weights(self):
        """(N,) float: each client's share of the example pool — the
        weights size-weighted aggregation uses in the server mean."""
        return self.sizes / self.sizes.sum()

    def client_batch(self, i: int):
        rng = self.rngs[i]
        topic = rng.choice(self.lm.num_topics, p=self.mix[i])
        return self.lm.sample(rng, topic, self.batch, self.seq_len)

    def round_batch(self, local_steps: int = 1):
        """(num_clients, local_steps, batch, seq) for one federated round."""
        out = np.stack([
            np.stack([self.client_batch(i) for _ in range(local_steps)])
            for i in range(self.num_clients)])
        return out

    def eval_batch(self, batch: int, seed: int = 9999):
        """Held-out IID batch (uniform topic mixture)."""
        rng = np.random.default_rng(seed)
        per = max(1, batch // self.lm.num_topics)
        parts = [self.lm.sample(rng, t, per, self.seq_len)
                 for t in range(self.lm.num_topics)]
        return np.concatenate(parts)[:batch]

    # ---- stream-state (de)serialization, for bit-exact checkpoint resume

    def rng_state(self) -> str:
        """Serialized per-client generator states (JSON)."""
        return json.dumps([r.bit_generator.state for r in self.rngs])

    def set_rng_state(self, state: str) -> None:
        for rng, st in zip(self.rngs, json.loads(state)):
            rng.bit_generator.state = st

    def _lm_fingerprint(self) -> str:
        """Digest of the seed-derived LM transition tables: the partition
        can be restored from a checkpoint, the tables cannot — a mismatch
        means the restoring process built the dataset from a different
        seed and the data stream would silently diverge."""
        import hashlib
        return hashlib.sha1(
            np.ascontiguousarray(self.lm.succ).tobytes()).hexdigest()[:16]

    def partition_state(self) -> str:
        """Serialized client partition (topic mixtures + example counts,
        plus the LM-table fingerprint) — checkpointed so a restored run
        provably resumes under the same clients even if the dataset was
        reconstructed differently."""
        return json.dumps({"mix": self.mix.tolist(),
                           "sizes": self.sizes.tolist(),
                           "lm": self._lm_fingerprint()})

    def set_partition_state(self, state: str) -> None:
        st = json.loads(state)
        if "lm" in st and st["lm"] != self._lm_fingerprint():
            raise ValueError(
                "checkpoint was written against a dataset with different "
                "LM transition tables (different seed/vocab/topics) — "
                "reconstruct the FederatedDataset with the original "
                "parameters to resume bit-exactly")
        mix = np.asarray(st["mix"], np.float64)
        sizes = np.asarray(st["sizes"], np.int64)
        if mix.shape != self.mix.shape:
            raise ValueError(
                f"checkpoint partition has {mix.shape[0]} clients x "
                f"{mix.shape[1]} topics; this dataset has "
                f"{self.mix.shape[0]} x {self.mix.shape[1]}")
        self.mix = mix
        self.sizes = sizes


class DeviceFederatedData:
    """On-device mirror of :class:`FederatedDataset`: the same topic
    transition tables and client mixtures, but sampled with ``jax.random``
    as a pure function of a PRNG key — usable *inside* the engine's
    ``lax.scan`` over rounds (``core/federated.py``), so large-N runs
    generate data where it is consumed instead of streaming it from host.
    """

    def __init__(self, succ, mix, noise: float, batch: int, seq_len: int):
        import jax.numpy as jnp
        self.succ = jnp.asarray(succ)               # (topics, vocab, branch)
        self.mix = jnp.asarray(mix, jnp.float32)    # (clients, topics)
        self.noise = float(noise)
        self.batch = batch
        self.seq_len = seq_len
        self.vocab = int(self.succ.shape[1])

    @classmethod
    def from_host(cls, ds: "FederatedDataset") -> "DeviceFederatedData":
        return cls(ds.lm.succ, ds.mix, ds.lm.noise, ds.batch, ds.seq_len)

    def sample_round(self, key, local_steps: int = 1):
        """(num_clients, local_steps, batch, seq) int32, pure jax (jittable,
        scannable, vmappable)."""
        import jax
        import jax.numpy as jnp
        n, topics = self.mix.shape

        def one_batch(k, mix_i):
            kt, k0, kseq = jax.random.split(k, 3)
            topic = jax.random.choice(kt, topics, p=mix_i)
            succ_t = self.succ[topic]               # (vocab, branch)
            t0 = jax.random.randint(k0, (self.batch,), 0, self.vocab)

            def gen(prev, kk):
                kc, kn, ku = jax.random.split(kk, 3)
                branch = jax.random.randint(kc, (self.batch,), 0,
                                            succ_t.shape[1])
                nxt = succ_t[prev, branch]
                noisy = jax.random.uniform(kn, (self.batch,)) < self.noise
                nxt = jnp.where(noisy, jax.random.randint(
                    ku, (self.batch,), 0, self.vocab), nxt)
                return nxt, nxt

            _, rest = jax.lax.scan(gen, t0,
                                   jax.random.split(kseq, self.seq_len - 1))
            return jnp.concatenate([t0[None], rest], 0).T.astype(jnp.int32)

        keys = jax.random.split(key, n * local_steps).reshape(n, local_steps)
        return jax.vmap(lambda ks, m: jax.vmap(
            lambda k: one_batch(k, m))(ks))(keys, self.mix)

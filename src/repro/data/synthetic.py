"""Synthetic federated LM data.

The paper's datasets (Alpaca, GSM8K, GLUE) are not available offline; we
substitute a structured synthetic language whose next-token distribution is
*learnable* (so convergence curves are meaningful) and which supports IID and
Dirichlet non-IID client partitions over "topic" mixtures — the statistic the
paper's heterogeneity experiments vary.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Markov-ish token source: K latent topics, each a sparse bigram table."""

    def __init__(self, vocab_size: int, num_topics: int = 8, seed: int = 0,
                 branch: int = 2, noise: float = 0.05):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.num_topics = num_topics
        # per-topic: each token deterministically prefers `branch` successors
        self.succ = rng.integers(0, vocab_size,
                                 size=(num_topics, vocab_size, branch))
        self.noise = noise

    def sample(self, rng, topic: int, batch: int, seq_len: int):
        toks = np.empty((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        succ = self.succ[topic]
        for t in range(1, seq_len):
            choice = rng.integers(0, succ.shape[1], size=batch)
            nxt = succ[toks[:, t - 1], choice]
            noise = rng.random(batch) < self.noise
            nxt = np.where(noise, rng.integers(0, self.vocab, size=batch), nxt)
            toks[:, t] = nxt
        return toks


def client_topic_mixtures(num_clients: int, num_topics: int, *,
                          partition: str = "iid", dirichlet_alpha: float = 0.5,
                          seed: int = 0):
    """Per-client categorical over topics: uniform (IID) or Dir(alpha)."""
    rng = np.random.default_rng(seed)
    if partition == "iid":
        return np.full((num_clients, num_topics), 1.0 / num_topics)
    if partition == "dirichlet":
        return rng.dirichlet(np.full(num_topics, dirichlet_alpha),
                             size=num_clients)
    raise ValueError(partition)


class FederatedDataset:
    """Per-client infinite batch iterator over the synthetic LM."""

    def __init__(self, vocab_size: int, num_clients: int, *, seq_len: int,
                 batch_per_client: int, partition: str = "iid",
                 dirichlet_alpha: float = 0.5, seed: int = 0,
                 num_topics: int = 8):
        self.lm = SyntheticLM(vocab_size, num_topics, seed=seed)
        self.mix = client_topic_mixtures(num_clients, num_topics,
                                         partition=partition,
                                         dirichlet_alpha=dirichlet_alpha,
                                         seed=seed)
        self.num_clients = num_clients
        self.seq_len = seq_len
        self.batch = batch_per_client
        self.rngs = [np.random.default_rng(seed + 1000 + i)
                     for i in range(num_clients)]

    def client_batch(self, i: int):
        rng = self.rngs[i]
        topic = rng.choice(self.lm.num_topics, p=self.mix[i])
        return self.lm.sample(rng, topic, self.batch, self.seq_len)

    def round_batch(self, local_steps: int = 1):
        """(num_clients, local_steps, batch, seq) for one federated round."""
        out = np.stack([
            np.stack([self.client_batch(i) for _ in range(local_steps)])
            for i in range(self.num_clients)])
        return out

    def eval_batch(self, batch: int, seed: int = 9999):
        """Held-out IID batch (uniform topic mixture)."""
        rng = np.random.default_rng(seed)
        per = max(1, batch // self.lm.num_topics)
        parts = [self.lm.sample(rng, t, per, self.seq_len)
                 for t in range(self.lm.num_topics)]
        return np.concatenate(parts)[:batch]

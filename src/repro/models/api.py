"""Public model API: build_model(cfg) -> Model with init / forward / loss /
init_cache / decode_step, uniform across all families (dense, moe, hybrid,
ssm, vlm, audio)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lora import as_adapter_set
from repro.kernels import dispatch
from repro.models.layers import norm_params, apply_norm
from repro.models.transformer import (apply_stack, banked_scan_layout,
                                      batched_scan_layout, decode_stack,
                                      init_stack, init_paged_stack_cache,
                                      init_stack_cache, prefill_stack)

PATCH_EMBED_DIM = 1152   # SigLIP stub output width (arXiv:2407.07726)


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


class Model:
    def __init__(self, cfg):
        self.cfg = cfg
        self.vocab_padded = pad_vocab(cfg.vocab_size)

    # ------------------------------------------------------------- params
    def init(self, key):
        cfg = self.cfg
        pdt = jnp.dtype(cfg.param_dtype)
        ke, ks, kh, kenc, kp = jax.random.split(key, 5)
        params = {
            "embed": (jax.random.normal(ke, (self.vocab_padded, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(pdt),
            "stack": init_stack(cfg, ks),
        }
        params.update(norm_params(cfg, cfg.d_model, "final"))
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(
                kh, (cfg.d_model, self.vocab_padded)) *
                cfg.d_model ** -0.5).astype(pdt)
        if cfg.family == "audio":
            enc = {"stack": init_stack(cfg, kenc,
                                       num_layers=cfg.encoder_layers,
                                       pattern=("attn",))}
            enc.update(norm_params(cfg, cfg.d_model, "encfinal"))
            params["encoder"] = enc
        if cfg.family == "vlm":
            params["patch_proj"] = (jax.random.normal(
                kp, (PATCH_EMBED_DIM, cfg.d_model)) *
                PATCH_EMBED_DIM ** -0.5).astype(pdt)
        return params

    # ------------------------------------------------------------- forward
    def _embed(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x.astype(jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            patches = (batch["patches"] @ params["patch_proj"]).astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def _encode(self, params, batch):
        cfg = self.cfg
        enc = params["encoder"]
        h, _ = apply_stack(cfg, enc["stack"],
                           batch["frames"].astype(jnp.dtype(cfg.dtype)),
                           causal=False, pattern=("attn",))
        return apply_norm(cfg, h, enc, "encfinal")

    @staticmethod
    def _stack_adapters(adapters):
        """Resolve an AdapterSet to the prepared "stack" subtree the block
        machinery consumes: rank mask applied, gamma folded into B (the one
        place scaling meets the model), banked per-request trees reordered
        for the layer scans."""
        if adapters is None:
            return None
        prepared = adapters.prepared()
        tree = (prepared.lora or {}).get("stack")
        if adapters.batched and tree:
            tree = (banked_scan_layout(tree, adapters.ids)
                    if adapters.ids is not None else
                    batched_scan_layout(tree))
        return tree

    def forward(self, params, batch, adapters=None):
        """Full-sequence forward.  Returns (logits, aux_loss).

        ``adapters`` is an :class:`repro.core.lora.AdapterSet` (or None for
        the base model)."""
        adapters = as_adapter_set(adapters)
        cfg = self.cfg
        with dispatch.scope(cfg.use_pallas):
            x = self._embed(params, batch)
            b, s, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            enc_out = (self._encode(params, batch)
                       if cfg.family == "audio" else None)
            x, aux = apply_stack(cfg, params["stack"], x,
                                 adapters=self._stack_adapters(adapters),
                                 positions=positions, enc_out=enc_out,
                                 causal=cfg.family != "encoder")
            x = apply_norm(cfg, x, params, "final")
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            logits = x @ head.astype(x.dtype)
        return logits, aux

    def loss(self, params, batch, adapters=None):
        """Next-token CE over the text segment (+ MoE aux).  Encoder-only
        models use MLM-style loss (mask every 5th token).

        ``adapters`` is an AdapterSet (or None for the base model)."""
        adapters = as_adapter_set(adapters)
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "encoder":
            s = tokens.shape[1]
            mask_id = self.vocab_padded - 1
            masked_pos = (jnp.arange(s) % 5) == 2
            inp = jnp.where(masked_pos[None, :], mask_id, tokens)
            logits, aux = self.forward(params, {**batch, "tokens": inp},
                                       adapters=adapters)
            lf = logits.astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(lf, axis=-1)
            ll = jnp.take_along_axis(lf, tokens[..., None], axis=-1)[..., 0]
            per_tok = (lse - ll) * masked_pos[None, :]
            ce = per_tok.sum() / (masked_pos.sum() * tokens.shape[0])
            return ce + aux, {"ce": ce, "aux": aux}
        from repro.sharding import opts
        if opts.enabled("chunked_ce"):
            return self._loss_chunked(params, batch, adapters)
        logits, aux = self.forward(params, batch, adapters=adapters)
        s_text = tokens.shape[1]
        logits = logits[:, -s_text:][:, :-1]
        labels = tokens[:, 1:]
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        ce = (lse - ll).mean()
        return ce + aux, {"ce": ce, "aux": aux}

    def _loss_chunked(self, params, batch, adapters, chunk: int = 512):
        """CE computed in sequence chunks: the full (b, s, V) logits tensor
        never materializes — the head matmul + logsumexp + label gather run
        per chunk inside a scan (beyond-paper memory-term optimization)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        with dispatch.scope(cfg.use_pallas):
            x = self._embed(params, batch)
            b, s, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            enc_out = (self._encode(params, batch)
                       if cfg.family == "audio" else None)
            x, aux = apply_stack(cfg, params["stack"], x,
                                 adapters=self._stack_adapters(adapters),
                                 positions=positions, enc_out=enc_out,
                                 causal=cfg.family != "encoder")
            x = apply_norm(cfg, x, params, "final")
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        s_text = tokens.shape[1]
        x = x[:, -s_text:][:, :-1]                    # predict positions
        labels = tokens[:, 1:]
        sl = x.shape[1]
        c = min(chunk, sl)
        pad = (-sl) % c
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        lp = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(jnp.ones((b, sl), bool), ((0, 0), (0, pad)))
        nc = xp.shape[1] // c
        xc = xp.reshape(b, nc, c, -1).swapaxes(0, 1)
        lc = lp.reshape(b, nc, c).swapaxes(0, 1)
        vc = valid.reshape(b, nc, c).swapaxes(0, 1)

        def chunk_step(tot, xs):
            xb, lb, vb = xs
            logits = (xb @ head.astype(xb.dtype)).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lb[..., None], -1)[..., 0]
            return tot + jnp.sum((lse - ll) * vb), None

        tot, _ = jax.lax.scan(jax.checkpoint(chunk_step),
                              jnp.zeros((), jnp.float32), (xc, lc, vc))
        ce = tot / (b * sl)
        return ce + aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        cross = cfg.encoder_frames if cfg.family == "audio" else 0
        return init_stack_cache(cfg, batch, max_len, dtype, cross_len=cross)

    def init_paged_cache(self, num_blocks: int, block_size: int, batch: int,
                         dtype=None):
        """Paged serving cache: per-layer KV pools of ``num_blocks`` x
        ``block_size`` slots shared by every request through per-request
        block tables, plus per-slot recurrent/cross state for ``batch``
        engine slots.  Block 0 is reserved as the null block idle slots
        write into (see launch/serve.py's allocator)."""
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        cross = cfg.encoder_frames if cfg.family == "audio" else 0
        return init_paged_stack_cache(cfg, num_blocks, block_size, batch,
                                      dtype, cross_len=cross)

    def prefill(self, params, cache, tokens, adapters=None, *, enc_out=None,
                last_only=False, table=None):
        """Whole-prompt forward that fills a FRESH cache in one batched
        pass: tokens (b, p) int32 -> (logits (b, p, V), new_cache).
        ``last_only=True`` projects only the final position through the
        lm head (logits (b, 1, V)) — generation consumes just that row, and
        at real vocab scale the head GEMM over every prompt position is the
        prefill's dominant wasted work.

        The cache comes back as ``p`` sequential :meth:`decode_step` calls
        would have left it (KV ring-buffer slots, recurrence states, conv
        tails), so generation is one prefill + a decode loop instead of
        feeding the prompt through single-token steps.  ``adapters`` as in
        decode_step — None, an AdapterSet, or a banked per-request set from
        ``AdapterBank.gather``/``requests``.  Encoder-decoder (audio)
        models pass the encoder output as ``enc_out`` so the per-layer
        cross K/V land in the cache.  A paged cache (``init_paged_cache``)
        additionally needs the requests' block ``table``."""
        adapters = as_adapter_set(adapters)
        cfg = self.cfg
        with dispatch.scope(cfg.use_pallas):
            x = jnp.take(params["embed"], tokens,
                         axis=0).astype(jnp.dtype(cfg.dtype))
            b, s, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            x, _, new_cache = prefill_stack(
                cfg, params["stack"], cache, x, positions,
                adapters=self._stack_adapters(adapters), enc_out=enc_out,
                table=table)
            x = apply_norm(cfg, x, params, "final")
            if last_only:
                x = x[:, -1:]
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            logits = x @ head.astype(x.dtype)
        return logits, new_cache

    def decode_step(self, params, cache, token, pos, adapters=None, *,
                    table=None):
        """One token: token (b,1) int32, pos (b,) absolute position.
        Returns (logits (b,1,V), new_cache).

        ``adapters`` may be a single AdapterSet or a ``batched`` one from
        ``AdapterBank.gather`` (one adapter per batch row — multi-tenant
        serving).  A paged cache additionally needs the requests' block
        ``table`` (b, blocks_per_req) int32."""
        adapters = as_adapter_set(adapters)
        cfg = self.cfg
        with dispatch.scope(cfg.use_pallas):
            x = jnp.take(params["embed"], token,
                         axis=0).astype(jnp.dtype(cfg.dtype))
            x, new_cache = decode_stack(cfg, params["stack"], cache, x, pos,
                                        adapters=self._stack_adapters(
                                            adapters),
                                        table=table)
            x = apply_norm(cfg, x, params, "final")
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            logits = x @ head.astype(x.dtype)
        return logits, new_cache

    # ------------------------------------------------------------- specs
    def input_specs(self, shape, *, n_clients: int = 0, dtype=None):
        """ShapeDtypeStruct stand-ins for every model input of an InputShape.

        For train shapes with ``n_clients``>0 the batch gets a leading client
        dim (global_batch = n_clients * per_client).  Modality frontends are
        stubs: precomputed frame/patch embeddings of the right shape."""
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        def batch_spec(b, s):
            d = {"tokens": sds((b, s), i32)}
            if cfg.family == "vlm":
                d["tokens"] = sds((b, s - cfg.num_patches), i32)
                d["patches"] = sds((b, cfg.num_patches, PATCH_EMBED_DIM), dt)
            if cfg.family == "audio":
                d["frames"] = sds((b, cfg.encoder_frames, cfg.d_model), dt)
            return d

        if shape.kind == "train":
            b, s = shape.global_batch, shape.seq_len
            if n_clients:
                per = b // n_clients
                spec = batch_spec(per, s)
                return {k: sds((n_clients,) + v.shape, v.dtype)
                        for k, v in spec.items()}
            return batch_spec(b, s)
        if shape.kind == "prefill":
            return batch_spec(shape.global_batch, shape.seq_len)
        # decode: one token + cache of seq_len
        b = shape.global_batch
        cache = jax.eval_shape(
            lambda: self.init_cache(b, shape.seq_len, dtype=dt))
        return {"token": sds((b, 1), i32), "pos": sds((b,), i32),
                "cache": cache}


def build_model(cfg) -> Model:
    return Model(cfg)

# Dry-run switch: XLA's HloCostAnalysis counts while-loop bodies ONCE (no
# trip-count multiplication), so scanned-layer models under-report flops/bytes
# /collectives.  The dry-run sets FULL_UNROLL=True to unroll the layer stack,
# attention block loops, and mLSTM chunk scans, making the compiled-module
# statistics exact.  Training/serving keep scans (compact HLO).
FULL_UNROLL = False


def scan_unroll(length: int) -> int:
    return length if FULL_UNROLL else 1

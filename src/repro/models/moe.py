"""Mixture-of-Experts MLP: top-k routing, capacity-based scatter dispatch,
shared (always-on) experts, and a Switch-style load-balance auxiliary loss.

Expert weights are stacked ``(E, d, ff)`` so the expert dim shards over the
``model`` mesh axis (expert parallelism); token->expert dispatch is a scatter
that GSPMD lowers to all-to-all style collectives on the mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.specs import constrain


def padded_experts(m) -> int:
    """Pad the expert count to a multiple of 16 for clean 16-way expert
    parallelism on the `model` mesh axis (e.g. qwen2-moe 60 -> 64).  The
    router stays at the logical count, so padded experts never receive
    tokens."""
    e = m.num_experts
    return e if e <= 16 else -(-e // 16) * 16


def moe_params(cfg, key):
    m = cfg.moe
    d = cfg.d_model
    pdt = jnp.dtype(cfg.param_dtype)
    kr, kg, ku, kd, ksg, ksu, ksd, kgt = jax.random.split(key, 8)
    e = padded_experts(m)
    ff = m.d_ff_expert
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(kr, (d, m.num_experts)) * s).astype(pdt),
        "w_gate": (jax.random.normal(kg, (e, d, ff)) * s).astype(pdt),
        "w_up": (jax.random.normal(ku, (e, d, ff)) * s).astype(pdt),
        "w_down": (jax.random.normal(kd, (e, ff, d)) * (ff ** -0.5)).astype(pdt),
    }
    if m.num_shared_experts:
        sf = m.d_ff_shared
        p["shared_gate"] = (jax.random.normal(ksg, (d, sf)) * s).astype(pdt)
        p["shared_up"] = (jax.random.normal(ksu, (d, sf)) * s).astype(pdt)
        p["shared_down"] = (jax.random.normal(ksd, (sf, d)) * (sf ** -0.5)).astype(pdt)
        p["shared_router"] = (jax.random.normal(kgt, (d, 1)) * s).astype(pdt)
    return p


def moe_apply(cfg, params, x, adapters=None):
    """x (b, s, d) -> (out (b, s, d), aux_loss scalar).

    ``adapters`` (an AdapterSet node in prepared form) is accepted for API
    uniformity with the other block kinds and reserved for adapter-on-expert
    variants — no current config targets expert projections."""
    from repro.sharding.opts import enabled
    if enabled("moe_grouped"):
        return _moe_apply_grouped(cfg, params, x)
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    ep = padded_experts(m)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (t, e)
    top_p, top_i = jax.lax.top_k(probs, k)                       # (t, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux (Switch): E * sum_e frac_tokens_e * mean_prob_e
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)          # (t, k, e)
    assign = onehot.sum(1)                                        # (t, e)
    frac = assign.mean(0)
    aux = e * jnp.sum(frac * probs.mean(0)) * m.router_aux_coef

    # ---- capacity + position-in-expert
    cap = max(1, int(t * k / e * m.capacity_factor))
    cap = -(-cap // 8) * 8                                        # align
    pos = (jnp.cumsum(assign, axis=0) - 1)                        # (t, e) position
    pos_k = jnp.take_along_axis(pos, top_i, axis=1).astype(jnp.int32)  # (t, k)
    keep = (pos_k < cap)
    w = jnp.where(keep, top_p, 0.0)                               # (t, k)

    # ---- scatter tokens into (ep*cap, d) expert buffers
    flat_idx = jnp.where(keep, top_i * cap + pos_k, ep * cap)     # drop -> OOB slot
    buf = jnp.zeros((ep * cap + 1, d), xf.dtype)
    src = jnp.repeat(xf[:, None, :], k, axis=1).reshape(t * k, d)
    buf = buf.at[flat_idx.reshape(-1)].add(src)
    ex_in = buf[:-1].reshape(ep, cap, d)
    ex_in = constrain(ex_in, ("model", None, None))

    # ---- expert FFN (swiglu), expert dim sharded over `model`
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"])
    ex_out = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])
    ex_out = constrain(ex_out, ("model", None, None))

    # ---- gather back and combine with routing weights
    flat_out = jnp.concatenate(
        [ex_out.reshape(ep * cap, d), jnp.zeros((1, d), ex_out.dtype)], 0)
    tok_out = flat_out[flat_idx]                                  # (t, k, d)
    out = jnp.einsum("tkd,tk->td", tok_out.astype(jnp.float32),
                     w.astype(jnp.float32))

    # ---- shared experts (always on)
    if m.num_shared_experts:
        sg = jax.nn.silu(xf @ params["shared_gate"]) * (xf @ params["shared_up"])
        sh = sg @ params["shared_down"]
        gate = jax.nn.sigmoid(xf.astype(jnp.float32) @
                              params["shared_router"].astype(jnp.float32))
        out = out + gate * sh.astype(jnp.float32)

    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_apply_grouped(cfg, params, x):
    """Grouped dispatch (beyond-paper, GShard-style): each batch row is a
    routing group with its own capacity, so the position-in-expert cumsum and
    the dispatch scatter are group-local.  Buffers shard 2D:
    (group->data, expert->model) — the global-cumsum serialization and the
    cross-shard scatter all-reduce of the flat path disappear."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    ep = padded_experts(m)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                       # (b, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)          # (b, s, k, e)
    assign = onehot.sum(2)                                        # (b, s, e)
    frac = assign.mean((0, 1))
    aux = e * jnp.sum(frac * probs.mean((0, 1))) * m.router_aux_coef

    cap = max(1, int(s * k / e * m.capacity_factor))
    cap = -(-cap // 8) * 8
    pos = jnp.cumsum(assign, axis=1) - 1                          # per group
    pos_k = jnp.take_along_axis(pos, top_i, axis=2).astype(jnp.int32)
    keep = pos_k < cap
    w = jnp.where(keep, top_p, 0.0)

    flat_idx = jnp.where(keep, top_i * cap + pos_k, ep * cap)     # (b, s, k)
    flat_idx = flat_idx.reshape(b, s * k)
    src = jnp.repeat(x[:, :, None, :], k, axis=2).reshape(b, s * k, d)
    buf = jnp.zeros((b, ep * cap + 1, d), x.dtype)
    buf = buf.at[jnp.arange(b)[:, None], flat_idx].add(src)
    ex_in = buf[:, :-1].reshape(b, ep, cap, d)
    ex_in = constrain(ex_in, (("pod", "data"), "model", None, None))

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", ex_in, params["w_gate"]))
    u = jnp.einsum("becd,edf->becf", ex_in, params["w_up"])
    ex_out = jnp.einsum("becf,efd->becd", g * u, params["w_down"])
    ex_out = constrain(ex_out, (("pod", "data"), "model", None, None))

    flat_out = jnp.concatenate(
        [ex_out.reshape(b, ep * cap, d),
         jnp.zeros((b, 1, d), ex_out.dtype)], axis=1)
    tok_out = jnp.take_along_axis(flat_out, flat_idx[..., None], axis=1)
    tok_out = tok_out.reshape(b, s, k, d)
    out = jnp.einsum("bskd,bsk->bsd", tok_out.astype(jnp.float32),
                     w.astype(jnp.float32))

    if m.num_shared_experts:
        xf = x.reshape(b * s, d)
        sg = jax.nn.silu(xf @ params["shared_gate"]) * (xf @ params["shared_up"])
        sh = (sg @ params["shared_down"]).reshape(b, s, d)
        gate = jax.nn.sigmoid(jnp.einsum(
            "bsd,do->bso", x.astype(jnp.float32),
            params["shared_router"].astype(jnp.float32)))
        out = out + gate * sh.astype(jnp.float32)

    return out.astype(x.dtype), aux

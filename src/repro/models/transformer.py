"""Generic block-stack transformer machinery.

A model is a stack of blocks drawn from ``cfg.block_pattern`` (repeated to
``num_layers``).  Full repeats of the pattern run under one ``jax.lax.scan``
(stacked params — keeps HLO size independent of depth); the remainder runs
unrolled.  Every block kind supports:

  init_block(cfg, key, kind)                      -> params pytree
  apply_block(..., mode="fullseq")                -> (x, aux)
  init_block_cache(cfg, kind, batch, max_len)     -> cache pytree
  apply_block(..., mode="decode", cache=, pos=)   -> (x, aux, cache)

Kinds: "attn" (GQA attention + MLP/MoE), "xattn" (self+cross attention + MLP,
for encoder-decoder), "rglru" (RG-LRU temporal mix + MLP), "mlstm", "slstm".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import scan_unroll
from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import (attn_params, attention_fullseq,
                                    attention_decode, attention_decode_paged,
                                    attention_prefill,
                                    attention_prefill_paged, init_kv_cache,
                                    init_paged_kv_cache, _project_qkv,
                                    attention_core, make_mask)
from repro.models.layers import (apply_norm, linear, mlp_apply, mlp_params,
                                 norm_params)
from repro.models.moe import moe_apply, moe_params


# ----------------------------------------------------------------- block init

def init_block(cfg, key, kind: str):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {}
    if kind in ("attn", "xattn"):
        p.update(norm_params(cfg, d, "ln1"))
        p["attn"] = attn_params(cfg, ks[0])
        if kind == "xattn":
            p.update(norm_params(cfg, d, "lnx"))
            p["cross"] = attn_params(cfg, ks[1])
        p.update(norm_params(cfg, d, "ln2"))
        if cfg.moe is not None:
            p["moe"] = moe_params(cfg, ks[2])
        else:
            p["mlp"] = mlp_params(cfg, ks[2], d, cfg.d_ff)
    elif kind == "rglru":
        p.update(norm_params(cfg, d, "ln1"))
        p["rglru"] = rglru_mod.rglru_params(cfg, ks[0])
        p.update(norm_params(cfg, d, "ln2"))
        p["mlp"] = mlp_params(cfg, ks[1], d, cfg.d_ff)
    elif kind == "mlstm":
        p.update(norm_params(cfg, d, "ln1"))
        p["mlstm"] = xlstm_mod.mlstm_params(cfg, ks[0])
    elif kind == "slstm":
        p.update(norm_params(cfg, d, "ln1"))
        p["slstm"] = xlstm_mod.slstm_params(cfg, ks[0])
    else:
        raise ValueError(kind)
    return p


def init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype,
                     cross_len: int = 0):
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "xattn":
        return {"self": init_kv_cache(cfg, batch, max_len, dtype),
                "cross_k": jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                                      cfg.head_dim), dtype),
                "cross_v": jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                                      cfg.head_dim), dtype)}
    if kind == "rglru":
        return rglru_mod.rglru_init_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.mlstm_init_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm_mod.slstm_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_paged_block_cache(cfg, kind: str, num_blocks: int, block_size: int,
                           batch: int, dtype, cross_len: int = 0):
    """Paged-serving counterpart of :func:`init_block_cache`: attention KV
    moves into a shared block pool (no batch dim — requests own pool blocks
    through the block table), while recurrent blocks and cross-attention K/V
    carry constant-size PER-SLOT state (``batch`` = engine slot count) —
    admit/evict for those is a slot-level state swap, not paging."""
    if kind == "attn":
        return init_paged_kv_cache(cfg, num_blocks, block_size, dtype)
    if kind == "xattn":
        return {"self": init_paged_kv_cache(cfg, num_blocks, block_size,
                                            dtype),
                "cross_k": jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                                      cfg.head_dim), dtype),
                "cross_v": jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                                      cfg.head_dim), dtype)}
    return init_block_cache(cfg, kind, batch, 0, dtype, cross_len=cross_len)


# ----------------------------------------------------------------- block apply

def _cross_attention(cfg, params, x, ck, cv, adapters=None):
    """Cross-attention against precomputed encoder K/V (no masking, no RoPE)."""
    b, s, _ = x.shape
    lq = (adapters or {}).get("q")
    q = linear(x, params["q"], lq).reshape(b, s, cfg.num_heads,
                                           cfg.head_dim)
    mask = jnp.ones((b, s, ck.shape[1]), bool)
    out = attention_core(cfg, q, ck, cv, mask)
    return linear(out.reshape(b, s, -1), params["o"],
                  (adapters or {}).get("o"))


def build_cross_kv(cfg, p_cross, enc_out):
    """Project encoder output to per-layer cross K/V (no RoPE)."""
    b, t, _ = enc_out.shape
    k = linear(enc_out, p_cross["k"]).reshape(b, t, cfg.num_kv_heads,
                                              cfg.head_dim)
    v = linear(enc_out, p_cross["v"]).reshape(b, t, cfg.num_kv_heads,
                                              cfg.head_dim)
    return k, v


def apply_block(cfg, kind, p, x, *, adapters=None, positions=None,
                causal=True, mode="fullseq", cache=None, pos=None,
                enc_out=None, table=None):
    """``mode``: "fullseq" (train/encode — no cache), "prefill" (whole
    prompt in one pass, cache filled as the token-by-token decode would
    have), "decode" (one token against the cache).  Prefill and decode
    return (x, aux, new_cache); fullseq returns (x, aux).

    A paged attention cache (``k_pool`` pool leaves instead of per-request
    ``k`` rings — see models/attention.py) routes to the paged prefill /
    decode paths; ``table`` (b, blocks_per_req) int32 is required then and
    ignored otherwise.  Non-attention state is per-slot either way."""
    adapters = adapters or {}
    aux = jnp.zeros((), jnp.float32)
    h1 = apply_norm(cfg, x, p, "ln1")
    new_cache = None

    if kind in ("attn", "xattn"):
        self_cache = (None if cache is None
                      else cache["self"] if kind == "xattn" else cache)
        paged = self_cache is not None and "k_pool" in self_cache
        if mode == "fullseq":
            a = attention_fullseq(cfg, p["attn"], h1, causal=causal,
                                  adapters=adapters.get("attn"),
                                  positions=positions)
        elif mode == "prefill":
            if paged:
                a, self_cache = attention_prefill_paged(
                    cfg, p["attn"], h1, self_cache, positions, table,
                    adapters=adapters.get("attn"))
            else:
                a, self_cache = attention_prefill(
                    cfg, p["attn"], h1, self_cache, positions,
                    adapters=adapters.get("attn"))
        else:
            if paged:
                a, self_cache = attention_decode_paged(
                    cfg, p["attn"], h1, self_cache, table, pos,
                    adapters=adapters.get("attn"))
            else:
                a, self_cache = attention_decode(
                    cfg, p["attn"], h1, self_cache,
                    pos, adapters=adapters.get("attn"))
        x = x + a
        if kind == "xattn":
            hx = apply_norm(cfg, x, p, "lnx")
            if mode == "decode" or (mode == "prefill" and enc_out is None):
                # decode reads the cache's cross K/V; prefill without an
                # encoder output keeps them too (the token-by-token path's
                # semantics: a fresh cache cross-attends zeros)
                ck, cv = cache["cross_k"], cache["cross_v"]
            else:
                ck, cv = build_cross_kv(cfg, p["cross"], enc_out)
            x = x + _cross_attention(cfg, p["cross"], hx, ck, cv,
                                     adapters=adapters.get("cross"))
        h2 = apply_norm(cfg, x, p, "ln2")
        if cfg.moe is not None:
            mo, aux = moe_apply(cfg, p["moe"], h2,
                                adapters=adapters.get("moe"))
            x = x + mo
        else:
            x = x + mlp_apply(cfg, p["mlp"], h2,
                              adapters=adapters.get("mlp"))
        if mode != "fullseq":
            new_cache = ({"self": self_cache, "cross_k": ck, "cross_v": cv}
                         if kind == "xattn" else self_cache)

    elif kind == "rglru":
        if mode == "fullseq":
            r = rglru_mod.rglru_apply_fullseq(cfg, p["rglru"], h1,
                                              adapters.get("rglru"))
        elif mode == "prefill":
            r, new_cache = rglru_mod.rglru_apply_prefill(
                cfg, p["rglru"], h1, cache, positions, adapters.get("rglru"))
        else:
            r, new_cache = rglru_mod.rglru_apply_decode(
                cfg, p["rglru"], h1, cache, pos, adapters.get("rglru"))
        x = x + r
        h2 = apply_norm(cfg, x, p, "ln2")
        x = x + mlp_apply(cfg, p["mlp"], h2)

    elif kind == "mlstm":
        if mode == "fullseq":
            m = xlstm_mod.mlstm_apply_fullseq(cfg, p["mlstm"], h1,
                                              adapters.get("mlstm"))
        elif mode == "prefill":
            m, new_cache = xlstm_mod.mlstm_apply_prefill(
                cfg, p["mlstm"], h1, cache, positions, adapters.get("mlstm"))
        else:
            m, new_cache = xlstm_mod.mlstm_apply_decode(
                cfg, p["mlstm"], h1, cache, pos, adapters.get("mlstm"))
        x = x + m

    elif kind == "slstm":
        if mode == "fullseq":
            s_ = xlstm_mod.slstm_apply_fullseq(cfg, p["slstm"], h1,
                                               adapters.get("slstm"))
        elif mode == "prefill":
            s_, new_cache = xlstm_mod.slstm_apply_prefill(
                cfg, p["slstm"], h1, cache, positions, adapters.get("slstm"))
        else:
            s_, new_cache = xlstm_mod.slstm_apply_decode(
                cfg, p["slstm"], h1, cache, pos, adapters.get("slstm"))
        x = x + s_
    else:
        raise ValueError(kind)

    if mode == "fullseq":
        return x, aux
    return x, aux, new_cache


# ----------------------------------------------------------------- the stack

def stack_layout(num_layers: int, pattern):
    m = len(pattern)
    return num_layers // m, tuple(pattern[:num_layers % m])


def init_stack(cfg, key, *, num_layers=None, pattern=None):
    num_layers = num_layers or cfg.num_layers
    pattern = pattern or cfg.block_pattern
    repeats, tail = stack_layout(num_layers, pattern)
    k_rep, k_tail = jax.random.split(key)
    out = {"repeat": {}, "tail": {}}
    if repeats:
        for j, kind in enumerate(pattern):
            keys = jax.random.split(jax.random.fold_in(k_rep, j), repeats)
            out["repeat"][f"p{j}"] = jax.vmap(
                lambda k, kd=kind: init_block(cfg, k, kd))(keys)
    for i, kind in enumerate(tail):
        out["tail"][f"t{i}"] = init_block(cfg, jax.random.fold_in(k_tail, i),
                                          kind)
    return out


def init_stack_cache(cfg, batch, max_len, dtype, *, num_layers=None,
                     pattern=None, cross_len=0):
    num_layers = num_layers or cfg.num_layers
    pattern = pattern or cfg.block_pattern
    repeats, tail = stack_layout(num_layers, pattern)
    mk = lambda kind: init_block_cache(cfg, kind, batch, max_len, dtype,
                                       cross_len=cross_len)
    out = {"repeat": {}, "tail": {}}
    if repeats:
        for j, kind in enumerate(pattern):
            out["repeat"][f"p{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (repeats,) + a.shape).copy(),
                mk(kind))
    for i, kind in enumerate(tail):
        out["tail"][f"t{i}"] = mk(kind)
    return out


def init_paged_stack_cache(cfg, num_blocks, block_size, batch, dtype, *,
                           num_layers=None, pattern=None, cross_len=0):
    """Stack cache for the paged serving engine: attention layers hold
    SHARED pools (repeat leaves gain a leading layer dim as usual), every
    other layer kind holds per-slot state for ``batch`` engine slots."""
    num_layers = num_layers or cfg.num_layers
    pattern = pattern or cfg.block_pattern
    repeats, tail = stack_layout(num_layers, pattern)
    mk = lambda kind: init_paged_block_cache(cfg, kind, num_blocks,
                                             block_size, batch, dtype,
                                             cross_len=cross_len)
    out = {"repeat": {}, "tail": {}}
    if repeats:
        for j, kind in enumerate(pattern):
            out["repeat"][f"p{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (repeats,) + a.shape).copy(),
                mk(kind))
    for i, kind in enumerate(tail):
        out["tail"][f"t{i}"] = mk(kind)
    return out


# The scheduler's cache surgery (launch/serve.py): a paged stack cache mixes
# two leaf families — SHARED pool subtrees (dicts holding "k_pool", no batch
# dim beyond the repeat-layer one) and PER-SLOT leaves (batch axis 0 in the
# tail, axis 1 under the repeat stacking).  Admission prefills newcomers on a
# view whose pools ARE the engine pools (their functional update only
# touches the newcomers' blocks) and whose per-slot leaves are fresh inits
# at the group size, then the merge takes pool subtrees wholesale and
# scatters per-slot leaves into the newcomers' slots.


def _walk_paged(c, v, fn_pool, fn_leaf, axis):
    if isinstance(c, dict):
        if "k_pool" in c:
            return fn_pool(c, None if v is None else v, axis)
        return {k: _walk_paged(c[k], None if v is None else v[k],
                               fn_pool, fn_leaf, axis) for k in c}
    return fn_leaf(c, v, axis)


def _map_paged_cache(cache, view, fn_pool, fn_leaf):
    return {"repeat": {k: _walk_paged(cache["repeat"][k],
                                      None if view is None
                                      else view["repeat"][k],
                                      fn_pool, fn_leaf, 1)
                       for k in cache["repeat"]},
            "tail": {k: _walk_paged(cache["tail"][k],
                                    None if view is None
                                    else view["tail"][k],
                                    fn_pool, fn_leaf, 0)
                     for k in cache["tail"]}}


def paged_prefill_view(cfg, cache, batch, dtype, *, num_layers=None,
                       pattern=None, cross_len=0):
    """Admission view: engine pools shared, fresh per-slot state for a
    ``batch``-request newcomer group (zero recurrences, -1e30 stabilizer
    states — exactly what a fresh fixed-batch cache would hold)."""
    fresh = init_paged_stack_cache(cfg, 1, 1, batch, dtype,
                                   num_layers=num_layers, pattern=pattern,
                                   cross_len=cross_len)
    return _map_paged_cache(cache, fresh,
                            lambda c, v, axis: c,
                            lambda c, v, axis: v)


def merge_paged_cache(cache, view, slots):
    """Fold an admission view back into the engine cache: pool subtrees
    come back wholesale (only the newcomers' blocks changed), per-slot
    leaves scatter into the newcomers' ``slots``."""
    return _map_paged_cache(
        cache, view,
        lambda c, v, axis: v,
        lambda c, v, axis: (c.at[slots].set(v) if axis == 0
                            else c.at[:, slots].set(v)))


def reset_paged_blocks(cache, blocks):
    """Invalidate ``blocks`` (1-D int32) in every layer's pos pool before
    reuse: freed blocks keep stale ``pos >= 0`` entries that the validity
    mask would otherwise re-admit into a new owner's attention."""
    def pool(c, v, axis):
        pp = (c["pos_pool"].at[blocks].set(-1) if axis == 0
              else c["pos_pool"].at[:, blocks].set(-1))
        return {**c, "pos_pool": pp}
    return _map_paged_cache(cache, None, pool, lambda c, v, axis: c)


def apply_stack(cfg, stack_params, x, *, adapters=None, positions=None,
                causal=True, pattern=None, remat=True, enc_out=None):
    """Full-sequence forward.  Returns (x, aux_sum).

    ``adapters`` is the prepared "stack" subtree of an AdapterSet (scaling
    folded, mask applied); banked per-request trees must be in scan layout
    (see :func:`batched_scan_layout`)."""
    pattern = pattern or cfg.block_pattern
    adapters = adapters or {}
    rep_p = stack_params.get("repeat", {})
    rep_lora = adapters.get("repeat") or _empty_like_stack(rep_p)

    def one_rep(h, xs):
        ps, los = xs
        from repro.sharding import opts as _opts
        if _opts.enabled("seq_parallel_residual"):
            from repro.sharding.specs import constrain as _constrain
            h = _constrain(h, (None, "model", None))
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pattern):
            h, a = apply_block(cfg, kind, ps[f"p{j}"], h,
                               adapters=los.get(f"p{j}"),
                               positions=positions, causal=causal,
                               enc_out=enc_out)
            aux = aux + a
        return h, aux

    aux_total = jnp.zeros((), jnp.float32)
    if rep_p:
        from repro.sharding import opts
        if remat and opts.enabled("remat_dots"):
            # save matmul outputs across the scan, recompute only elementwise
            body = jax.checkpoint(
                one_rep,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat:
            body = jax.checkpoint(one_rep)
        else:
            body = one_rep
        n_rep = jax.tree.leaves(rep_p)[0].shape[0]
        x, auxs = jax.lax.scan(body, x, (rep_p, rep_lora),
                               unroll=scan_unroll(n_rep))
        aux_total = aux_total + auxs.sum()
    kinds = _tail_kinds(cfg, pattern, stack_params)
    for i, kind in enumerate(kinds):
        x, a = apply_block(cfg, kind, stack_params["tail"][f"t{i}"], x,
                           adapters=(adapters.get("tail") or {}).get(f"t{i}"),
                           positions=positions, causal=causal,
                           enc_out=enc_out)
        aux_total = aux_total + a
    return x, aux_total


def _tail_kinds(cfg, pattern, stack_params):
    n_tail = len(stack_params.get("tail") or {})
    return tuple(pattern[:n_tail])


def prefill_stack(cfg, stack_params, cache, x, positions, *, adapters=None,
                  pattern=None, enc_out=None, table=None):
    """Whole-prompt forward that also fills every block cache in ONE pass —
    the batched replacement for feeding the prompt through single-token
    decode steps.  Returns (x, aux_sum, new_cache); the cache comes back
    exactly as the token-by-token decode would have left it (KV ring-buffer
    slots or pool blocks, recurrence states, conv tails).  ``table`` routes
    paged attention caches; it is the same for every layer (each layer has
    its own pool of identical geometry), so it rides the scan closure."""
    pattern = pattern or cfg.block_pattern
    adapters = adapters or {}
    rep_p = stack_params.get("repeat", {})
    rep_lora = adapters.get("repeat") or _empty_like_stack(rep_p)

    def scan_body(h, xs):
        ps, los, cs = xs
        new_cs = {}
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pattern):
            h, a, nc = apply_block(cfg, kind, ps[f"p{j}"], h,
                                   adapters=los.get(f"p{j}"),
                                   positions=positions, mode="prefill",
                                   cache=cs[f"p{j}"], enc_out=enc_out,
                                   table=table)
            new_cs[f"p{j}"] = nc
            aux = aux + a
        return h, (new_cs, aux)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"repeat": {}, "tail": {}}
    if rep_p:
        n_rep = jax.tree.leaves(rep_p)[0].shape[0]
        x, (new_cache["repeat"], auxs) = jax.lax.scan(
            scan_body, x, (rep_p, rep_lora, cache["repeat"]),
            unroll=scan_unroll(n_rep))
        aux_total = aux_total + auxs.sum()
    kinds = _tail_kinds(cfg, pattern, stack_params)
    for i, kind in enumerate(kinds):
        key = f"t{i}"
        x, a, nc = apply_block(cfg, kind, stack_params["tail"][key], x,
                               adapters=(adapters.get("tail") or {}).get(key),
                               positions=positions, mode="prefill",
                               cache=cache["tail"][key], enc_out=enc_out,
                               table=table)
        new_cache["tail"][key] = nc
        aux_total = aux_total + a
    return x, aux_total, new_cache


def decode_stack(cfg, stack_params, cache, x, pos, *, adapters=None,
                 pattern=None, table=None):
    """One-token decode through the stack.  Returns (x, new_cache)."""
    pattern = pattern or cfg.block_pattern
    adapters = adapters or {}
    rep_p = stack_params.get("repeat", {})
    rep_lora = adapters.get("repeat") or _empty_like_stack(rep_p)

    def scan_body(h, xs):
        ps, los, cs = xs
        new_cs = {}
        for j, kind in enumerate(pattern):
            h, _, nc = apply_block(cfg, kind, ps[f"p{j}"], h,
                                   adapters=los.get(f"p{j}"),
                                   mode="decode", cache=cs[f"p{j}"], pos=pos,
                                   table=table)
            new_cs[f"p{j}"] = nc
        return h, new_cs

    new_cache = {"repeat": {}, "tail": {}}
    if rep_p:
        n_rep = jax.tree.leaves(rep_p)[0].shape[0]
        x, new_cache["repeat"] = jax.lax.scan(
            scan_body, x, (rep_p, rep_lora, cache["repeat"]),
            unroll=scan_unroll(n_rep))
    kinds = _tail_kinds(cfg, pattern, stack_params)
    for i, kind in enumerate(kinds):
        key = f"t{i}"
        x, _, nc = apply_block(cfg, kind, stack_params["tail"][key], x,
                               adapters=(adapters.get("tail") or {}).get(key),
                               mode="decode",
                               cache=cache["tail"][key], pos=pos, table=table)
        new_cache["tail"][key] = nc
    return x, new_cache


def _empty_like_stack(rep_p):
    """LoRA-free stand-in (no leaves, scans alongside params)."""
    return {k: {} for k in rep_p}


def batched_scan_layout(stack_adapters):
    """Reorder a banked per-request adapter tree for the layer scans.

    ``AdapterBank.gather`` puts the request dim first on every leaf; the
    repeated blocks scan over their layer dim, which must lead.  Swap the
    (request, layer) axes on the "repeat" subtree only — tail leaves carry
    no layer dim and stay request-leading, which is exactly the 3-D
    per-request shape the dispatch layer's batched path expects."""
    if not stack_adapters:
        return stack_adapters
    out = dict(stack_adapters)
    rep = stack_adapters.get("repeat")
    if rep:
        out["repeat"] = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), rep)
    return out


def _attach_ids(tree, ids):
    """Insert the request->tenant map into every adapter node of a LAZY bank
    tree: ``{"a", "b"}`` nodes become ``{"a", "b", "ids"}`` — the layout the
    dispatch layer's banked path consumes."""
    def walk(node):
        if isinstance(node, dict):
            if node and set(node) <= {"a", "b"}:
                return {**node, "ids": ids}
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def banked_scan_layout(stack_adapters, ids):
    """Scan layout for a LAZY bank tree (``AdapterBank.requests``): leaves
    stay tenant-stacked ``(K, ...)`` and ``ids`` (B,) maps batch rows to
    tenants.

    Repeat leaves ``(K, layers, ...)`` swap to ``(layers, K, ...)`` so the
    layer scans slice one ``(K, ...)`` bank page per layer; ``ids``
    broadcasts to ``(layers, B)`` so every scan step carries the same
    request map.  The bank itself is never gathered here — each projection
    gathers (or the BGMV kernel's index_map does) from its own ``(K, ...)``
    page."""
    if not stack_adapters:
        return stack_adapters
    out = dict(stack_adapters)
    rep = stack_adapters.get("repeat")
    if rep:
        swapped = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), rep)
        n_rep = jax.tree.leaves(swapped)[0].shape[0]
        out["repeat"] = _attach_ids(
            swapped, jnp.broadcast_to(ids, (n_rep,) + ids.shape))
    tail = stack_adapters.get("tail")
    if tail:
        out["tail"] = _attach_ids(tail, ids)
    return out

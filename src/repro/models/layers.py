"""Shared neural-net building blocks (pure functions over explicit pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import lora_linear


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x, params, prefix: str):
    if cfg.norm == "layernorm":
        return layer_norm(x, params[f"{prefix}_scale"], params[f"{prefix}_bias"])
    return rms_norm(x, params[f"{prefix}_scale"])


def norm_params(cfg, d: int, prefix: str):
    p = {f"{prefix}_scale": jnp.ones((d,), _pdt(cfg))}
    if cfg.norm == "layernorm":
        p[f"{prefix}_bias"] = jnp.zeros((d,), _pdt(cfg))
    return p


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------- RoPE

def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., s) int -> cos/sin (..., s, head_dim//2) float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x (..., s, h, hd); positions broadcastable to (..., s)."""
    cos, sin = rope_angles(positions, x.shape[-1], theta)
    cos = cos[..., None, :]   # (..., s, 1, half)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    emb = jnp.zeros((length, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# --------------------------------------------------------------------------- MLP

def mlp_params(cfg, key, d_in: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_in ** -0.5
    s_ff = d_ff ** -0.5
    pdt = _pdt(cfg)
    p = {"w_up": (jax.random.normal(k2, (d_in, d_ff)) * s_in).astype(pdt),
         "w_down": (jax.random.normal(k3, (d_ff, d_in)) * s_ff).astype(pdt)}
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d_in, d_ff)) * s_in).astype(pdt)
    return p


def mlp_apply(cfg, params, x, adapters=None):
    """Gated MLP.  ``adapters`` reserved for adapter-on-mlp variants.  The
    three GEMMs route through ``linear`` so a quantized frozen base
    (core/quant.py packed leaves) hits the dequant-in-VMEM kernel tier; with
    fp leaves ``linear`` reduces to the same single XLA GEMM as before."""
    up = linear(x, params["w_up"])
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(linear(x, params["w_gate"])) * up
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(linear(x, params["w_gate"]), approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return linear(h, params["w_down"])


def linear(x, w, adapters=None):
    """y = x W (+ (x A^T) B^T) — the LoRA-aware projection primitive.

    ``adapters`` is ``{"a": (r, d_in), "b": (d_out, r)}`` or None — an
    adapter node of an ``AdapterSet`` already in prepared form (the scaling
    factor folded into B, rank mask applied), so the projection itself is
    scale-free.  Routed through ``repro.kernels.dispatch`` so configs with
    ``use_pallas`` hit the fused Pallas kernel (with fused custom-VJP
    backward) instead of three XLA GEMMs; leaves with a leading request dim
    (``AdapterBank.gather``) take the batched multi-tenant path.
    """
    return lora_linear(x, w, adapters, 1.0)

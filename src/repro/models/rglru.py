"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

TPU adaptation: the diagonal linear recurrence h_t = a_t*h_{t-1} + b_t runs as
``jax.lax.associative_scan`` (log-depth, MXU/VPU friendly) instead of a
sequential CUDA scan.  Decode keeps O(1) state: the recurrence hidden plus a
(width-1) causal-conv tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CONV_WIDTH = 4
_C = 8.0  # RG-LRU gate sharpness constant


def rglru_params(cfg, key):
    d = cfg.d_model
    dr = cfg.rglru_d_state or d
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    sr = dr ** -0.5
    # Lambda init so that a = sigmoid(lam)^c in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1 - u ** (1.0 / _C)))
    return {
        "wx": (jax.random.normal(ks[0], (d, dr)) * s).astype(pdt),     # x branch
        "wy": (jax.random.normal(ks[1], (d, dr)) * s).astype(pdt),     # gate branch
        "conv": (jax.random.normal(ks[2], (CONV_WIDTH, dr)) *
                 CONV_WIDTH ** -0.5).astype(pdt),
        "w_a": (jax.random.normal(ks[3], (dr, dr)) * sr).astype(pdt),  # recurrence gate
        "w_i": (jax.random.normal(ks[4], (dr, dr)) * sr).astype(pdt),  # input gate
        "lam": lam.astype(pdt),
        "w_out": (jax.random.normal(ks[2], (dr, d)) * sr).astype(pdt),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv, width W.  x (b,s,dr), w (W,dr).
    ``tail`` (b, W-1, dr) are the trailing inputs from previous steps."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_tail = xp[:, -(width - 1):]
    return out, new_tail


def _gates(params, xb):
    """a_t (recurrence coeff) and gated input, elementwise over (.., dr)."""
    r = jax.nn.sigmoid(xb @ params["w_a"])
    i = jax.nn.sigmoid(xb @ params["w_i"])
    log_a = -_C * r * jax.nn.softplus(-params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * xb)
    return a, b


def rglru_scan(a, b, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1. a,b (b,s,dr)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def comb(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h


def rglru_apply_fullseq(cfg, params, x, adapters=None):
    """x (b,s,d) -> (b,s,d).  LoRA (if given) adapts wx / wy projections."""
    from repro.models.layers import linear
    xb = linear(x, params["wx"], (adapters or {}).get("wx"))
    yb = linear(x, params["wy"], (adapters or {}).get("wy"))
    xb, _ = _causal_conv(xb, params["conv"])
    xf = xb.astype(jnp.float32)
    a, b = _gates(params, xf)
    h = rglru_scan(a, b)
    out = h * jax.nn.gelu(yb.astype(jnp.float32), approximate=True)
    return (out @ params["w_out"].astype(jnp.float32)).astype(x.dtype)


def rglru_init_cache(cfg, batch, dtype):
    dr = cfg.rglru_d_state or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv_tail": jnp.zeros((batch, CONV_WIDTH - 1, dr), dtype)}


def rglru_apply_prefill(cfg, params, x, cache, positions, adapters=None):
    """Whole-prompt RG-LRU that also returns the decode cache: the
    associative scan continues from ``cache`` (h0 + conv tail), and the
    final recurrence state / trailing conv inputs become the new cache —
    one batched pass instead of s sequential decode steps."""
    from repro.models.layers import linear
    xb = linear(x, params["wx"], (adapters or {}).get("wx"))
    yb = linear(x, params["wy"], (adapters or {}).get("wy"))
    xb, new_tail = _causal_conv(xb, params["conv"], cache["conv_tail"])
    xf = xb.astype(jnp.float32)
    a, b = _gates(params, xf)
    h = rglru_scan(a, b, h0=cache["h"])
    out = h * jax.nn.gelu(yb.astype(jnp.float32), approximate=True)
    y = (out @ params["w_out"].astype(jnp.float32)).astype(x.dtype)
    return y, {"h": h[:, -1], "conv_tail": new_tail}


def rglru_apply_decode(cfg, params, x, cache, pos, adapters=None):
    """One-token step.  x (b,1,d)."""
    from repro.models.layers import linear
    xb = linear(x, params["wx"], (adapters or {}).get("wx"))
    yb = linear(x, params["wy"], (adapters or {}).get("wy"))
    xb, new_tail = _causal_conv(xb, params["conv"], cache["conv_tail"])
    xf = xb[:, 0].astype(jnp.float32)
    a, b = _gates(params, xf)
    h = a * cache["h"] + b
    out = h * jax.nn.gelu(yb[:, 0].astype(jnp.float32), approximate=True)
    y = (out @ params["w_out"].astype(jnp.float32)).astype(x.dtype)
    return y[:, None, :], {"h": h, "conv_tail": new_tail}

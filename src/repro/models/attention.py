"""GQA/MQA attention: full-sequence (train/prefill) and single-token decode.

Supports causal masking, sliding windows, qk-norm, logit soft-capping, and
bidirectional (encoder) attention.  Softmax always runs in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import scan_unroll
from repro.models.layers import apply_rope, linear, rms_norm
from repro.sharding import opts
from repro.sharding.specs import constrain

NEG_INF = -1e30


def _maybe_expand_kv(cfg, q, k, v):
    """Under the ``expand_kv`` opt: repeat KV heads to the full head count and
    constrain the head dim onto the `model` axis, so attention shards by head
    instead of computing (partially) replicated when kv_heads < axis size."""
    if not opts.enabled("expand_kv"):
        return q, k, v
    h = q.shape[2]
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    spec = (("pod", "data"), None, "model", None)
    return (constrain(q, spec), constrain(k, spec), constrain(v, spec))


def attn_params(cfg, key, *, cross: bool = False, d_model=None):
    d = d_model or cfg.d_model
    pdt = jnp.dtype(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "q": (jax.random.normal(kq, (d, cfg.q_dim)) * s).astype(pdt),
        "k": (jax.random.normal(kk, (d, cfg.kv_dim)) * s).astype(pdt),
        "v": (jax.random.normal(kv, (d, cfg.kv_dim)) * s).astype(pdt),
        "o": (jax.random.normal(ko, (cfg.q_dim, d)) * (cfg.q_dim ** -0.5)).astype(pdt),
    }
    if cfg.qk_norm:
        p["q_norm_scale"] = jnp.ones((cfg.head_dim,), pdt)
        p["k_norm_scale"] = jnp.ones((cfg.head_dim,), pdt)
    return p


def _project_qkv(cfg, params, x, kv_x=None, adapters=None, positions=None,
                 kv_positions=None, use_rope=True):
    """Returns q (b,s,h,hd), k/v (b,t,kh,hd) with RoPE + qk-norm applied."""
    kv_x = x if kv_x is None else kv_x
    b, s, _ = x.shape
    t = kv_x.shape[1]
    lq = (adapters or {}).get("q")
    lk = (adapters or {}).get("k")
    lv = (adapters or {}).get("v")
    q = linear(x, params["q"], lq).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = linear(kv_x, params["k"], lk).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = linear(kv_x, params["v"], lv).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm_scale"])
        k = rms_norm(k, params["k_norm_scale"])
    if use_rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        if kv_positions is None:
            kv_positions = jnp.arange(t)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def attention_core(cfg, q, k, v, mask):
    """q (b,s,h,hd), k/v (b,t,kh,hd), mask (b,1,s,t) or (b,kh,g,s,t)-broadcastable."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def make_mask(positions_q, positions_kv, *, causal: bool, window=None,
              valid_kv=None):
    """(b, s_q, s_kv) boolean mask."""
    pq = positions_q[:, :, None]
    pk = positions_kv[:, None, :]
    m = jnp.ones(jnp.broadcast_shapes(pq.shape, pk.shape), bool)
    if causal:
        m &= pk <= pq
    if window is not None:
        m &= pq - pk < window
    if valid_kv is not None:
        m &= valid_kv[:, None, :]
    return m


BLOCKWISE_THRESHOLD = 2048   # use flash-style blocked attention above this
Q_BLOCK = 1024
KV_BLOCK = 1024


def blockwise_attention(cfg, q, k, v, positions_q, positions_kv, *, causal,
                        window):
    """Flash-style attention in pure JAX: outer scan over q blocks, inner
    remat'd scan over kv blocks carrying (acc, m, l).  Memory O(s*hd);
    backward recomputes blocks (scan + jax.checkpoint) instead of storing
    the (s, t) score matrix.  The Pallas kernel in repro/kernels mirrors this
    on real TPUs."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    bq = min(Q_BLOCK, s)
    bk = min(KV_BLOCK, t)
    nq, nk = -(-s // bq), -(-t // bk)
    pad_q, pad_k = nq * bq - s, nk * bk - t
    qf = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    pq = jnp.pad(positions_q, ((0, 0), (0, pad_q)), constant_values=-1)
    pk = jnp.pad(positions_kv, ((0, 0), (0, pad_k)), constant_values=2**30)
    qf = qf.reshape(b, nq, bq, kh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kf = kf.reshape(b, nk, bk, kh, hd).transpose(1, 0, 2, 3, 4)
    vf = vf.reshape(b, nk, bk, kh, hd).transpose(1, 0, 2, 3, 4)
    pqb = pq.reshape(b, nq, bq).transpose(1, 0, 2)
    pkb = pk.reshape(b, nk, bk).transpose(1, 0, 2)
    scale = hd ** -0.5

    def kv_step(carry, xs):
        acc, m, l, qblk, pq_blk = carry
        kblk, vblk, pk_blk = xs
        sc = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk) * scale
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            sc = c * jnp.tanh(sc / c)
        msk = make_mask(pq_blk, pk_blk, causal=causal, window=window)
        sc = jnp.where(msk[:, None, None, :, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.where(sc <= NEG_INF / 2, 0.0, jnp.exp(sc - m_new[..., None]))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgqt,btkd->bkgqd", p, vblk)
        return (acc_new, m_new, l_new, qblk, pq_blk), None

    def q_block(qblk, pq_blk):
        if opts.enabled("seq_parallel_attn") and h % 16 != 0:
            # context parallelism: shard the query block's seq dim over
            # `model` when heads can't divide the axis (8-head archs)
            qblk = constrain(qblk, (None, "model", None, None, None))
        acc0 = jnp.zeros((b, kh, g, bq, hd), jnp.float32)
        m0 = jnp.full((b, kh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, bq), jnp.float32)
        (acc, m, l, _, _), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0, qblk, pq_blk),
            (kf, vf, pkb), unroll=scan_unroll(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, hd)

    _, outs = jax.lax.scan(lambda _, xs: (None, q_block(*xs)), None,
                           (qf, pqb), unroll=scan_unroll(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * bq, h, hd)[:, :s]
    return out.astype(q.dtype)


def attention_fullseq(cfg, params, x, *, causal=True, adapters=None,
                      positions=None, kv_x=None, use_rope=True, window=None):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    kv_pos = (positions if kv_x is None else
              jnp.broadcast_to(jnp.arange(kv_x.shape[1])[None, :], (b, kv_x.shape[1])))
    q, k, v = _project_qkv(cfg, params, x, kv_x=kv_x, adapters=adapters,
                           positions=positions, kv_positions=kv_pos,
                           use_rope=use_rope)
    win = window if window is not None else cfg.attn_window
    t = k.shape[1]
    q, k, v = _maybe_expand_kv(cfg, q, k, v)
    if max(s, t) > BLOCKWISE_THRESHOLD:
        out = blockwise_attention(cfg, q, k, v, positions, kv_pos,
                                  causal=causal, window=win if causal else None)
    else:
        mask = make_mask(positions, kv_pos, causal=causal,
                         window=win if causal else None)
        out = attention_core(cfg, q, k, v, mask)
    lo = (adapters or {}).get("o")
    return linear(out.reshape(b, s, -1), params["o"], lo)


# ----------------------------------------------------------------- KV cache prefill

def fill_kv_cache(cache, k, v, positions):
    """Write a whole prompt's K/V rows into the ring-buffer cache at the
    slots the token-by-token decode would have used (``pos % size``).  When
    the prompt overflows a sliding-window cache, only the last ``size``
    positions land — exactly the survivors of sequential ring writes."""
    size = cache["k"].shape[1]
    if k.shape[1] > size:
        k, v, positions = k[:, -size:], v[:, -size:], positions[:, -size:]
    slots = positions % size
    bidx = jnp.arange(k.shape[0])[:, None]
    return {"k": cache["k"].at[bidx, slots].set(k),
            "v": cache["v"].at[bidx, slots].set(v),
            "pos": cache["pos"].at[bidx, slots].set(positions)}


def attention_prefill(cfg, params, x, cache, positions, *, adapters=None):
    """Whole-prompt attention that also fills the KV cache — the batched
    form of running ``attention_decode`` once per prompt token on a FRESH
    cache.  x (b, s, d), positions (b, s).  Returns (out, new_cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, adapters=adapters,
                           positions=positions, kv_positions=positions)
    new_cache = fill_kv_cache(cache, k, v, positions)
    win = cfg.attn_window
    q, k, v = _maybe_expand_kv(cfg, q, k, v)
    if s > BLOCKWISE_THRESHOLD:
        out = blockwise_attention(cfg, q, k, v, positions, positions,
                                  causal=True, window=win)
    else:
        mask = make_mask(positions, positions, causal=True, window=win)
        out = attention_core(cfg, q, k, v, mask)
    y = linear(out.reshape(b, s, -1), params["o"],
               (adapters or {}).get("o"))
    return y, new_cache


# ----------------------------------------------------------------- KV cache decode

def init_kv_cache(cfg, batch: int, max_len: int, dtype):
    """Per-layer cache: ring buffer when cfg.attn_window is set."""
    size = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def attention_decode(cfg, params, x, cache, pos, *, adapters=None):
    """One-token decode.  x (b,1,d); pos (b,) current absolute position.

    Returns (out (b,1,d), new_cache).  Ring-buffer writes for sliding window.
    """
    b = x.shape[0]
    size = cache["k"].shape[1]
    q, k, v = _project_qkv(cfg, params, x, adapters=adapters,
                           positions=pos[:, None], kv_positions=pos[:, None])
    slot = pos % size                                   # (b,)
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(pos)
    valid = new_pos >= 0
    mask = make_mask(pos[:, None], new_pos, causal=True,
                     window=cfg.attn_window, valid_kv=valid)
    out = attention_core(cfg, q, new_k, new_v, mask)
    lo = (adapters or {}).get("o")
    y = linear(out.reshape(b, 1, -1), params["o"], lo)
    return y, {"k": new_k, "v": new_v, "pos": new_pos}


# ----------------------------------------------------------------- paged KV cache
#
# The paged layout replaces the per-request ring buffer (batch, size, kh, hd)
# with a SHARED block pool (num_blocks, block_size, kh, hd) plus a per-request
# block table (b, blocks_per_req) int32 mapping virtual block j of request i
# to a pool block.  A request's view of the pool is a virtual ring of
# vlen = blocks_per_req * block_size slots: token at absolute position p
# lands in virtual slot p % vlen, i.e. pool block table[i, (p % vlen) //
# block_size] at offset (p % vlen) % block_size.  Because that is the exact
# ring formula with vlen in place of size, gathering a request's blocks back
# into (b, vlen, kh, hd) reproduces the ring-buffer layout element for
# element — when block_size divides the ring size the paged decode is
# bit-identical to the ring decode (tests/test_paged.py).
#
# Block 0 is the NULL block: the scheduler points idle batch slots' table
# rows at it, so their (discarded) decode writes land harmlessly in a block
# no live request ever owns.  The pos pool doubles as the validity mask
# (entry >= 0 == written), exactly like the ring cache's pos array.


def init_paged_kv_cache(cfg, num_blocks: int, block_size: int, dtype):
    """Per-layer shared pool.  The per-request geometry (how many blocks a
    request owns) is the block TABLE's width, not a pool property."""
    return {
        "k_pool": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
        "v_pool": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
        "pos_pool": jnp.full((num_blocks, block_size), -1, jnp.int32),
    }


def paged_gather(cache, table):
    """Materialize each request's virtual ring view of the pool:
    (k (b, vlen, kh, hd), v (b, vlen, kh, hd), pos (b, vlen))."""
    b, mb = table.shape
    bs = cache["k_pool"].shape[1]
    kh, hd = cache["k_pool"].shape[2:]
    k = cache["k_pool"][table].reshape(b, mb * bs, kh, hd)
    v = cache["v_pool"][table].reshape(b, mb * bs, kh, hd)
    pos = cache["pos_pool"][table].reshape(b, mb * bs)
    return k, v, pos


def fill_paged_kv_cache(cache, k, v, positions, table):
    """Paged counterpart of :func:`fill_kv_cache`: write a whole prompt's
    K/V rows into each request's pool blocks at the virtual-ring slots the
    token-by-token decode would have used.  On overflow only the last
    ``vlen`` positions land — the survivors of sequential ring writes."""
    bs = cache["k_pool"].shape[1]
    vlen = table.shape[1] * bs
    if k.shape[1] > vlen:
        k, v, positions = k[:, -vlen:], v[:, -vlen:], positions[:, -vlen:]
    vslot = positions % vlen                                # (b, s)
    blk = jnp.take_along_axis(table, vslot // bs, axis=1)   # (b, s)
    off = vslot % bs
    return {"k_pool": cache["k_pool"].at[blk, off].set(k),
            "v_pool": cache["v_pool"].at[blk, off].set(v),
            "pos_pool": cache["pos_pool"].at[blk, off].set(positions)}


def attention_prefill_paged(cfg, params, x, cache, positions, table, *,
                            adapters=None):
    """Whole-prompt attention that fills the request's POOL blocks.  The
    attention itself is over the prompt's own K/V (same math as
    :func:`attention_prefill`); only the cache writes differ."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, params, x, adapters=adapters,
                           positions=positions, kv_positions=positions)
    new_cache = fill_paged_kv_cache(cache, k, v, positions, table)
    win = cfg.attn_window
    q, k, v = _maybe_expand_kv(cfg, q, k, v)
    if s > BLOCKWISE_THRESHOLD:
        out = blockwise_attention(cfg, q, k, v, positions, positions,
                                  causal=True, window=win)
    else:
        mask = make_mask(positions, positions, causal=True, window=win)
        out = attention_core(cfg, q, k, v, mask)
    y = linear(out.reshape(b, s, -1), params["o"],
               (adapters or {}).get("o"))
    return y, new_cache


def attention_decode_paged(cfg, params, x, cache, table, pos, *,
                           adapters=None):
    """One-token decode against the block pool.  x (b,1,d); table (b,
    blocks_per_req) int32; pos (b,) absolute position.

    Reference tier gathers the request's blocks back into the ring layout
    and reuses the exact ring mask/attention ops (bit-identity); on the
    pallas tier the gather never materializes — the kernel's BlockSpecs
    stream pool blocks through the block table via scalar prefetch."""
    from repro.kernels import dispatch

    b = x.shape[0]
    bs = cache["k_pool"].shape[1]
    vlen = table.shape[1] * bs
    q, k, v = _project_qkv(cfg, params, x, adapters=adapters,
                           positions=pos[:, None], kv_positions=pos[:, None])
    vslot = pos % vlen                                  # (b,)
    bidx = jnp.arange(b)
    blk = table[bidx, vslot // bs]
    off = vslot % bs
    new_cache = {"k_pool": cache["k_pool"].at[blk, off].set(k[:, 0]),
                 "v_pool": cache["v_pool"].at[blk, off].set(v[:, 0]),
                 "pos_pool": cache["pos_pool"].at[blk, off].set(pos)}
    if dispatch.resolve_mode() == "pallas":
        from repro.kernels.paged_attention import paged_attention
        dispatch.stats["paged"] += 1
        out = paged_attention(
            q[:, 0], new_cache["k_pool"], new_cache["v_pool"],
            new_cache["pos_pool"], table, pos,
            window=cfg.attn_window, softcap=cfg.attn_logit_softcap)[:, None]
    else:
        kg, vg, pg = paged_gather(new_cache, table)
        mask = make_mask(pos[:, None], pg, causal=True,
                         window=cfg.attn_window, valid_kv=pg >= 0)
        out = attention_core(cfg, q, kg, vg, mask)
    lo = (adapters or {}).get("o")
    y = linear(out.reshape(b, 1, -1), params["o"], lo)
    return y, new_cache

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallel train form,
recurrent decode form) and sLSTM (scalar memory, sequential scan).

TPU adaptation: mLSTM trains with the stabilized parallel (attention-like)
formulation — an O(T^2) einsum that maps onto the MXU — and decodes with the
O(1) recurrent matrix-memory update.  sLSTM is inherently sequential (hidden-
state feedback through nonlinearities) and runs as ``jax.lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import scan_unroll


# ================================================================== mLSTM

def mlstm_params(cfg, key):
    d = cfg.d_model
    h = cfg.num_heads
    hd = cfg.head_dim
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "q": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(pdt),
        "k": (jax.random.normal(ks[1], (d, h * hd)) * s).astype(pdt),
        "v": (jax.random.normal(ks[2], (d, h * hd)) * s).astype(pdt),
        "w_i": (jax.random.normal(ks[3], (d, h)) * s).astype(pdt),
        "w_f": (jax.random.normal(ks[4], (d, h)) * s).astype(pdt),
        "b_f": jnp.full((h,), 3.0, pdt),        # bias toward remembering
        "o": (jax.random.normal(ks[5], (h * hd, d)) * (h * hd) ** -0.5).astype(pdt),
        "ogate": (jax.random.normal(ks[6], (d, h * hd)) * s).astype(pdt),
    }


def _mlstm_qkv(cfg, params, x, adapters):
    from repro.models.layers import linear
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = linear(x, params["q"], (adapters or {}).get("q")).reshape(b, s, h, hd)
    k = linear(x, params["k"], (adapters or {}).get("k")).reshape(b, s, h, hd)
    v = linear(x, params["v"], (adapters or {}).get("v")).reshape(b, s, h, hd)
    return (q.astype(jnp.float32), k.astype(jnp.float32) * hd ** -0.5,
            v.astype(jnp.float32))


MLSTM_CHUNK = 256


def _mlstm_fullseq(cfg, params, x, adapters=None, carry0=None):
    """Stabilized chunkwise-parallel form: within-chunk O(C^2) on the MXU,
    across-chunk recurrent matrix-memory carry (scan).  x (b,s,d).
    Returns (out, final_carry) — the carry after the LAST REAL token, so
    prefill can hand it to the recurrent decode form as the cache."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _mlstm_qkv(cfg, params, x, adapters)
    xf = x.astype(jnp.float32)
    log_i = xf @ params["w_i"].astype(jnp.float32)                       # (b,s,h)
    log_f = jax.nn.log_sigmoid(xf @ params["w_f"].astype(jnp.float32)
                               + params["b_f"].astype(jnp.float32))

    c = min(MLSTM_CHUNK, s)
    pad = (-s) % c
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, log_f = map(zpad, (q, k, v, log_f))
        # identity-safe padding: pad forget gates decay 0 (log_f = 0) and
        # pad input gates -inf (log_i = -1e30), so pad tokens neither decay
        # nor write the carried state — the final carry is exactly the state
        # after the last real token.  Real-position outputs are causal and
        # unaffected either way; pad outputs are sliced off.
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
    nc = q.shape[1] // c
    chunked = lambda a: a.reshape(b, nc, c, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, lic, lfc = map(chunked, (q, k, v, log_i, log_f))

    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, xs):
        C_st, n_st, m_st = carry                     # (b,h,hd,hd) (b,h,hd) (b,h)
        qb, kb, vb, li, lf = xs                      # (b,c,h,hd) ... (b,c,h)
        bacc = jnp.cumsum(lf, axis=1)                # (b,c,h)
        total = bacc[:, -1]                          # (b,h)
        # intra-chunk decay matrix D[t,u] = bacc[t]-bacc[u]+li[u]
        dmat = bacc[:, :, None, :] - bacc[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)              # (b,c,h)
        m_inter = m_st[:, None, :] + bacc            # (b,c,h)
        m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        w = jnp.where(jnp.isfinite(dmat), jnp.exp(dmat - m_t[:, :, None, :]), 0.0)
        sc = jnp.einsum("bthd,buhd->btuh", qb, kb) * w
        num = jnp.einsum("btuh,buhd->bthd", sc, vb)
        nvec = jnp.einsum("btuh,buhd->bthd", w, kb)
        inter_w = jnp.exp(m_inter - m_t)             # (b,c,h)
        num = num + inter_w[..., None] * jnp.einsum("bthd,bhde->bthe", qb, C_st)
        nvec = nvec + inter_w[..., None] * n_st[:, None]
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qb, nvec)),
                          jnp.exp(-m_t))
        out = num / den[..., None]                   # (b,c,h,hd)
        # ---- state update to end of chunk
        key_d = li + total[:, None] - bacc           # (b,c,h)
        m_new = jnp.maximum(m_st + total, jnp.max(key_d, axis=1))
        kw = jnp.exp(key_d - m_new[:, None])         # (b,c,h)
        carry_w = jnp.exp(m_st + total - m_new)      # (b,h)
        C_new = (carry_w[..., None, None] * C_st +
                 jnp.einsum("buh,buhd,buhe->bhde", kw, kb, vb))
        n_new = carry_w[..., None] * n_st + jnp.einsum("buh,buhd->bhd", kw, kb)
        return (C_new, n_new, m_new), out

    if carry0 is None:
        carry0 = (jnp.zeros((b, h, hd, hd), jnp.float32),
                  jnp.zeros((b, h, hd), jnp.float32),
                  jnp.full((b, h), -1e30, jnp.float32))
    # NOTE: deliberately NOT unrolled under FULL_UNROLL — at 32k tokens the
    # 128-chunk unroll explodes compile time, and the intra-chunk O(C^2) part
    # it would make countable is <=5% of mLSTM flops (projections dominate).
    # The dry-run calibration documents this as a known <=5% undercount.
    carry, outs = jax.lax.scan(jax.checkpoint(chunk_step), carry0,
                               (qc, kc, vc, lic, lfc))
    out = outs.swapaxes(0, 1).reshape(b, nc * c, h, hd)[:, :s]
    ogate = jax.nn.sigmoid(xf @ params["ogate"].astype(jnp.float32))
    out = out.reshape(b, s, -1) * ogate
    return (out @ params["o"].astype(jnp.float32)).astype(x.dtype), carry


def mlstm_apply_fullseq(cfg, params, x, adapters=None):
    return _mlstm_fullseq(cfg, params, x, adapters)[0]


def mlstm_apply_prefill(cfg, params, x, cache, positions, adapters=None):
    """Whole-prompt mLSTM continuing from ``cache``; the chunk scan's final
    carry (exact thanks to identity-safe padding) becomes the decode cache."""
    out, (C, n, m) = _mlstm_fullseq(
        cfg, params, x, adapters, carry0=(cache["C"], cache["n"], cache["m"]))
    return out, {"C": C, "n": n, "m": m}


def mlstm_init_cache(cfg, batch, dtype):
    h, hd = cfg.num_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_apply_decode(cfg, params, x, cache, pos, adapters=None):
    """Recurrent matrix-memory step.  x (b,1,d)."""
    b = x.shape[0]
    q, k, v = _mlstm_qkv(cfg, params, x, adapters)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                                   # (b,h,hd)
    xf = x[:, 0].astype(jnp.float32)
    log_i = xf @ params["w_i"].astype(jnp.float32)                        # (b,h)
    log_f = jax.nn.log_sigmoid(xf @ params["w_f"].astype(jnp.float32)
                               + params["b_f"].astype(jnp.float32))
    m_new = jnp.maximum(log_f + cache["m"], log_i)
    fw = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    iw = jnp.exp(log_i - m_new)[..., None]
    c_new = fw[..., None] * cache["C"] + iw[..., None] * (k[..., :, None] *
                                                          v[..., None, :])
    n_new = fw * cache["n"] + iw * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    out = num / den[..., None]
    ogate = jax.nn.sigmoid(xf @ params["ogate"].astype(jnp.float32))
    out = out.reshape(b, -1) * ogate
    y = (out @ params["o"].astype(jnp.float32)).astype(x.dtype)
    return y[:, None, :], {"C": c_new, "n": n_new, "m": m_new}


# ================================================================== sLSTM

def slstm_params(cfg, key):
    d = cfg.d_model
    nh = cfg.slstm_num_heads
    hd = d // nh
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    sr = hd ** -0.5
    p = {}
    for name, kk in zip(("z", "i", "f", "o"), ks[:4]):
        p[f"w_{name}"] = (jax.random.normal(kk, (d, d)) * s).astype(pdt)
    for name, kk in zip(("z", "i", "f", "o"), ks[4:8]):
        p[f"r_{name}"] = (jax.random.normal(kk, (nh, hd, hd)) * sr).astype(pdt)
    p["b_f"] = jnp.full((d,), 3.0, pdt)
    p["w_proj"] = (jax.random.normal(ks[8], (d, d)) * s).astype(pdt)
    return p


def _slstm_step(cfg, params, carry, x_t):
    """carry: (h (b,d), c, n, m); x_t: pre-projected gates (b, 4, d)."""
    nh = cfg.slstm_num_heads
    h, c, n, m = carry
    b, d = h.shape
    hd = d // nh
    hh = h.reshape(b, nh, hd)

    def rec(name):
        return jnp.einsum("bnh,nhk->bnk", hh, params[f"r_{name}"].astype(
            jnp.float32)).reshape(b, d)

    z = jnp.tanh(x_t[:, 0] + rec("z"))
    log_i = x_t[:, 1] + rec("i")
    log_f = jax.nn.log_sigmoid(x_t[:, 2] + rec("f")
                               + params["b_f"].astype(jnp.float32))
    o = jax.nn.sigmoid(x_t[:, 3] + rec("o"))
    m_new = jnp.maximum(log_f + m, log_i)
    iw = jnp.exp(log_i - m_new)
    fw = jnp.exp(log_f + m - m_new)
    c_new = fw * c + iw * z
    n_new = jnp.maximum(fw * n + iw, 1e-6)
    h_new = o * c_new / n_new
    return (h_new, c_new, n_new, m_new), h_new


def _slstm_gate_inputs(params, x):
    xf = x.astype(jnp.float32)
    gates = [xf @ params[f"w_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")]
    return jnp.stack(gates, axis=-2)          # (b, s, 4, d)


def _slstm_fullseq(cfg, params, x, adapters=None, carry=None):
    b, s, d = x.shape
    gi = _slstm_gate_inputs(params, x)
    if adapters is not None and "z" in adapters:
        # gate-input adapter (prepared form: scale already folded into B)
        za, zb = adapters["z"]["a"], adapters["z"]["b"]
        gi = gi.at[:, :, 0].add((x @ za.T) @ zb.T)
    if carry is None:
        carry = (jnp.zeros((b, d), jnp.float32),) * 2 + (
            jnp.full((b, d), 1e-6, jnp.float32),
            jnp.full((b, d), -1e30, jnp.float32))
    step = lambda c, xt: _slstm_step(cfg, params, c, xt)
    carry, hs = jax.lax.scan(step, carry, jnp.swapaxes(gi, 0, 1))
    h = jnp.swapaxes(hs, 0, 1)                # (b, s, d)
    return (h @ params["w_proj"].astype(jnp.float32)).astype(x.dtype), carry


def slstm_apply_fullseq(cfg, params, x, adapters=None):
    return _slstm_fullseq(cfg, params, x, adapters)[0]


def slstm_apply_prefill(cfg, params, x, cache, positions, adapters=None):
    """Whole-prompt sLSTM continuing from ``cache``; the scan carry IS the
    decode cache, so prefill-then-decode matches the sequential path."""
    out, (h, c, n, m) = _slstm_fullseq(
        cfg, params, x, adapters,
        carry=(cache["h"], cache["c"], cache["n"], cache["m"]))
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_init_cache(cfg, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.full((batch, d), 1e-6, jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_apply_decode(cfg, params, x, cache, pos, adapters=None):
    gi = _slstm_gate_inputs(params, x)[:, 0]  # (b, 4, d)
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    (h, c, n, m), _ = _slstm_step(cfg, params, carry, gi)
    y = (h @ params["w_proj"].astype(jnp.float32)).astype(x.dtype)
    return y[:, None, :], {"h": h, "c": c, "n": n, "m": m}

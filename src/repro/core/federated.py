"""Federated LoRA fine-tuning: a compiled multi-round engine.

One federated round (paper §3):
  1. every client runs ``local_steps`` SGD/AdamW steps on its LoRA params
     (vmap over the client dim — on a mesh the client dim shards over
     ``data``/``pod`` axes, so local training is collective-free),
  2. the server aggregates per the strategy (FedSA/SFed: mean of A only —
     one small all-reduce over the client axes),
  3. the aggregate is broadcast back (same collective).

Engine architecture (the ROADMAP "fast as the hardware allows" move):

  round body   one round as a pure function of (state, batches, round_idx,
               weights) — shared by every execution mode below.
  run_chunk    ``jax.lax.scan`` of the round body over a *chunk* of rounds,
               entirely on device.  A carried PRNG key is split once per
               round inside the scan; partial participation is sampled from
               it with ``jax.random`` (choice without replacement); batches
               either stream in as stacked scan inputs (host data) or are
               synthesized on device by a ``batch_fn`` (``jax.random``
               inside the scan — zero host traffic).  Client/optimizer
               carries are donated, and the stacked per-round metrics come
               back in one transfer, so the host syncs once per chunk
               instead of once per round.
  FederatedTrainer   a thin host wrapper that keeps the public API (``run``,
               ``run_round``, ``eval_perplexity``, ``history``) and calls
               ``run_chunk`` in chunks of ``chunk_rounds`` (default: the
               ``log_every`` stride, else the whole request).  ``run_round``
               is a chunk of one, so per-round and chunked execution are the
               same compiled computation and stay bit-identical.

The scaling factor gamma = scaling_factor(scheme, alpha, r, N) multiplies the
adapter product in every forward pass — SFed-LoRA's contribution is that this
is sqrt(N/r), tied to the *distribution config*, not just the adapter shape.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import get_strategy
from repro.core.quant import dequantize_tree, has_quantized
from repro.core.lora import (AdapterSet, apply_rank_mask, init_lora,
                             mask_rank_tree, rank_mask)
from repro.core.scaling import per_client_gammas, scaling_factor
from repro.optim.optimizers import apply_updates, global_norm, make_optimizer


def participation_weights(key, num_clients: int, num_sampled: int):
    """(N,) 0/1 mask with exactly ``num_sampled`` ones, sampled uniformly
    without replacement from the round's PRNG key (device-side)."""
    perm = jax.random.permutation(key, num_clients)
    return jnp.zeros((num_clients,), jnp.float32).at[perm[:num_sampled]].set(1.0)


def _make_client_local(model, strat, opt_cfg):
    """The per-client local-training scan (``local_steps`` optimizer steps
    on one client's adapter state), shared by the synchronous and the
    buffered round bodies — the two engines must differ only in the
    server-side delivery/aggregation path, never in client compute."""
    _, opt_update = make_optimizer(opt_cfg)

    def client_local(base, lora, opt_state, batches, round_idx, mask_row,
                     gamma_i, gamma_static):
        def step(carry, batch):
            lo, st = carry
            def loss_fn(l):
                # no rank_mask here: the engine maintains the mask invariant
                # externally (zero-init, grad masking below, re-mask after
                # aggregation), so ``l`` is already exactly masked — passing
                # the mask would only add a redundant traced multiply to the
                # hot loop (and break bit-identity with the uniform-rank
                # fast path)
                aset = AdapterSet(
                    lora=l,
                    gamma=gamma_static if gamma_i is None else gamma_i)
                return model.loss(base, batch, adapters=aset)
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(lo)
            gnorm = global_norm(grads)
            grads = strat.mask_grads(grads, round_idx)
            if mask_row is not None:
                grads = mask_rank_tree(grads, mask_row)
            if opt_cfg.grad_clip:
                from repro.optim.optimizers import clip_by_global_norm
                grads = clip_by_global_norm(grads, opt_cfg.grad_clip)
            updates, st = opt_update(grads, st, lo)
            lo = apply_updates(lo, updates)
            return (lo, st), {"loss": loss, "grad_norm": gnorm}

        (lora, opt_state), ms = jax.lax.scan(step, (lora, opt_state), batches)
        return lora, opt_state, ms

    return client_local


def make_round_body(model, *, strategy, opt_cfg, track_update_norm=False):
    """Returns round_body(base, adapters, opt_N, batches, round_idx, weights).

    ``adapters`` is a client-stacked :class:`AdapterSet`: its ``lora`` tree
    and ``opt_N`` carry a leading client dim, ``batches`` leaves are
    (N, local_steps, batch, ...).  Returns (adapters', opt_N, metrics).

    The scaling factor and the per-client rank mask are READ OFF the
    AdapterSet — the engine no longer threads them as loose arguments:

      - a python-float ``adapters.gamma`` (homogeneous, or uniform
        per-client gammas collapsed by AdapterSet) stays static and is
        folded into B at trace time by the model API;
      - a per-client (N,) ``adapters.gamma`` reaches each client as a
        traced gamma_i under the vmap and is folded into that client's B
        inside the loss (``AdapterSet.fold_gamma``), so the gamma reaching
        the kernels is always the static 1.0 the fused Pallas tier needs;
      - ``adapters.rank_mask`` (N, r_max) enables heterogeneous per-client
        ranks in the padded representation: client gradients are masked to
        the active rank rows and the server aggregate is rank-aware (see
        ``core/aggregation``).

    ``track_update_norm`` adds a per-round ``update_norm`` metric: the
    gamma-scaled norm of the post-aggregation adapter movement, the series
    the collapse sentinel (``repro.analysis.stability_check``) judges
    against the Theorem 4.2 moment-scale prediction.  Opt-in so the
    default metrics treedef (and every pinned bit-identity test) is
    untouched.
    """
    strat = get_strategy(strategy)
    client_local = _make_client_local(model, strat, opt_cfg)

    def round_body(base, adapters, opt_N, batches, round_idx, weights=None):
        """``weights`` (N,) non-negative: 0 = non-sampled (keeps its local
        state, only receives the aggregate); positive values additionally
        weight the server mean (e.g. by client example counts)."""
        lora_N = adapters.lora
        mask_N = adapters.rank_mask
        g = adapters.gamma
        static = isinstance(g, (int, float))
        gamma_N = None if static else jnp.asarray(g, jnp.float32)
        new_lora, new_opt, ms = jax.vmap(
            functools.partial(client_local,
                              gamma_static=g if static else None),
            in_axes=(None, 0, 0, 0, None,
                     None if mask_N is None else 0,
                     None if gamma_N is None else 0))(
                base, lora_N, opt_N, batches, round_idx, mask_N, gamma_N)
        if weights is not None:
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(
                    weights.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
                new, old)
            new_lora = sel(new_lora, lora_N)
            new_opt = sel(new_opt, opt_N)
        new_lora = strat.aggregate(new_lora, round_idx, weights=weights,
                                   rank_mask=mask_N)
        metrics = {"loss": ms["loss"].mean(), "grad_norm": ms["grad_norm"].mean()}
        if track_update_norm:
            # gamma-scaled aggregated adapter movement: to first order the
            # effective-weight step is gamma*(dB·A + B·dA), so |gamma|*|d
            # lora| tracks the Thm 4.2 moment scale the sentinel checks
            g_scale = abs(g) if static else jnp.mean(jnp.abs(gamma_N))
            metrics["update_norm"] = g_scale * global_norm(
                jax.tree.map(lambda a, b: a - b, new_lora, lora_N))
        return dataclasses.replace(adapters, lora=new_lora), new_opt, metrics

    return round_body


def _tree_where(row_mask, new, old):
    """Per-client row select over two identically-shaped stacked trees."""
    return jax.tree.map(
        lambda a, b: jnp.where(
            row_mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), new, old)


def _quantize_rho(rho: float) -> float:
    """Quantize the carried gamma correction rho = sqrt(N_eff/N) before
    the trainer folds it statically into the next chunk's gamma: each
    distinct gamma compiles its own executable (it rides the AdapterSet
    treedef), so an unquantized rho would recompile every chunk under
    sustained faults.  Two decimals bounds the executable set at ~100.
    rho >= 0.995 passes through as exactly 1.0, keeping the staleness-0
    fold a bitwise no-op."""
    rho = float(rho)
    if rho >= 0.995:
        return 1.0
    return max(round(rho, 2), 0.01)


def make_buffered_round_body(model, *, strategy, opt_cfg, fault_model=None,
                             track_update_norm=False):
    """The async FedBuff-style round body: returns
    round_body(base, adapters, opt_N, tau, rho, batches, round_idx,
    k_fault, part, size_w, expected) -> (adapters', opt_N', tau', rho',
    metrics).

    One round, fully inside the scan (no host clocks, no per-arrival
    jits):

      1. every sampled client WITHOUT an in-flight upload trains locally
         (in-flight clients hold their pending update and skip the round —
         their state is the update still in transit);
      2. the fault model draws this round's drop/straggle/corrupt masks
         from ``k_fault``; corruption applies to a COPY of the upload,
         never the client's local state;
      3. the server screens arrivals (non-finite always rejected; norm
         outliers vs ``screen_mult`` x the candidate median when screening is
         on), caps the accepted buffer at ``buffer_size`` in client-index
         order (overflow stays in flight), and aggregates the accepted
         uploads with staleness weights ``(1 + tau)^-beta`` composed with
         the size weights;
      4. clients still in flight (stragglers + overflow) bump tau and keep
         local state; everyone else resets tau and receives the broadcast
         on exactly the leaves the inner strategy aggregates
         (``agg_leaf_flags``) — dropped/rejected clients therefore resync
         from the server, losing their corrupt/lost update;
      5. the carried correction factor rho' = sqrt(N_eff_mass / expected)
         is the Theorem 4.2 staleness correction: gamma_eff = gamma * rho
         = alpha*sqrt(N_eff/r) (see
         ``repro.core.scaling.staleness_corrected_gamma``).  The trainer
         applies it at CHUNK boundaries as a static gamma fold (the
         engine's per-gamma-executable specialization) rather than as an
         in-scan runtime multiply: a runtime gamma would block XLA's
         constant-folding of gamma into the loss graph and break the
         staleness-0 bit-identity by ulps.  Within a chunk the body
         trains with the chunk-start gamma_eff and carries rho for the
         metrics and the next fold.

    At zero faults, M = N, and tau = 0, every mask is the constant it is
    in the synchronous engine and rho stays exactly 1.0, so this body is
    BIT-identical to ``make_round_body`` (pinned by the conformance
    harness): ``where(True, new, old)`` is ``new``, the weighted mean
    with all-ones weights equals the fast-path mean bitwise (both lower
    to sum * reciprocal — see ``aggregate_clients``), and the gamma fold
    ``gamma * 1.0`` is exact, so the same executable keeps serving.

    ``expected`` is the round's sampled-client count (static python int) —
    the denominator that makes N_eff = N at full delivery.
    """
    from repro.core.aggregation import (BufferedStrategy, combine_received,
                                        per_client_finite, per_client_norm)
    from repro.core.faults import FaultModel
    strat = get_strategy(strategy)
    if not isinstance(strat, BufferedStrategy):
        raise ValueError(
            "make_buffered_round_body needs a BufferedStrategy (wrap the "
            "inner method with aggregation.buffered(...))")
    inner = strat.inner
    fault_model = fault_model or FaultModel()
    client_local = _make_client_local(model, strat, opt_cfg)

    def round_body(base, adapters, opt_N, tau, rho, batches, round_idx,
                   k_fault, part=None, size_w=None, expected=None):
        lora_N = adapters.lora
        mask_N = adapters.rank_mask
        g = adapters.gamma
        n = jax.tree.leaves(lora_N)[0].shape[0]
        expected = n if expected is None else expected
        # gamma stays STATIC exactly as in make_round_body — the trainer
        # already folded the previous chunk's rho into adapters.gamma, so
        # the client compute graph is the synchronous engine's graph
        static = isinstance(g, (int, float))
        gamma_N = None if static else jnp.asarray(g, jnp.float32)
        new_lora, new_opt, ms = jax.vmap(
            functools.partial(client_local,
                              gamma_static=g if static else None),
            in_axes=(None, 0, 0, 0, None,
                     None if mask_N is None else 0,
                     None if gamma_N is None else 0))(
                base, lora_N, opt_N, batches, round_idx, mask_N, gamma_N)

        sampled = (jnp.ones((n,), bool) if part is None else part > 0)
        in_flight = tau > 0
        trained = sampled & ~in_flight
        local_lora = _tree_where(trained, new_lora, lora_N)
        local_opt = _tree_where(trained, new_opt, opt_N)

        fr = fault_model.sample(k_fault, n)
        attempting = sampled | in_flight
        dropped = attempting & fr["drop"]
        straggling = attempting & ~dropped & fr["straggle"]
        arrived = attempting & ~dropped & ~straggling
        upload = fault_model.corrupt_tree(
            jax.random.fold_in(k_fault, 1), local_lora,
            arrived & fr["corrupt"])

        rejected = jnp.zeros((n,), bool)
        if strat.screen:
            finite = per_client_finite(upload)
            norms = per_client_norm(
                jax.tree.map(lambda u, o: u - o, upload, lora_N))
            cand = arrived & finite
            cnt = cand.sum()
            # judge against the candidate MEDIAN, not the mean: a finite
            # norm-bomb inflates the mean by ~its own norm/N, so at small
            # N it could never exceed mult x mean; the median stays at the
            # clean level for up to half the cohort corrupted
            med = jnp.sort(jnp.where(cand, norms, jnp.inf))[
                jnp.maximum(cnt - 1, 0) // 2]
            outlier = (norms > strat.screen_mult * med) & (cnt > 1)
            rejected = arrived & (~finite | outlier)
        accepted = arrived & ~rejected
        if strat.buffer_size:
            # cap the buffer in client-index order; overflow stays in
            # flight and ages like a straggler
            csum = jnp.cumsum(accepted.astype(jnp.int32))
            in_buf = accepted & (csum <= strat.buffer_size)
            overflow = accepted & ~in_buf
            accepted = in_buf
        else:
            overflow = jnp.zeros((n,), bool)

        disc = (1.0 + tau.astype(jnp.float32)) ** (-strat.beta)
        w_up = accepted.astype(jnp.float32) * disc
        if size_w is not None:
            w_up = w_up * size_w
        # the aggregate's keep=False fallback rows must be the same mixed
        # new/old tree the synchronous engine feeds it — and replacing
        # non-accepted rows also keeps NaN/Inf uploads out of the weighted
        # sums (0 * NaN would still poison them)
        san = _tree_where(accepted, upload, local_lora)
        agg = inner.aggregate(san, round_idx, weights=w_up,
                              rank_mask=mask_N)

        pend = straggling | overflow
        fa, fb = inner.agg_leaf_flags(round_idx)
        out_lora = combine_received(local_lora, agg, ~pend, fa, fb)
        tau_next = jnp.where(pend, tau + 1, 0).astype(tau.dtype)
        mass = (accepted.astype(jnp.float32) * disc).sum()
        n_eff = n * mass / expected
        # floor at one effective client: a fully-lost round must not zero
        # the next round's gammas (maximum(x, 1) == x bitwise at x >= 1,
        # so the staleness-0 path still carries rho == 1.0 exactly)
        rho_next = jnp.sqrt(jnp.maximum(mass, 1.0) / expected)

        metrics = {"loss": ms["loss"].mean(),
                   "grad_norm": ms["grad_norm"].mean(),
                   "n_eff": n_eff, "gamma_scale": rho_next,
                   "delivered": accepted.sum().astype(jnp.float32),
                   "rejected": rejected.sum().astype(jnp.float32),
                   "stale": pend.sum().astype(jnp.float32)}
        if track_update_norm:
            # same form as the synchronous metric — the chunk-start gamma
            # already carries the staleness correction
            g_scale = abs(g) if static else jnp.mean(jnp.abs(gamma_N))
            metrics["update_norm"] = g_scale * global_norm(
                jax.tree.map(lambda a, b: a - b, out_lora, lora_N))
        return (dataclasses.replace(adapters, lora=out_lora), local_opt,
                tau_next, rho_next, metrics)

    return round_body


def make_fed_round_step(model, *, strategy, opt_cfg, donate: bool = True,
                        jit: bool = True):
    """Single-round entry point (back-compat shim over the round body).

    Returns round_step(base, adapters, opt_N, batches, round_idx, weights).
    With ``jit=False`` returns the raw function (multi-device tests wrap it
    in their own pjit with explicit shardings).
    """
    round_step = make_round_body(model, strategy=strategy, opt_cfg=opt_cfg)
    if not jit:
        return round_step
    return jax.jit(round_step, donate_argnums=(1, 2) if donate else ())


def make_run_chunk(model, *, strategy, opt_cfg, participation: float = 1.0,
                   batch_fn=None, client_weights=None,
                   donate: bool = True, jit: bool = True,
                   track_update_norm: bool = False, fault_model=None):
    """Build the chunked scan executor.

    Returns run_chunk(base, adapters, opt_N, key, round0, batches=None,
    num_rounds=None) -> (adapters, opt_N, key, metrics), where ``adapters``
    is the client-stacked :class:`AdapterSet` the scan carries (A/B tree +
    gamma(s) + rank mask as ONE pytree — the scaling config cannot
    desynchronize from the state it scales).

      - ``key``     carried PRNG key; split once per round inside the scan
                    (participation sampling and on-device batch synthesis
                    both derive from it, so per-round and chunked execution
                    consume randomness identically).
      - ``round0``  traced scalar: global index of the chunk's first round
                    (rolora alternation, schedules, resume).
      - ``batches`` host-staged data with a leading (num_rounds,) dim on
                    every leaf — required unless the engine was built with a
                    ``batch_fn(key, round_idx) -> batches`` that generates
                    data on device inside the scan, in which case the static
                    ``num_rounds`` sets the chunk length.
      - metrics come back stacked: {"loss": (num_rounds,), ...}.

    ``client_weights`` (N,) are static per-client aggregation weights
    (e.g. example counts for size-weighted FedAvg); they compose with the
    sampled participation mask inside the scan.

    ``adapters``/``opt_N``/``key`` are donated when ``jit`` and ``donate``.

    A :class:`~repro.core.aggregation.BufferedStrategy` switches to the
    async buffered engine: the scan additionally carries ``async_state``
    ({"tau": (N,) int32 staleness counters, "rho": scalar f32 gamma
    correction}) and the signature becomes run_chunk(base, adapters,
    opt_N, key, round0, async_state, batches=None, num_rounds=None) ->
    (adapters, opt_N, key, async_state, metrics).  ``fault_model``
    (:class:`~repro.core.faults.FaultModel`) injects deterministic
    drop/straggle/corrupt faults from a per-round key derived from the
    carried scan key — identical to the synchronous key stream, so the
    two engines consume randomness identically at staleness 0.
    """
    from repro.core.aggregation import BufferedStrategy
    strat = get_strategy(strategy)
    buffered = isinstance(strat, BufferedStrategy)
    if fault_model is not None and not buffered:
        raise ValueError(
            "fault injection needs the buffered engine — wrap the "
            "strategy with aggregation.buffered(...) (the synchronous "
            "scan cannot represent an in-flight upload)")
    if buffered:
        round_body = make_buffered_round_body(
            model, strategy=strat, opt_cfg=opt_cfg, fault_model=fault_model,
            track_update_norm=track_update_norm)
    else:
        round_body = make_round_body(model, strategy=strat, opt_cfg=opt_cfg,
                                     track_update_norm=track_update_norm)
    size_w = None if client_weights is None else jnp.asarray(
        client_weights, jnp.float32)

    def run_chunk(base, adapters, opt_N, key, round0, async_state=None,
                  batches=None, num_rounds=None):
        # packed frozen base on the reference tier: dequantize UP FRONT,
        # once per compiled chunk — scan-invariant, so XLA materializes the
        # fp view once instead of per round-step.  Fused tiers keep the base
        # packed (per-tile VMEM dequant inside the kernels).
        if has_quantized(base):
            from repro.kernels import dispatch
            with dispatch.scope(model.cfg.use_pallas):
                if dispatch.resolve_mode() == "reference":
                    base = dequantize_tree(base)
        num_clients = jax.tree.leaves(adapters.lora)[0].shape[0]
        num_sampled = max(1, int(round(participation * num_clients)))
        if buffered and async_state is None:
            raise ValueError(
                "the buffered engine carries async_state — pass "
                "{'tau': (N,) int32, 'rho': f32 scalar} (init: zeros, 1.0)")

        def scan_step(carry, xs):
            if buffered:
                aset_c, opt_c, k, tau_c, rho_c = carry
            else:
                aset_c, opt_c, k = carry
            k, k_round = jax.random.split(k)
            # identical split order to the synchronous engine, then a
            # SEPARATE fold for faults: the data/sampling streams match at
            # staleness 0 and the fault stream is chunking-invariant
            k_data, k_sample = jax.random.split(k_round)
            if batch_fn is None:
                round_idx, b = xs
            else:
                round_idx = xs
                b = batch_fn(k_data, round_idx)
            part = None
            if participation < 1.0:
                part = participation_weights(k_sample, num_clients,
                                             num_sampled)
            if buffered:
                k_fault = jax.random.fold_in(k_round, 7)
                aset_c, opt_c, tau_c, rho_c, metrics = round_body(
                    base, aset_c, opt_c, tau_c, rho_c, b, round_idx,
                    k_fault, part=part, size_w=size_w,
                    expected=num_sampled)
                return (aset_c, opt_c, k, tau_c, rho_c), metrics
            weights = part
            if size_w is not None:
                weights = size_w if weights is None else weights * size_w
            aset_c, opt_c, metrics = round_body(base, aset_c, opt_c, b,
                                                round_idx, weights)
            return (aset_c, opt_c, k), metrics

        if batch_fn is None:
            if batches is None:
                raise ValueError("run_chunk needs `batches` unless the "
                                 "engine was built with a batch_fn")
            n_r = jax.tree.leaves(batches)[0].shape[0]
            xs = (round0 + jnp.arange(n_r), batches)
        else:
            if num_rounds is None:
                raise ValueError("run_chunk needs a static `num_rounds` "
                                 "when batches are generated on device")
            xs = round0 + jnp.arange(num_rounds)
        if buffered:
            carry0 = (adapters, opt_N, key, async_state["tau"],
                      async_state["rho"])
            (adapters, opt_N, key, tau, rho), ms = jax.lax.scan(
                scan_step, carry0, xs)
            return adapters, opt_N, key, {"tau": tau, "rho": rho}, ms
        (adapters, opt_N, key), ms = jax.lax.scan(
            scan_step, (adapters, opt_N, key), xs)
        return adapters, opt_N, key, ms

    if not jit:
        return run_chunk
    return jax.jit(run_chunk, static_argnames=("num_rounds",),
                   donate_argnums=((1, 2, 3, 5) if buffered else (1, 2, 3))
                   if donate else ())


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Collapse-watchdog policy for :class:`FederatedTrainer`.

    At every chunk boundary the watchdog judges the chunk's per-round
    ``update_norm`` series with ``stability_report`` (Theorem 4.2).  On a
    failed verdict it rolls the trainer back to the last-good snapshot
    (taken before the chunk) and retries with a recovery action chosen by
    :func:`repro.analysis.stability_check.recovery_action`:

      - ``rescale`` (config half violated): adopt the paper's own fix,
        gamma = alpha*sqrt(N/r) — a mis-scaled gamma is deterministic in
        (gamma, r, N); no amount of retrying fixes it.  Disabled via
        ``rescale_gamma=False`` (then every recovery is a backoff).
      - ``backoff`` (measured drift): multiply participation by
        ``backoff`` (floored at one client) and advance the fault seed,
        so the retry samples a smaller, fresh cohort.

    After ``max_retries`` failed retries of the same chunk the watchdog
    raises :class:`~repro.analysis.stability_check.ScalingCollapseError`.
    Verdicts need >= 2 norms, so chunks of one round are judged on the
    trailing window only once enough history exists.
    """
    max_retries: int = 2
    backoff: float = 0.5
    rescale_gamma: bool = True
    scale_tol: float = 4.0
    trend_tol: float = 8.0


class FederatedTrainer:
    """Host-level orchestration: state, chunked rounds, evaluation.

    ``data_mode``:
      "host"    batches come from ``dataset.round_batch`` on the host and are
                staged per chunk as stacked scan inputs (default — preserves
                the exact host data stream).
      "device"  batches are synthesized inside the scan from the carried PRNG
                key via :class:`repro.data.synthetic.DeviceFederatedData`
                (same topic tables as the host dataset; zero host traffic —
                the large-N stress-test path).

    ``chunk_rounds`` caps how many rounds one ``run_chunk`` call scans over
    (default: the ``log_every`` stride, else the whole ``run`` request).
    ``mesh``: when given, base params are tensor-sharded and the client dim of
    LoRA/optimizer state shards over the mesh's client axes ("pod"/"data")
    per ``sharding/rules.py``.

    Heterogeneous clients: ``lora_cfg.ranks`` (one rank per client) switches
    to the padded-rank representation — every client allocates
    r_max = max(ranks), a per-client rank mask keeps the extra rows inert
    (zero-init, grad-masked, excluded from and re-masked after aggregation),
    and each client trains/serves with its own gamma_i = scaling(alpha, r_i,
    N).  ``fed_cfg.weight_by_size`` additionally weights the server mean by
    the dataset's per-client example counts (``dataset.size_weights``).
    With all ranks equal this path is bit-identical to the homogeneous
    engine (tests/test_conformance.py).
    """

    def __init__(self, model, dataset, *, lora_cfg, fed_cfg, opt_cfg,
                 seed: int = 0, base_params=None, data_mode: str = "host",
                 chunk_rounds: int = 0, mesh=None,
                 track_stability: bool = False, watchdog=None):
        self.model = model
        self.dataset = dataset
        self.fed_cfg = fed_cfg
        self.opt_cfg = opt_cfg
        self.data_mode = data_mode
        self.chunk_rounds = chunk_rounds
        self.mesh = mesh
        # the collapse watchdog judges every chunk, so it needs the
        # update_norm metric the sentinel consumes
        self.watchdog = watchdog
        self.watchdog_events = []
        # opt-in per-round update_norm metric feeding stability_report();
        # off by default so the engine's metrics treedef stays pinned
        self.track_stability = track_stability or watchdog is not None
        # async buffered engine: an explicit buffer config or any fault
        # injection switches the scan to the FedBuff-style round body
        self.async_mode = (fed_cfg.buffer_size is not None
                           or fed_cfg.faults is not None)
        n = fed_cfg.num_clients
        ranks = lora_cfg.ranks
        if ranks is not None:
            # heterogeneous per-client ranks: padded representation at
            # r_max with a per-client rank mask (see core/lora.py)
            ranks = tuple(int(r) for r in ranks)
            if len(ranks) != n:
                raise ValueError(
                    f"lora_cfg.ranks has {len(ranks)} entries but "
                    f"num_clients={n}")
            self.ranks = ranks
            self.rank_mask = rank_mask(ranks)
            self.gammas = per_client_gammas(lora_cfg.scaling, lora_cfg.alpha,
                                            ranks, n)
            # uniform gamma stays a concrete float (and the engine's static
            # fast path); truly mixed gammas have no single value
            self.gamma = (self.gammas[0]
                          if len(set(self.gammas)) == 1 else None)
            lora_cfg = dataclasses.replace(lora_cfg, rank=max(ranks))
        else:
            self.ranks = None
            self.rank_mask = None
            self.gamma = scaling_factor(lora_cfg.scaling, lora_cfg.alpha,
                                        lora_cfg.rank, n)
            self.gammas = (self.gamma,) * n
        self.lora_cfg = lora_cfg      # reflects the padded rank when het
        key = jax.random.key(seed)
        kb, kl = jax.random.split(key)
        self.base = base_params if base_params is not None else model.init(kb)
        lora1 = init_lora(self.base, kl, lora_cfg,
                          targets=lora_cfg.targets)
        # FedSA init: all clients start from the SAME A (and B=0)
        self.lora = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), lora1)
        if self.rank_mask is not None:
            # client i's rows r_i..r_max of A start (and stay) exactly zero
            self.lora = apply_rank_mask(self.lora, self.rank_mask)
        opt_init, _ = make_optimizer(opt_cfg)
        opt1 = opt_init(lora1)
        self.opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), opt1)
        self.client_weights = None
        if fed_cfg.weight_by_size:
            if not hasattr(dataset, "size_weights"):
                raise ValueError(
                    "fed_cfg.weight_by_size needs a dataset exposing "
                    "size_weights (per-client example counts)")
            self.client_weights = jnp.asarray(dataset.size_weights,
                                              jnp.float32)

        if data_mode == "device":
            from repro.data.synthetic import DeviceFederatedData
            self.device_data = DeviceFederatedData.from_host(dataset)
        elif data_mode != "host":
            raise ValueError(f"unknown data_mode '{data_mode}'")
        self._build_engine()
        # async carry: per-client staleness counters + the gamma correction
        # factor rho = sqrt(N_eff/N) (1.0 = fully synchronous)
        self.async_state = None
        # the staleness correction the NEXT chunk's gamma is folded with
        # (quantized host mirror of async_state["rho"]; 1.0 = synchronous)
        self._rho_host = 1.0
        if self.async_mode:
            self.async_state = {"tau": jnp.zeros((n,), jnp.int32),
                                "rho": jnp.asarray(1.0, jnp.float32)}
        # all round-level randomness (participation sampling, device-side
        # data) flows from this carried JAX key — no separate host RNG
        self._key = jax.random.key(seed + 31337)
        self.round_idx = 0
        self.history = []
        if mesh is not None:
            self._place_on_mesh(mesh)
        # cached so repeated evals reuse one compilation (a float gamma
        # rides in the AdapterSet treedef, so it stays trace-static — the
        # fused kernel tier's requirement — and each distinct gamma gets
        # its own executable, exactly like the old static_argnames path)
        self._eval_loss = jax.jit(
            lambda p, b, adapters: model.loss(p, b, adapters=adapters))

    def _build_engine(self):
        """(Re)build the compiled chunk executor from the current config,
        rank mask, size weights, and (device mode) data tables.  ``restore``
        calls this again when the checkpointed data partition differs from
        the constructed one — the old executor's baked-in weights/tables
        would otherwise silently go stale."""
        batch_fn = None
        if self.data_mode == "device":
            device_data = self.device_data
            local_steps = self.fed_cfg.local_steps
            batch_fn = lambda k, ridx: {
                "tokens": device_data.sample_round(k, local_steps)}
        strategy = self.fed_cfg.aggregation
        fault_model = None
        if self.async_mode:
            from repro.core.aggregation import buffered
            from repro.core.faults import FaultModel
            strategy = buffered(
                strategy, buffer_size=self.fed_cfg.buffer_size or 0,
                beta=self.fed_cfg.staleness_beta,
                screen=self.fed_cfg.screen_updates,
                screen_mult=self.fed_cfg.screen_norm_mult)
            fault_model = FaultModel(self.fed_cfg.faults)
        self._run_chunk = make_run_chunk(
            self.model, strategy=strategy,
            opt_cfg=self.opt_cfg,
            participation=self.fed_cfg.participation, batch_fn=batch_fn,
            client_weights=self.client_weights, donate=True,
            track_update_norm=self.track_stability,
            fault_model=fault_model)

    @functools.cached_property
    def round_step(self):
        """Single-round entry over externally supplied batches (callers with
        modality stubs the synthetic dataset cannot produce):
        round_step(base, adapters, opt_N, batches, round_idx, weights=None)
        with ``adapters`` a client-stacked AdapterSet (``trainer.adapters``).
        Compiled lazily — the engine itself runs through ``run_chunk``."""
        return make_fed_round_step(
            self.model, strategy=self.fed_cfg.aggregation,
            opt_cfg=self.opt_cfg, donate=False)

    # ------------------------------------------------------------- adapters

    @property
    def adapters(self) -> AdapterSet:
        """The trainer's client-stacked AdapterSet: the A/B state plus the
        per-client scaling factors and rank mask as one pytree — the unit
        the engine carries, checkpoints serialize, and serving registers
        into an AdapterBank."""
        gamma = self.gammas if self.ranks is not None else self.gamma
        return AdapterSet(lora=self.lora, gamma=gamma,
                          rank_mask=self.rank_mask,
                          rank=self.lora_cfg.rank, alpha=self.lora_cfg.alpha)

    def client_adapters(self, client: int) -> AdapterSet:
        """Client ``client``'s personalized AdapterSet (own gamma_i and
        rank-mask row) — what that client deploys."""
        mask = None if self.rank_mask is None else self.rank_mask[client]
        r = self.ranks[client] if self.ranks else self.lora_cfg.rank
        return AdapterSet(
            lora=jax.tree.map(lambda x: x[client], self.lora),
            gamma=self.gammas[client], rank_mask=mask, rank=int(r),
            alpha=self.lora_cfg.alpha)

    # ------------------------------------------------------------- sharding

    def _place_on_mesh(self, mesh):
        from repro.sharding import rules
        self.base = jax.device_put(self.base,
                                   rules.params_sharding(self.base, mesh))
        self.lora = jax.device_put(self.lora,
                                   rules.lora_sharding(self.lora, mesh))
        self.opt_state = jax.device_put(
            self.opt_state, rules.lora_sharding(self.opt_state, mesh))

    def _mesh_scope(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.sharding.specs import use_mesh
        return use_mesh(self.mesh)

    # -------------------------------------------------------------- running

    def _stage_batches(self, num_rounds: int):
        """Host data for the next ``num_rounds`` rounds, stacked for the
        scan: leaves (num_rounds, N, local_steps, batch, seq)."""
        nb = np.stack([self.dataset.round_batch(self.fed_cfg.local_steps)
                       for _ in range(num_rounds)])
        batches = {"tokens": jnp.asarray(nb)}
        if self.mesh is not None:
            from repro.sharding import rules
            batches = jax.device_put(
                batches, rules.chunked_inputs_sharding(batches, self.mesh))
        return batches

    def _train_adapters(self) -> AdapterSet:
        """The AdapterSet the next chunk trains with: the configured
        adapters, with the staleness correction rho folded into gamma
        (gamma_eff = gamma * rho, Theorem 4.2's alpha*sqrt(N_eff/r)).
        The fold is STATIC — gamma rides the treedef — so the staleness-0
        path (rho == 1.0) reuses the synchronous executable bit-exactly."""
        aset = self.adapters
        if self.async_state is None or self._rho_host == 1.0:
            return aset
        g = aset.gamma
        g = (tuple(x * self._rho_host for x in g) if isinstance(g, tuple)
             else g * self._rho_host)
        return dataclasses.replace(aset, gamma=g)

    def _run_one_chunk(self, num_rounds: int):
        kwargs = {}
        if self.data_mode == "device":
            kwargs["num_rounds"] = num_rounds
        else:
            kwargs["batches"] = self._stage_batches(num_rounds)
        with self._mesh_scope():
            if self.async_mode:
                (aset, self.opt_state, self._key, self.async_state,
                 ms) = self._run_chunk(
                    self.base, self._train_adapters(), self.opt_state,
                    self._key, jnp.asarray(self.round_idx, jnp.int32),
                    self.async_state, **kwargs)
                self._rho_host = _quantize_rho(
                    float(self.async_state["rho"]))
            else:
                aset, self.opt_state, self._key, ms = self._run_chunk(
                    self.base, self.adapters, self.opt_state, self._key,
                    jnp.asarray(self.round_idx, jnp.int32), **kwargs)
        # only the A/B tree is engine state (gamma/rank mask are static
        # config riding in the AdapterSet treedef — the trainer keeps its
        # own uniform-rank mask even though the canonical AdapterSet form
        # collapses an all-ones mask to None)
        self.lora = aset.lora
        ms = {k: np.asarray(v) for k, v in ms.items()}
        out = []
        for i in range(num_rounds):
            self.round_idx += 1
            m = {k: float(v[i]) for k, v in ms.items()}
            m["round"] = self.round_idx
            self.history.append(m)
            out.append(m)
        return out

    # ------------------------------------------------------------- watchdog

    def _snapshot(self):
        """Host copy of everything a chunk mutates — taken BEFORE the
        chunk runs (the engine donates its device buffers, so the copies
        must leave the device first)."""
        host = lambda t: jax.tree.map(lambda x: np.asarray(x), t)
        snap = {"lora": host(self.lora), "opt": host(self.opt_state),
                "key": np.asarray(jax.random.key_data(self._key)),
                "round": self.round_idx, "hist": len(self.history),
                "events": len(self.watchdog_events),
                "rho_host": self._rho_host}
        if self.async_state is not None:
            snap["async"] = host(self.async_state)
        if self.data_mode == "host" and hasattr(self.dataset, "rng_state"):
            snap["data_state"] = self.dataset.rng_state()
        return snap

    def _rollback(self, snap):
        """Restore the last-good snapshot (state, PRNG streams, history)."""
        dev = lambda t: jax.tree.map(jnp.asarray, t)
        self.lora = dev(snap["lora"])
        self.opt_state = dev(snap["opt"])
        self._key = jax.random.wrap_key_data(jnp.asarray(snap["key"]))
        self.round_idx = snap["round"]
        del self.history[snap["hist"]:]
        self._rho_host = snap["rho_host"]
        if "async" in snap:
            self.async_state = {
                "tau": jnp.asarray(snap["async"]["tau"], jnp.int32),
                "rho": jnp.asarray(snap["async"]["rho"], jnp.float32)}
        if "data_state" in snap and hasattr(self.dataset, "set_rng_state"):
            self.dataset.set_rng_state(snap["data_state"])
        if self.mesh is not None:
            self._place_on_mesh(self.mesh)

    def _chunk_report(self, chunk_len: int):
        """Stability verdict over the chunk just run (its own norms only —
        a mid-run gamma rescale must not make the trend straddle two
        scaling regimes).  Falls back to the trailing two-round window for
        chunks of one; None when there is not enough history yet."""
        wd = self.watchdog
        norms = [h["update_norm"] for h in self.history
                 if "update_norm" in h]
        norms = norms[-max(chunk_len, 2):]
        if len(norms) < 2:
            return None
        from repro.analysis.stability_check import stability_report
        gamma = (self.gamma if self.gamma is not None
                 else float(np.mean(self.gammas)))
        return stability_report(
            norms, gamma=gamma, r=self.lora_cfg.rank,
            n_clients=self.fed_cfg.num_clients, alpha=self.lora_cfg.alpha,
            scale_tol=wd.scale_tol, trend_tol=wd.trend_tol)

    def _recover(self, report, retries: int):
        """Apply the retry policy for a failed chunk verdict."""
        from repro.analysis.stability_check import recovery_action
        wd = self.watchdog
        action = recovery_action(report, scale_tol=wd.scale_tol)
        n = self.fed_cfg.num_clients
        if action == "rescale" and wd.rescale_gamma:
            # adopt the paper's factor: gamma = alpha*sqrt(N/r) (per-client
            # gamma_i under heterogeneous ranks).  gamma rides in the
            # AdapterSet treedef, so the next chunk recompiles once with
            # the new static scale — no engine rebuild needed.
            if self.ranks is not None:
                self.gammas = per_client_gammas(
                    "sfedlora", self.lora_cfg.alpha, self.ranks, n)
                self.gamma = (self.gammas[0]
                              if len(set(self.gammas)) == 1 else None)
            else:
                self.gamma = scaling_factor(
                    "sfedlora", self.lora_cfg.alpha, self.lora_cfg.rank, n)
                self.gammas = (self.gamma,) * n
            self.lora_cfg = dataclasses.replace(self.lora_cfg,
                                                scaling="sfedlora")
            detail = f"gamma->{(self.gamma or self.gammas[0]):.4g} (sfedlora)"
        else:
            action = "backoff"
            p = max(self.fed_cfg.participation * wd.backoff, 1.0 / n)
            faults = self.fed_cfg.faults
            if faults is not None:
                faults = dataclasses.replace(faults, seed=faults.seed + 1)
            self.fed_cfg = dataclasses.replace(self.fed_cfg,
                                               participation=p,
                                               faults=faults)
            # participation and the fault seed are baked into the compiled
            # scan — rebuild (rare: only on a recovery event)
            self._build_engine()
            detail = f"participation->{p:.3g}, fault seed advanced"
        self.watchdog_events.append(
            {"round": self.round_idx, "verdict": report.verdict,
             "action": action, "detail": detail, "retry": retries + 1})

    def _run_chunk_watched(self, chunk: int):
        """Run one chunk under the watchdog: snapshot, run, judge; on a
        failed verdict roll back, recover, retry (bounded)."""
        if self.watchdog is None:
            return self._run_one_chunk(chunk)
        from repro.analysis.stability_check import ScalingCollapseError
        retries = 0
        while True:
            snap = self._snapshot()
            out = self._run_one_chunk(chunk)
            report = self._chunk_report(chunk)
            if report is None or report.ok:
                return out
            if retries >= self.watchdog.max_retries:
                raise ScalingCollapseError(
                    f"watchdog: chunk ending at round {self.round_idx} "
                    f"still '{report.verdict}' after {retries} "
                    f"retries: {report}")
            self._rollback(snap)
            self._recover(report, retries)
            retries += 1

    # -------------------------------------------------------------- running

    def run_round(self):
        """One federated round (a chunk of one — same compiled round body as
        chunked execution, so the two stay bit-identical)."""
        return self._run_chunk_watched(1)[0]

    def run(self, rounds=None, log_every: int = 0):
        # each distinct chunk length compiles its own scan; a trailing
        # partial chunk (rounds % stride != 0) therefore costs one extra
        # compile — pick chunk_rounds dividing the round budget to avoid it
        rounds = rounds or self.fed_cfg.rounds
        done = 0
        while done < rounds:
            chunk = min(self.chunk_rounds or log_every or rounds,
                        rounds - done)
            for m in self._run_chunk_watched(chunk):
                if log_every and m["round"] % log_every == 0:
                    print(f"round {m['round']:4d}  loss {m['loss']:.4f}  "
                          f"|g| {m['grad_norm']:.3e}  "
                          f"ppl {np.exp(m['loss']):.2f}")
            done += chunk
        return self.history

    def client_gamma(self, client: int) -> float:
        """The scaling factor client ``client`` trains and serves with
        (gamma_i = scaling(alpha, r_i, N) under heterogeneous ranks)."""
        return self.gammas[client]

    @property
    def gamma_eff(self) -> float:
        """The staleness-corrected scaling factor the NEXT chunk trains
        with: gamma * rho where rho = sqrt(N_eff/N) from the last buffered
        round, quantized for the static treedef fold (1.0 — i.e. plain
        gamma — when synchronous or before any round has run)."""
        base = (self.gamma if self.gamma is not None
                else float(np.mean(self.gammas)))
        return base * self._rho_host

    def stability_report(self, **kwargs):
        """Judge the run's per-round ``update_norm`` series against the
        Theorem 4.2 moment-scale prediction (requires
        ``track_stability=True``; see repro.analysis.stability_check)."""
        from repro.analysis.stability_check import stability_report
        norms = [h["update_norm"] for h in self.history
                 if "update_norm" in h]
        if len(norms) < 2:
            raise ValueError(
                "stability_report needs >= 2 rounds of update_norm history "
                "— construct the trainer with track_stability=True and run "
                "at least two rounds")
        gamma = (self.gamma if self.gamma is not None
                 else float(np.mean(self.gammas)))
        return stability_report(
            norms, gamma=gamma, r=self.lora_cfg.rank,
            n_clients=self.fed_cfg.num_clients, alpha=self.lora_cfg.alpha,
            **kwargs)

    def publish_adapters(self, live, clients=None) -> int:
        """Push the current round's adapters into a live serving bank.

        ``live`` is a :class:`~repro.core.lora.LiveAdapterBank`; each
        client's personalized AdapterSet (own gamma_i folded in, rank-mask
        row applied) is published under its client index as the tenant id.
        Resident tenants hot-swap on device between decode chunks with zero
        recompiles; the rest land in the host store.  Returns the number of
        tenants published."""
        clients = range(self.fed_cfg.num_clients) if clients is None else clients
        n = 0
        for c in clients:
            live.publish(int(c), self.client_adapters(int(c)))
            n += 1
        return n

    def eval_perplexity(self, batch: int = 16, client: int = 0) -> float:
        """Held-out perplexity using client ``client``'s personalized model."""
        toks = jnp.asarray(self.dataset.eval_batch(batch))
        loss, _ = self._eval_loss(self.base, {"tokens": toks},
                                  self.client_adapters(client))
        return float(jnp.exp(loss))

    # ----------------------------------------------------------- checkpoint

    def save(self, path: str) -> None:
        """Checkpoint state + round index + PRNG key (+ the host dataset's
        RNG stream state, the per-client rank mask, and the data-partition
        state) so a restored run continues bit-exactly.  The whole
        AdapterSet round-trips: gammas/alpha/ranks/scaling ride along as
        ``adapter_meta`` so serving can rebuild it without the trainer."""
        from repro.checkpoint.io import save_federated_state
        data_state = None
        if self.data_mode == "host" and hasattr(self.dataset, "rng_state"):
            data_state = self.dataset.rng_state()
        partition_state = None
        if hasattr(self.dataset, "partition_state"):
            partition_state = self.dataset.partition_state()
        meta = {
            "gammas": np.asarray(self.gammas, np.float32),
            "alpha": float(self.lora_cfg.alpha),
            "rank": int(self.lora_cfg.rank),
            "ranks": np.asarray(self.ranks if self.ranks is not None
                                else (self.lora_cfg.rank,)
                                * self.fed_cfg.num_clients, np.int64),
            "scaling": self.lora_cfg.scaling,
        }
        async_state = None
        if self.async_state is not None:
            async_state = {k: np.asarray(v)
                           for k, v in self.async_state.items()}
        save_federated_state(path, self.base, self.lora, self.opt_state,
                             self.round_idx, key=self._key,
                             data_state=data_state,
                             rank_mask=self.rank_mask,
                             partition_state=partition_state,
                             adapter_meta=meta,
                             async_state=async_state)

    def restore(self, path: str) -> None:
        from repro.checkpoint.io import load_federated_state
        base, lora, opt, rnd, key, data_state, extras = load_federated_state(
            path, full=True)
        ck_mask = extras.get("rank_mask")
        if (ck_mask is None) != (self.rank_mask is None) or (
                ck_mask is not None and not np.array_equal(
                    np.asarray(ck_mask), np.asarray(self.rank_mask))):
            raise ValueError(
                "checkpoint per-client rank mask does not match this "
                "trainer's configured ranks — restore with the same "
                "lora_cfg.ranks the run was saved with")
        if "partition_state" in extras and hasattr(self.dataset,
                                                   "set_partition_state"):
            unchanged = (hasattr(self.dataset, "partition_state") and
                         self.dataset.partition_state()
                         == extras["partition_state"])
            self.dataset.set_partition_state(extras["partition_state"])
            if not unchanged:
                # the compiled engine baked in the constructed partition's
                # size weights / device data tables — rebuild it so the
                # resumed run aggregates under the CHECKPOINTED partition
                if self.data_mode == "device":
                    from repro.data.synthetic import DeviceFederatedData
                    self.device_data = DeviceFederatedData.from_host(
                        self.dataset)
                if self.client_weights is not None:
                    self.client_weights = jnp.asarray(
                        self.dataset.size_weights, jnp.float32)
                self._build_engine()
        self.base, self.lora, self.opt_state = base, lora, opt
        self.round_idx = rnd
        # drop history entries from beyond the restored round so consumers
        # never mix two timelines
        self.history = [h for h in self.history if h["round"] <= rnd]
        if key is not None:
            self._key = key
        if data_state is not None and hasattr(self.dataset, "set_rng_state"):
            self.dataset.set_rng_state(data_state)
        if self.async_mode:
            ck_async = extras.get("async_state")
            if ck_async is not None:
                self.async_state = {
                    "tau": jnp.asarray(ck_async["tau"], jnp.int32),
                    "rho": jnp.asarray(ck_async["rho"], jnp.float32)}
            else:
                # legacy (synchronous-era) checkpoint: fresh async carry
                self.async_state = {
                    "tau": jnp.zeros((self.fed_cfg.num_clients,), jnp.int32),
                    "rho": jnp.asarray(1.0, jnp.float32)}
            # the fold mirror is derived, not stored — recompute it so the
            # resumed chunk trains with the same gamma_eff the
            # uninterrupted run would have used
            self._rho_host = _quantize_rho(float(self.async_state["rho"]))
        if self.mesh is not None:
            self._place_on_mesh(self.mesh)

"""Federated LoRA fine-tuning: the jitted round step and a host-level trainer.

One federated round (paper §3):
  1. every client runs ``local_steps`` SGD/AdamW steps on its LoRA params
     (vmap over the client dim — on a mesh the client dim shards over
     ``data``/``pod`` axes, so local training is collective-free),
  2. the server aggregates per the strategy (FedSA/SFed: mean of A only —
     one small all-reduce over the client axes),
  3. the aggregate is broadcast back (same collective).

The scaling factor gamma = scaling_factor(scheme, alpha, r, N) multiplies the
adapter product in every forward pass — SFed-LoRA's contribution is that this
is sqrt(N/r), tied to the *distribution config*, not just the adapter shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (aggregate_clients, mask_grads,
                                    strategy_flags)
from repro.core.lora import init_lora
from repro.core.scaling import scaling_factor
from repro.optim.optimizers import apply_updates, global_norm, make_optimizer


def make_fed_round_step(model, *, strategy: str, opt_cfg, gamma: float,
                        donate: bool = True, jit: bool = True):
    """Returns round_step(base, lora_N, opt_N, batches, round_idx).

    ``lora_N``/``opt_N`` have a leading client dim; ``batches`` leaves are
    (N, local_steps, batch, ...).  Returns (lora_N, opt_N, metrics).
    With ``jit=False`` returns the raw function (the dry-run wraps it in its
    own pjit with explicit shardings).
    """
    opt_init, opt_update = make_optimizer(opt_cfg)

    def client_local(base, lora, opt_state, batches, round_idx):
        (train_a, train_b), _ = strategy_flags(strategy, round_idx)

        def step(carry, batch):
            lo, st = carry
            def loss_fn(l):
                return model.loss(base, batch, lora=l, gamma=gamma)
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(lo)
            gnorm = global_norm(grads)
            grads = mask_grads(grads, train_a, train_b)
            if opt_cfg.grad_clip:
                from repro.optim.optimizers import clip_by_global_norm
                grads = clip_by_global_norm(grads, opt_cfg.grad_clip)
            updates, st = opt_update(grads, st, lo)
            lo = apply_updates(lo, updates)
            return (lo, st), {"loss": loss, "grad_norm": gnorm}

        (lora, opt_state), ms = jax.lax.scan(step, (lora, opt_state), batches)
        return lora, opt_state, ms

    def round_step(base, lora_N, opt_N, batches, round_idx, weights=None):
        """``weights`` (N,) in {0,1}: partial participation — non-sampled
        clients keep their previous local state and only receive the
        aggregate."""
        new_lora, new_opt, ms = jax.vmap(
            client_local, in_axes=(None, 0, 0, 0, None))(
                base, lora_N, opt_N, batches, round_idx)
        if weights is not None:
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(
                    weights.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
                new, old)
            new_lora = sel(new_lora, lora_N)
            new_opt = sel(new_opt, opt_N)
        _, (agg_a, agg_b) = strategy_flags(strategy, round_idx)
        new_lora = aggregate_clients(new_lora, agg_a, agg_b, weights=weights)
        metrics = {"loss": ms["loss"].mean(), "grad_norm": ms["grad_norm"].mean()}
        return new_lora, new_opt, metrics

    if not jit:
        return round_step
    return jax.jit(round_step, donate_argnums=(1, 2) if donate else ())


class FederatedTrainer:
    """Host-level orchestration: state, rounds, evaluation."""

    def __init__(self, model, dataset, *, lora_cfg, fed_cfg, opt_cfg,
                 seed: int = 0, base_params=None):
        self.model = model
        self.dataset = dataset
        self.fed_cfg = fed_cfg
        self.lora_cfg = lora_cfg
        n = fed_cfg.num_clients
        self.gamma = scaling_factor(lora_cfg.scaling, lora_cfg.alpha,
                                    lora_cfg.rank, n)
        key = jax.random.key(seed)
        kb, kl = jax.random.split(key)
        self.base = base_params if base_params is not None else model.init(kb)
        lora1 = init_lora(self.base, kl, lora_cfg,
                          targets=lora_cfg.targets)
        # FedSA init: all clients start from the SAME A (and B=0)
        self.lora = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), lora1)
        opt_init, _ = make_optimizer(opt_cfg)
        opt1 = opt_init(lora1)
        self.opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), opt1)
        self.round_step = make_fed_round_step(
            model, strategy=fed_cfg.aggregation, opt_cfg=opt_cfg,
            gamma=self.gamma, donate=False)
        self.round_idx = 0
        self.history = []
        # cached so repeated evals reuse one compilation (gamma is static:
        # the fused kernel tier bakes it into the Pallas kernels at trace
        # time, so it cannot be a traced argument)
        self._eval_loss = jax.jit(model.loss, static_argnames=("gamma",))
        import numpy as _np
        self._rng = _np.random.default_rng(seed + 31337)

    def run_round(self):
        nb = self.dataset.round_batch(self.fed_cfg.local_steps)
        batches = {"tokens": jnp.asarray(nb)}
        n = self.fed_cfg.num_clients
        weights = None
        if self.fed_cfg.participation < 1.0:
            k = max(1, int(round(self.fed_cfg.participation * n)))
            idx = self._rng.choice(n, size=k, replace=False)
            weights = jnp.zeros((n,), jnp.float32).at[idx].set(1.0)
        self.lora, self.opt_state, m = self.round_step(
            self.base, self.lora, self.opt_state, batches,
            jnp.asarray(self.round_idx), weights)
        self.round_idx += 1
        m = {k: float(v) for k, v in m.items()}
        m["round"] = self.round_idx
        self.history.append(m)
        return m

    def run(self, rounds=None, log_every: int = 0):
        rounds = rounds or self.fed_cfg.rounds
        for _ in range(rounds):
            m = self.run_round()
            if log_every and self.round_idx % log_every == 0:
                print(f"round {self.round_idx:4d}  loss {m['loss']:.4f}  "
                      f"|g| {m['grad_norm']:.3e}  ppl {np.exp(m['loss']):.2f}")
        return self.history

    def eval_perplexity(self, batch: int = 16, client: int = 0) -> float:
        """Held-out perplexity using client ``client``'s personalized model."""
        toks = jnp.asarray(self.dataset.eval_batch(batch))
        lora_i = jax.tree.map(lambda x: x[client], self.lora)
        loss, _ = self._eval_loss(self.base, {"tokens": toks}, lora=lora_i,
                                  gamma=self.gamma)
        return float(jnp.exp(loss))

from repro.core.scaling import scaling_factor, SCALINGS
from repro.core.lora import (AdapterBank, AdapterSet, init_adapter_set,
                             init_lora, merge_lora)
from repro.core.aggregation import (REGISTRY, STRATEGIES, Strategy,
                                    aggregate_clients, get_strategy)
from repro.core.federated import (FederatedTrainer, make_fed_round_step,
                                  make_run_chunk)

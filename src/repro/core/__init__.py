from repro.core.scaling import scaling_factor, SCALINGS
from repro.core.lora import init_lora, merge_lora
from repro.core.aggregation import STRATEGIES, aggregate_clients
from repro.core.federated import FederatedTrainer, make_fed_round_step

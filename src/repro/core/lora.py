"""LoRA parameter trees mirroring a model's stacked block parameters.

The LoRA tree has the same {"stack": {"repeat": {"p0": ...}, "tail": ...}}
shape as the base params, but each targeted projection leaf ``w (d_in, d_out)``
becomes ``{"a": (r, d_in), "b": (d_out, r)}`` (stacked over the scan dim for
repeated blocks, and over the client dim in federated training).

Initialization follows the paper / standard LoRA: A ~ N(0, sigma^2), B = 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# which leaves inside each block subtree are adaptable, per target name
_TARGET_SUBTREES = ("attn", "cross", "mlstm", "rglru")
_TARGET_LEAVES = {
    "q": ("attn/q", "cross/q", "mlstm/q"),
    "k": ("attn/k", "cross/k", "mlstm/k"),
    "v": ("attn/v", "cross/v", "mlstm/v"),
    "o": ("attn/o", "cross/o", "mlstm/o"),
    "wx": ("rglru/wx",),
    "wy": ("rglru/wy",),
}


def _targeted_paths(targets):
    out = set()
    for t in targets:
        out.update(_TARGET_LEAVES.get(t, ()))
    return out


def init_lora(params, key, lora_cfg, *, targets=None):
    """Build a LoRA tree for every targeted projection found in ``params``.

    Works on the full model params (walks into "stack"/"encoder") and keeps
    leading stack dims, so scanned blocks get stacked adapters.
    """
    targets = _targeted_paths(targets or lora_cfg.targets)
    r = lora_cfg.rank
    std = lora_cfg.init_std
    counter = [0]

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                sub = walk(v, path + (k,))
                if sub is not None:
                    out[k] = sub
            return out or None
        # leaf array: check if its (parent, name) is targeted
        tail = "/".join(path[-2:])
        if tail not in targets:
            return None
        arr = node
        lead = arr.shape[:-2]              # stacked scan dims
        d_in, d_out = arr.shape[-2:]
        counter[0] += 1
        ka = jax.random.fold_in(key, counter[0])
        a = jax.random.normal(ka, lead + (r, d_in), jnp.float32) * std
        b = jnp.zeros(lead + (d_out, r), jnp.float32)
        return {"a": a.astype(arr.dtype), "b": b.astype(arr.dtype)}

    return walk(params, ()) or {}


def lora_tree_for_model(model, key, lora_cfg):
    """LoRA tree from the model config alone (via eval_shape init)."""
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    shapes = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)
    return init_lora(shapes, key, lora_cfg)


def merge_lora(params, lora, gamma):
    """W0 + gamma * B A merged into the base weights (inference-time,
    zero-latency deployment — the paper's 'no inference cost' property)."""
    def merge_node(p_node, l_node):
        if not (isinstance(l_node, dict)):
            return p_node
        if set(l_node) == {"a", "b"}:
            a, b = l_node["a"], l_node["b"]
            delta = jnp.einsum("...or,...ri->...io", b, a) * gamma
            return (p_node + delta.astype(p_node.dtype))
        if isinstance(p_node, dict):
            return {k: merge_node(v, l_node.get(k, None))
                    for k, v in p_node.items()}
        return p_node

    return merge_node(params, lora)


def num_lora_params(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))


# ------------------------------------------------------- heterogeneous ranks
#
# Heterogeneous per-client ranks use a PADDED representation: every client
# allocates rank r_max = max(ranks) so the client-stacked trees, the
# lax.scan engine, and the mesh sharding of the client dim all keep one
# uniform shape — client i's rows r_i..r_max of A (and columns of B) are
# inert: zero at init, gradient-masked during local steps, re-masked after
# every server aggregate, and excluded from aggregation means.

def rank_mask(ranks, r_max: int = 0):
    """(N, r_max) float32 mask: row i is r_i ones then r_max - r_i zeros."""
    ranks = tuple(int(r) for r in ranks)
    if not ranks or any(r < 1 for r in ranks):
        raise ValueError(f"per-client ranks must all be >= 1, got {ranks}")
    r_max = r_max or max(ranks)
    if max(ranks) > r_max:
        raise ValueError(f"rank {max(ranks)} exceeds padded r_max={r_max}")
    return (jnp.arange(r_max)[None, :]
            < jnp.asarray(ranks)[:, None]).astype(jnp.float32)


def _walk_ab(tree, fn_a, fn_b):
    """Apply fn_a / fn_b to the a / b leaves of every adapter node (the one
    canonical adapter-tree walker — ``core/aggregation`` imports it as
    ``_map_ab``).  Nodes holding only one of the two matrices (e.g. the
    output of :func:`split_ab`) are tolerated."""
    def walk(node):
        if isinstance(node, dict):
            if node and set(node) <= {"a", "b"}:
                out = {}
                if "a" in node:
                    out["a"] = fn_a(node["a"])
                if "b" in node:
                    out["b"] = fn_b(node["b"])
                return out
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def rank_leaf_mask(mask, x, which: str):
    """Broadcast a (N, r) rank mask against a client-stacked adapter leaf:
    the rank dim is axis -2 on 'a' leaves ((N, ..., r, d_in)) and axis -1
    on 'b' leaves ((N, ..., d_out, r))."""
    n, r = mask.shape
    if which == "a":
        shape = (n,) + (1,) * (x.ndim - 3) + (r, 1)
    else:
        shape = (n,) + (1,) * (x.ndim - 2) + (r,)
    return mask.reshape(shape).astype(x.dtype)


def apply_rank_mask(lora_stacked, mask):
    """Zero the inactive rank rows of A / columns of B per client.

    ``lora_stacked`` has a leading client dim on every leaf
    (a: (N, ..., r, d_in), b: (N, ..., d_out, r)); ``mask`` is (N, r).
    """
    fa = lambda x: x * rank_leaf_mask(mask, x, "a")
    fb = lambda x: x * rank_leaf_mask(mask, x, "b")
    return _walk_ab(lora_stacked, fa, fb)


def mask_rank_tree(lora, mask_row):
    """Single-client version of :func:`apply_rank_mask` (``mask_row`` (r,)
    — typically a traced row under the engine's vmap over clients): zeroes
    rank rows of A / columns of B, e.g. for per-client gradient masking."""
    fa = lambda x: x * mask_row[..., :, None].astype(x.dtype)
    fb = lambda x: x * mask_row.astype(x.dtype)
    return _walk_ab(lora, fa, fb)


def scale_lora_b(lora, scale):
    """Scale every B matrix by ``scale`` (may be traced).

    Folding a per-client gamma_i into B — y = xW + 1.0 * (x A^T)(gamma B)^T
    — is mathematically identical to gamma * B A and keeps the gamma passed
    to the kernels a static 1.0, which the fused Pallas tier requires."""
    fb = lambda x: x * jnp.asarray(scale, x.dtype)
    return _walk_ab(lora, lambda a: a, fb)


def split_ab(lora):
    """Split a LoRA tree into (A-only tree, B-only tree) with the same
    structure — used by the selective-aggregation strategies.  Nodes holding
    only one of the two matrices (e.g. the output of a previous split) yield
    an empty dict on the missing side."""
    def pick(node, which):
        if isinstance(node, dict):
            if node and set(node) <= {"a", "b"}:
                return {which: node[which]} if which in node else {}
            return {k: pick(v, which) for k, v in node.items()}
        return node

    return pick(lora, "a"), pick(lora, "b")

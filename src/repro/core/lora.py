"""LoRA parameter trees and the first-class adapter API.

The LoRA tree has the same {"stack": {"repeat": {"p0": ...}, "tail": ...}}
shape as the base params, but each targeted projection leaf ``w (d_in, d_out)``
becomes ``{"a": (r, d_in), "b": (d_out, r)}`` (stacked over the scan dim for
repeated blocks, and over the client dim in federated training).

Initialization follows the paper / standard LoRA: A ~ N(0, sigma^2), B = 0.

The unit the rest of the codebase consumes is :class:`AdapterSet` — the A/B
tree, the scaling factor gamma, the (optional) per-client rank mask, and the
rank/alpha metadata traveling as ONE registered pytree.  Every place that
used to thread ``(lora, gamma, rank_mask)`` as loose arguments (the model
API, the federated engine, checkpointing, serving) takes a single
``adapters=`` argument instead, and every gamma fold — static, traced,
per-client — happens in exactly one place: :meth:`AdapterSet.fold_gamma`.
:class:`AdapterBank` stacks K prepared adapter sets for multi-tenant
serving: per-request adapter ids gather from the bank inside one compiled
decode step.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as onp

from repro.analysis.hostcheck import check_adapter_ids
from repro.core.quant import QuantizedLinear, dequantize

# which leaves inside each block subtree are adaptable, per target name
_TARGET_SUBTREES = ("attn", "cross", "mlstm", "rglru")
_TARGET_LEAVES = {
    "q": ("attn/q", "cross/q", "mlstm/q"),
    "k": ("attn/k", "cross/k", "mlstm/k"),
    "v": ("attn/v", "cross/v", "mlstm/v"),
    "o": ("attn/o", "cross/o", "mlstm/o"),
    "wx": ("rglru/wx",),
    "wy": ("rglru/wy",),
}


def _targeted_paths(targets):
    out = set()
    for t in targets:
        out.update(_TARGET_LEAVES.get(t, ()))
    return out


def init_lora(params, key, lora_cfg, *, targets=None):
    """Build a LoRA tree for every targeted projection found in ``params``.

    Works on the full model params (walks into "stack"/"encoder") and keeps
    leading stack dims, so scanned blocks get stacked adapters.
    """
    targets = _targeted_paths(targets or lora_cfg.targets)
    r = lora_cfg.rank
    std = lora_cfg.init_std
    counter = [0]

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                sub = walk(v, path + (k,))
                if sub is not None:
                    out[k] = sub
            return out or None
        # leaf array: check if its (parent, name) is targeted
        tail = "/".join(path[-2:])
        if tail not in targets:
            return None
        arr = node
        lead = arr.shape[:-2]              # stacked scan dims
        d_in, d_out = arr.shape[-2:]
        counter[0] += 1
        ka = jax.random.fold_in(key, counter[0])
        a = jax.random.normal(ka, lead + (r, d_in), jnp.float32) * std
        b = jnp.zeros(lead + (d_out, r), jnp.float32)
        return {"a": a.astype(arr.dtype), "b": b.astype(arr.dtype)}

    return walk(params, ()) or {}


def lora_tree_for_model(model, key, lora_cfg):
    """LoRA tree from the model config alone (via eval_shape init)."""
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    shapes = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)
    return init_lora(shapes, key, lora_cfg)


def merge_lora(params, lora, gamma):
    """W0 + gamma * B A merged into the base weights (inference-time,
    zero-latency deployment — the paper's 'no inference cost' property)."""
    def merge_node(p_node, l_node):
        if not (isinstance(l_node, dict)):
            return p_node
        if set(l_node) == {"a", "b"}:
            a, b = l_node["a"], l_node["b"]
            delta = jnp.einsum("...or,...ri->...io", b, a) * gamma
            if isinstance(p_node, QuantizedLinear):
                # merged weights leave packed form: the sum W0 + gamma B A is
                # not representable on W0's quantization grid.  Callers that
                # want a packed merged base re-quantize the result.
                w = dequantize(p_node)
                return w + delta.astype(w.dtype)
            return (p_node + delta.astype(p_node.dtype))
        if isinstance(p_node, dict):
            return {k: merge_node(v, l_node.get(k, None))
                    for k, v in p_node.items()}
        return p_node

    return merge_node(params, lora)


def num_lora_params(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))


# ------------------------------------------------------- heterogeneous ranks
#
# Heterogeneous per-client ranks use a PADDED representation: every client
# allocates rank r_max = max(ranks) so the client-stacked trees, the
# lax.scan engine, and the mesh sharding of the client dim all keep one
# uniform shape — client i's rows r_i..r_max of A (and columns of B) are
# inert: zero at init, gradient-masked during local steps, re-masked after
# every server aggregate, and excluded from aggregation means.

def rank_mask(ranks, r_max: int = 0):
    """(N, r_max) float32 mask: row i is r_i ones then r_max - r_i zeros."""
    ranks = tuple(int(r) for r in ranks)
    if not ranks or any(r < 1 for r in ranks):
        raise ValueError(f"per-client ranks must all be >= 1, got {ranks}")
    r_max = r_max or max(ranks)
    if max(ranks) > r_max:
        raise ValueError(f"rank {max(ranks)} exceeds padded r_max={r_max}")
    return (jnp.arange(r_max)[None, :]
            < jnp.asarray(ranks)[:, None]).astype(jnp.float32)


def _walk_ab(tree, fn_a, fn_b):
    """Apply fn_a / fn_b to the a / b leaves of every adapter node (the one
    canonical adapter-tree walker — ``core/aggregation`` imports it as
    ``_map_ab``).  Nodes holding only one of the two matrices (e.g. the
    output of :func:`split_ab`) are tolerated."""
    def walk(node):
        if isinstance(node, dict):
            if node and set(node) <= {"a", "b"}:
                out = {}
                if "a" in node:
                    out["a"] = fn_a(node["a"])
                if "b" in node:
                    out["b"] = fn_b(node["b"])
                return out
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def rank_leaf_mask(mask, x, which: str):
    """Broadcast a (N, r) rank mask against a client-stacked adapter leaf:
    the rank dim is axis -2 on 'a' leaves ((N, ..., r, d_in)) and axis -1
    on 'b' leaves ((N, ..., d_out, r))."""
    n, r = mask.shape
    if which == "a":
        shape = (n,) + (1,) * (x.ndim - 3) + (r, 1)
    else:
        shape = (n,) + (1,) * (x.ndim - 2) + (r,)
    return mask.reshape(shape).astype(x.dtype)


def apply_rank_mask(lora_stacked, mask):
    """Zero the inactive rank rows of A / columns of B per client.

    ``lora_stacked`` has a leading client dim on every leaf
    (a: (N, ..., r, d_in), b: (N, ..., d_out, r)); ``mask`` is (N, r).
    """
    fa = lambda x: x * rank_leaf_mask(mask, x, "a")
    fb = lambda x: x * rank_leaf_mask(mask, x, "b")
    return _walk_ab(lora_stacked, fa, fb)


def mask_rank_tree(lora, mask_row):
    """Single-client version of :func:`apply_rank_mask` (``mask_row`` (r,)
    — typically a traced row under the engine's vmap over clients): zeroes
    rank rows of A / columns of B, e.g. for per-client gradient masking."""
    fa = lambda x: x * mask_row[..., :, None].astype(x.dtype)
    fb = lambda x: x * mask_row.astype(x.dtype)
    return _walk_ab(lora, fa, fb)


def scale_lora_b(lora, scale):
    """Scale every B matrix by ``scale`` (may be traced).

    Folding a per-client gamma_i into B — y = xW + 1.0 * (x A^T)(gamma B)^T
    — is mathematically identical to gamma * B A and keeps the gamma passed
    to the kernels a static 1.0, which the fused Pallas tier requires."""
    fb = lambda x: x * jnp.asarray(scale, x.dtype)
    return _walk_ab(lora, lambda a: a, fb)


def split_ab(lora):
    """Split a LoRA tree into (A-only tree, B-only tree) with the same
    structure — used by the selective-aggregation strategies.  Nodes holding
    only one of the two matrices (e.g. the output of a previous split) yield
    an empty dict on the missing side."""
    def pick(node, which):
        if isinstance(node, dict):
            if node and set(node) <= {"a", "b"}:
                return {which: node[which]} if which in node else {}
            return {k: pick(v, which) for k, v in node.items()}
        return node

    return pick(lora, "a"), pick(lora, "b")


# ----------------------------------------------------------- first-class API
#
# AdapterSet / AdapterBank: (lora, gamma, rank_mask) as one pytree.

def adapter_rank(lora) -> int:
    """The (padded) rank of a LoRA tree, read off the first A leaf."""
    for leaf in jax.tree.leaves(lora):
        return int(leaf.shape[-2])   # a: (..., r, d_in) visited first ("a"<"b")
    return 0


def pad_rank_tree(lora, r_max: int):
    """Zero-pad every adapter to rank ``r_max`` (rows of A, columns of B).

    Zero rank rows/columns contribute nothing to x A^T B^T, so padding is
    exact — it is how mixed-rank adapters share one stacked bank."""
    def pad(x, axis):
        extra = r_max - x.shape[axis]
        if extra < 0:
            raise ValueError(
                f"adapter rank {x.shape[axis]} exceeds r_max={r_max}")
        if extra == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, extra)
        return jnp.pad(x, widths)
    return _walk_ab(lora, lambda a: pad(a, a.ndim - 2),
                    lambda b: pad(b, b.ndim - 1))


def _static_gamma(gamma) -> bool:
    return isinstance(gamma, (int, float))


@dataclasses.dataclass(frozen=True)
class AdapterSet:
    """A/B tree + scaling factor + rank mask + metadata as ONE pytree.

    ``gamma`` is a python float (static — baked into the trace, the fused
    kernel tier's requirement) or a jax scalar/(N,) array (traced or
    per-client — folded into B by :meth:`fold_gamma` so the kernels still
    see a static scale).  ``rank_mask`` is ``(r,)`` for a single client or
    ``(N, r)``/``(K, r)`` for client-stacked / bank-gathered sets; ``None``
    means every rank row is active.  ``rank``/``alpha`` are bookkeeping
    metadata (checkpoint round-trips, bank registration).  ``batched`` marks
    a per-request set from an :class:`AdapterBank`: either every leaf
    carries a leading request dim pairing with the batch row of ``x``
    (``gather`` — materialized) or the leaves stay bank-stacked ``(K, ...)``
    with ``ids`` mapping batch rows to tenants (``requests`` — the lazy
    form whose gather happens at the projection site, in-kernel on the
    BGMV tier).

    Pytree layout: ``lora`` is a child; ``gamma`` and ``rank_mask`` are
    CONFIG, not state — when they are concrete host values (a float, a
    materialized array) they ride in the treedef as static aux data, so
    under jit they become trace-time constants: a float gamma is baked into
    the fused Pallas kernels exactly like the old static argument, and an
    all-ones rank mask constant-folds to nothing, keeping the uniform-rank
    path bit-identical to the mask-free one.  Only traced values (a
    per-request mask from ``AdapterBank.gather``, a per-client gamma_i
    under the engine's vmap) become pytree children.  Two sets with
    different static configs compile separately — by design.
    """
    lora: Any
    gamma: Any = 1.0
    rank_mask: Any = None
    rank: int = 0
    alpha: float = 0.0
    batched: bool = False
    ids: Any = None          # (B,) request->tenant map for lazy banked sets

    def __post_init__(self):
        # Normalize concrete config to HOST values once, here: pytree
        # flatten runs inside jaxlib's C++ dispatch, where a device->host
        # transfer is not safe — by construction the flatten below only
        # ever serializes numpy data.  Traced values pass through.
        g = self.gamma
        if isinstance(g, (tuple, list)):
            gs = [float(x) for x in g]
            g = gs[0] if all(x == gs[0] for x in gs) \
                else onp.asarray(gs, onp.float32)
            object.__setattr__(self, "gamma", g)
        elif isinstance(g, jax.Array) and not isinstance(g, jax.core.Tracer):
            g = onp.asarray(g)
            object.__setattr__(self, "gamma",
                               float(g) if g.ndim == 0 else g)
        m = self.rank_mask
        if m is not None and not isinstance(m, jax.core.Tracer):
            m = onp.asarray(m)
            # canonical form: an all-ones mask masks nothing — collapse it
            # to None (exactly like uniform gammas collapse to one float),
            # so uniform-rank federations take the homogeneous fast path
            # bit-for-bit instead of compiling degenerate mask multiplies
            object.__setattr__(self, "rank_mask",
                               None if m.all() else m)

    # ---------------------------------------------------------- constructors

    @classmethod
    def from_config(cls, lora_cfg, *, n_clients: int = 1, lora=None,
                    rank_mask=None) -> "AdapterSet":
        """AdapterSet for a :class:`LoRAConfig`: the scheme's scaling factor
        gamma = scaling(alpha, r, N) is derived HERE — call sites never
        assemble gamma by hand.  ``lora`` may be a real A/B tree, a
        shape-level stand-in (dryrun), or None to fill in later."""
        from repro.core.scaling import scaling_factor
        gamma = scaling_factor(lora_cfg.scaling, lora_cfg.alpha,
                               lora_cfg.rank, n_clients)
        return cls(lora=lora, gamma=gamma, rank_mask=rank_mask,
                   rank=lora_cfg.rank, alpha=lora_cfg.alpha)

    # ------------------------------------------------------------ transforms

    def masked(self) -> "AdapterSet":
        """Zero the inactive rank rows of A / columns of B per the mask.

        Idempotent (the mask is 0/1), and a bitwise no-op on adapters that
        already satisfy the mask invariant — gradients taken through the
        masked tree come out exactly zero at inactive coordinates."""
        if self.rank_mask is None:
            return self
        m = jnp.asarray(self.rank_mask)
        lora = (mask_rank_tree(self.lora, m) if m.ndim == 1
                else apply_rank_mask(self.lora, m))
        return dataclasses.replace(self, lora=lora)

    def fold_gamma(self) -> "AdapterSet":
        """Fold gamma into B: y = xW + (x A^T)(gamma B)^T == xW + gamma B A x.

        THE one place gamma is folded.  Handles a static float (folded at
        trace time), a traced scalar (per-client gamma_i under the engine's
        vmap), and a per-client/per-tenant (N,) array on a stacked tree.
        The result always carries the static ``gamma=1.0`` the fused Pallas
        tier requires."""
        g = self.gamma
        if _static_gamma(g):
            if float(g) == 1.0:
                return self
            lora = scale_lora_b(self.lora, float(g))
        else:
            garr = jnp.asarray(g)
            if garr.ndim == 0:
                lora = scale_lora_b(self.lora, garr)
            else:
                fb = lambda x: x * garr.reshape(
                    garr.shape + (1,) * (x.ndim - 1)).astype(x.dtype)
                lora = _walk_ab(self.lora, lambda a: a, fb)
        return dataclasses.replace(self, lora=lora, gamma=1.0)

    def prepared(self) -> "AdapterSet":
        """Mask + fold: the canonical form the model stack consumes
        (plain A/B tree, implicit scale 1)."""
        return self.masked().fold_gamma()

    def merge(self, params):
        """W0 + gamma * B A merged into the base weights (inference-time,
        zero-latency deployment — the paper's 'no inference cost'
        property)."""
        return merge_lora(params, self.prepared().lora, 1.0)

    # ---------------------------------------------------------- stack/unstack

    @classmethod
    def stack(cls, sets) -> "AdapterSet":
        """Stack K same-rank sets along a new leading dim (clients/tenants).

        Uniform float gammas stay one static float; mixed gammas become a
        (K,) array child.  Mixed ranks must be padded first — see
        :meth:`AdapterBank.from_sets`, which handles that."""
        sets = list(sets)
        if not sets:
            raise ValueError("AdapterSet.stack needs at least one set")
        ranks = {adapter_rank(s.lora) for s in sets}
        if len(ranks) > 1:
            raise ValueError(
                f"AdapterSet.stack needs uniform ranks, got {sorted(ranks)}; "
                "pad first (AdapterBank.from_sets does this)")
        lora = jax.tree.map(lambda *xs: jnp.stack(xs), *[s.lora for s in sets])
        gammas = [s.gamma for s in sets]
        if all(_static_gamma(g) for g in gammas):
            gamma = tuple(float(g) for g in gammas)   # __post_init__ collapses
        else:
            gamma = jnp.stack([jnp.asarray(g, jnp.float32) for g in gammas])
        r = ranks.pop()
        if any(s.rank_mask is not None for s in sets):
            rows = [jnp.ones((r,), jnp.float32) if s.rank_mask is None
                    else jnp.asarray(s.rank_mask, jnp.float32)
                    for s in sets]
            mask = jnp.stack(rows)
        else:
            mask = None
        return cls(lora=lora, gamma=gamma, rank_mask=mask, rank=r,
                   alpha=sets[0].alpha)

    def unstack(self):
        """The inverse of :meth:`stack`: K single-client sets."""
        n = jax.tree.leaves(self.lora)[0].shape[0]
        return [self.client(i) for i in range(n)]

    def client(self, i: int) -> "AdapterSet":
        """Client ``i``'s slice of a client-stacked set (its own gamma_i and
        rank-mask row included)."""
        g = self.gamma
        if not _static_gamma(g) and jnp.asarray(g).ndim >= 1:
            g = jnp.asarray(g)[i]
        m = None if self.rank_mask is None else jnp.asarray(self.rank_mask)[i]
        return dataclasses.replace(
            self, lora=jax.tree.map(lambda x: x[i], self.lora), gamma=g,
            rank_mask=m, batched=False)

    def num_params(self) -> int:
        return num_lora_params(self.lora)


def _encode_static(v):
    """Encode a concrete config value as hashable treedef aux data; traced
    values return None (they must travel as pytree children).  Only host
    (numpy/python) values reach the array branch — ``AdapterSet`` normalizes
    at construction, because flatten may run inside jaxlib's C++ dispatch
    where device->host transfers are unsafe."""
    if v is None:
        return ("none",)
    if isinstance(v, (int, float)):
        return ("float", float(v))
    if isinstance(v, onp.ndarray):
        return ("array", v.shape, str(v.dtype), v.tobytes())
    return None


def _decode_static(enc):
    if enc[0] == "none":
        return None
    if enc[0] == "float":
        return enc[1]
    _, shape, dtype, buf = enc
    # .copy(): own the memory rather than viewing the treedef's bytes
    return onp.frombuffer(buf, dtype=dtype).reshape(shape).copy()


def _aset_flatten(s):
    g_aux = _encode_static(s.gamma)
    m_aux = _encode_static(s.rank_mask)
    children = (s.lora,
                None if m_aux is not None else s.rank_mask,
                None if g_aux is not None else s.gamma,
                s.ids)
    aux = (g_aux, m_aux, s.rank, s.alpha, s.batched)
    return children, aux


def _aset_unflatten(aux, children):
    lora, mask_child, gamma_child, ids = children
    g_aux, m_aux, rank, alpha, batched = aux
    gamma = gamma_child if g_aux is None else _decode_static(g_aux)
    rank_mask = mask_child if m_aux is None else _decode_static(m_aux)
    return AdapterSet(lora=lora, gamma=gamma, rank_mask=rank_mask,
                      rank=rank, alpha=alpha, batched=batched, ids=ids)


jax.tree_util.register_pytree_node(AdapterSet, _aset_flatten, _aset_unflatten)


def init_adapter_set(params, key, lora_cfg, *, n_clients: int = 1,
                     targets=None) -> AdapterSet:
    """Fresh AdapterSet for ``params`` with the scheme's scaling factor.

    The single constructor call sites use instead of assembling
    (init_lora, scaling_factor, rank metadata) by hand."""
    return AdapterSet.from_config(
        lora_cfg, n_clients=n_clients,
        lora=init_lora(params, key, lora_cfg, targets=targets))


@functools.partial(jax.jit, donate_argnums=(0,))
def _bank_slot_swap(lora, new, slot):
    """One stacked-bank slot replaced on device: the bank leaves are DONATED,
    so on backends that support donation the update happens in the bank's own
    buffers — no second copy of a fleet-sized bank ever exists.  ``slot`` is
    traced, so every slot of a given bank shape shares ONE executable."""
    return jax.tree.map(lambda L, x: L.at[slot].set(x.astype(L.dtype)),
                        lora, new)


@dataclasses.dataclass(frozen=True)
class AdapterBank:
    """K prepared adapter sets stacked for multi-tenant serving.

    Registration folds each tenant's gamma into its B and pads mixed ranks
    to ``r_max`` under a (K, r_max) rank mask, so the bank is one uniform
    stacked tree: a compiled decode step gathers per-request adapters with
    ``bank.gather(ids)`` (ids traced — one executable serves every tenant
    mix) and routes them through the batched adapter path in
    ``kernels/dispatch``.

    ``version`` counts slot publishes (:meth:`publish`) — host-side
    bookkeeping for the adapter lifecycle, deliberately NOT part of the
    pytree (neither child nor treedef aux): a version bump must never change
    the jit cache key, or every publish would recompile the serving engines.
    It therefore does not survive a flatten/unflatten round trip.
    """
    lora: Any                                 # leaves (K,) + leaf shape
    rank_mask: Any = None                     # (K, r_max) or None
    ranks: Tuple[int, ...] = ()               # per-tenant active ranks
    version: int = 0                          # publish counter (host-only)

    @property
    def size(self) -> int:
        return jax.tree.leaves(self.lora)[0].shape[0]

    @property
    def r_max(self) -> int:
        return adapter_rank(self.lora)

    @classmethod
    def from_sets(cls, sets) -> "AdapterBank":
        """Register K AdapterSets (possibly mixed-rank) as one bank."""
        sets = [s.prepared() for s in sets]
        ranks = tuple(adapter_rank(s.lora) for s in sets)
        r_max = max(ranks)
        padded = [pad_rank_tree(s.lora, r_max) for s in sets]
        lora = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
        return cls(lora=lora, rank_mask=rank_mask(ranks, r_max), ranks=ranks)

    @classmethod
    def from_adapter_set(cls, stacked: AdapterSet, ranks=None) -> "AdapterBank":
        """Register a client-stacked AdapterSet (e.g. a restored federated
        checkpoint: every client becomes a tenant)."""
        prepared = stacked.prepared()
        n = jax.tree.leaves(prepared.lora)[0].shape[0]
        r_pad = adapter_rank(prepared.lora)
        if ranks is None:
            if stacked.rank_mask is not None:
                ranks = tuple(int(r) for r in
                              onp.asarray(stacked.rank_mask).sum(axis=-1))
            else:
                ranks = (r_pad,) * n
        return cls(lora=prepared.lora, rank_mask=rank_mask(ranks, r_pad),
                   ranks=tuple(int(r) for r in ranks))

    def publish(self, slot: int, aset: AdapterSet, *,
                donate: bool = True) -> "AdapterBank":
        """Atomically replace tenant ``slot`` with ``aset`` — the versioned
        bank update that lets federated rounds re-publish adapters while
        serving continues.

        The new set is prepared (rank-masked, gamma folded into B) and
        zero-padded to the bank's ``r_max``, so the stacked leaves keep
        EXACTLY their shapes and dtypes: every executable compiled against
        the bank (decode chunks, admission prefills, the fixed engine) stays
        valid — swapping a slot triggers zero recompiles (asserted in
        tests/test_lifecycle.py).  A set whose rank exceeds ``r_max`` is
        rejected rather than silently reshaping the bank.

        With ``donate=True`` (default) the old leaves are donated to the
        update: the returned bank REPLACES ``self``, whose buffers may be
        invalidated — drop the old reference.  Pass ``donate=False`` to keep
        the old bank readable (e.g. A/B comparison in tests).
        """
        if not 0 <= int(slot) < self.size:
            raise ValueError(f"slot {slot} out of range for a bank of "
                             f"{self.size} tenants")
        slot = int(slot)
        prepared = aset.prepared()
        r = adapter_rank(prepared.lora)
        r_max = self.r_max
        if r > r_max:
            raise ValueError(
                f"published rank {r} exceeds the bank's r_max={r_max}: "
                "slot shapes are padded-stable by construction — rebuild "
                "the bank (AdapterBank.from_sets) to grow the rank ceiling")
        padded = pad_rank_tree(prepared.lora, r_max)
        bank_leaves, bank_def = jax.tree.flatten(self.lora)
        new_leaves, new_def = jax.tree.flatten(padded)
        if bank_def != new_def:
            raise ValueError(
                "published adapter tree structure does not match the "
                f"bank's: {new_def} vs {bank_def}")
        for bl, nl in zip(bank_leaves, new_leaves):
            if bl.shape[1:] != nl.shape:
                raise ValueError(
                    f"published adapter leaf shape {nl.shape} does not "
                    f"match the bank slot shape {bl.shape[1:]}")
        if donate:
            with warnings.catch_warnings():
                # XLA CPU cannot honor donation and warns; the swap is still
                # correct there (one extra copy), and real accelerators
                # donate in place
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                lora = _bank_slot_swap(self.lora, padded,
                                       jnp.asarray(slot, jnp.int32))
        else:
            lora = jax.tree.map(
                lambda L, x: L.at[slot].set(x.astype(L.dtype)),
                self.lora, padded)
        ranks = list(self.ranks or (r_max,) * self.size)
        ranks[slot] = r
        return AdapterBank(lora=lora, rank_mask=rank_mask(tuple(ranks), r_max),
                           ranks=tuple(ranks), version=self.version + 1)

    def gather(self, ids) -> AdapterSet:
        """Per-request adapters, MATERIALIZED: ``ids`` (b,) int tenant
        indices (may be traced).  Returns a ``batched`` AdapterSet whose
        leaves carry a leading request dim — gamma is already folded, so it
        serves under the static scale 1 every kernel tier accepts.  No rank
        mask rides along: bank registration stored the sets exactly masked
        and zero-padded, so a gathered mask would only re-multiply every
        A/B leaf by its own zero pattern on every decode step.

        Copies every adapter leaf per call — prefer :meth:`requests` on the
        serving hot path, which defers the gather to the projection site."""
        check_adapter_ids(ids, self.size, what="gather id")
        ids = jnp.asarray(ids)
        lora = jax.tree.map(lambda x: x[ids], self.lora)
        return AdapterSet(lora=lora, gamma=1.0,
                          rank=adapter_rank(lora), batched=True)

    def requests(self, ids) -> AdapterSet:
        """Per-request adapters, LAZY: the bank leaves stay stacked
        ``(K, ...)`` and the request->tenant map rides along as ``ids``, so
        the gather happens per projection — inside the BGMV kernel via its
        ids-indexed BlockSpecs on the fused tiers, or as a per-layer XLA
        gather on the reference tier — instead of materializing ``(B, ...)``
        copies of every adapter leaf each generation step."""
        check_adapter_ids(ids, self.size, what="request id")
        return AdapterSet(lora=self.lora, gamma=1.0,
                          rank=adapter_rank(self.lora), batched=True,
                          ids=jnp.asarray(ids, jnp.int32))

    def adapter(self, k: int) -> AdapterSet:
        """Tenant ``k`` as a plain single AdapterSet (the per-adapter loop
        the bank's batched decode is conformance-tested against)."""
        mask = None if self.rank_mask is None else self.rank_mask[k]
        return AdapterSet(lora=jax.tree.map(lambda x: x[k], self.lora),
                          gamma=1.0, rank_mask=mask,
                          rank=int(self.ranks[k]) if self.ranks else 0)


jax.tree_util.register_pytree_node(
    AdapterBank,
    lambda b: ((b.lora, b.rank_mask), (b.ranks,)),
    lambda aux, ch: AdapterBank(lora=ch[0], rank_mask=ch[1], ranks=aux[0]))


class LiveAdapterBank:
    """Adapter lifecycle at fleet scale: an HBM-resident hot set over a
    host-RAM tenant store.

    The device :class:`AdapterBank` holds ``hot_slots`` padded slots; the
    full tenant population lives host-side as numpy trees (prepared —
    gamma-folded, rank-masked — and zero-padded to ``r_max``, so promotion
    is a pure copy).  This serves a bank that does NOT fit in HBM: a
    request for a non-resident tenant promotes it into the least-recently-
    used unpinned slot at the next chunk boundary; the evictee is demoted
    to the host store for free, because the store is always authoritative
    (:meth:`publish` writes host first, then swaps the device slot only if
    the tenant is resident).

    This object is intentionally NOT a pytree: it is host-side lifecycle
    state (residency map, LRU clock, versions).  Compiled code only ever
    sees ``live.bank`` — a plain AdapterBank whose shapes never change, so
    promotions, demotions, and publishes all reuse the same executables.

    Recency is driven by the request ids flowing through
    ``launch/serve.serve_scheduled`` (admission + every decode chunk calls
    :meth:`touch` / :meth:`acquire`), which is why stale tenant ids on idle
    engine slots are a correctness hazard there — see the ids_arr reset in
    ``serve_scheduled``'s eviction path.
    """

    def __init__(self, *, bank: AdapterBank, store: dict, slot_tenant):
        self.bank = bank
        self.store = store                    # tenant -> {lora, rank, version}
        self.slot_tenant = [int(t) for t in slot_tenant]
        if len(self.slot_tenant) != bank.size:
            raise ValueError("slot_tenant must name every device slot")
        self.tenant_slot = {t: s for s, t in enumerate(self.slot_tenant)
                            if t >= 0}
        self._tick = 0
        self._last_used = [0] * len(self.slot_tenant)
        self.version = 0                      # global publish counter
        self.promotions = 0
        self.demotions = 0
        self.swaps = 0                        # in-place resident publishes

    # ------------------------------------------------------------ properties

    @property
    def hot_slots(self) -> int:
        return len(self.slot_tenant)

    @property
    def r_max(self) -> int:
        return self.bank.r_max

    @property
    def tenants(self):
        return sorted(self.store)

    def has(self, tenant) -> bool:
        return int(tenant) in self.store

    def resident(self, tenant) -> bool:
        return int(tenant) in self.tenant_slot

    def tenant_version(self, tenant) -> int:
        return self.store[int(tenant)]["version"]

    # ---------------------------------------------------------- constructors

    @classmethod
    def from_sets(cls, sets, *, hot_slots: int,
                  r_max: int = 0) -> "LiveAdapterBank":
        """Register tenants 0..len(sets)-1; the first ``hot_slots`` of them
        start device-resident.  ``r_max`` (default: the max rank seen) is
        the bank's permanent rank ceiling — later publishes may use any
        rank up to it."""
        sets = list(sets)
        if not sets:
            raise ValueError("LiveAdapterBank needs at least one tenant")
        prepared = [s.prepared() for s in sets]
        ranks = [adapter_rank(p.lora) for p in prepared]
        r_max = int(r_max) or max(ranks)
        if max(ranks) > r_max:
            raise ValueError(f"rank {max(ranks)} exceeds r_max={r_max}")
        store = {t: {"lora": jax.tree.map(onp.asarray,
                                          pad_rank_tree(p.lora, r_max)),
                     "rank": r, "version": 0}
                 for t, (p, r) in enumerate(zip(prepared, ranks))}
        return cls._build(store, hot_slots=hot_slots, r_max=r_max)

    @classmethod
    def from_bank(cls, bank: AdapterBank, *, hot_slots: int
                  ) -> "LiveAdapterBank":
        """Wrap a static AdapterBank: every bank row becomes a host-store
        tenant (row index = tenant id) and the first ``hot_slots`` start
        resident — ``--hot-slots`` on the serve CLI takes this path."""
        host = jax.tree.map(onp.asarray, bank.lora)
        ranks = bank.ranks or (bank.r_max,) * bank.size
        store = {t: {"lora": jax.tree.map(lambda x, t=t: x[t], host),
                     "rank": int(ranks[t]), "version": 0}
                 for t in range(bank.size)}
        return cls._build(store, hot_slots=hot_slots, r_max=bank.r_max)

    @classmethod
    def _build(cls, store, *, hot_slots: int, r_max: int) -> "LiveAdapterBank":
        if hot_slots < 1:
            raise ValueError(f"need >= 1 hot slot, got {hot_slots}")
        tenants = sorted(store)
        resident = tenants[:hot_slots]
        template = store[tenants[0]]["lora"]
        rows, slot_tenant, slot_ranks = [], [], []
        for s in range(hot_slots):
            if s < len(resident):
                t = resident[s]
                rows.append(store[t]["lora"])
                slot_tenant.append(t)
                slot_ranks.append(store[t]["rank"])
            else:                      # spare slot: zeros (inert by padding)
                rows.append(jax.tree.map(onp.zeros_like, template))
                slot_tenant.append(-1)
                slot_ranks.append(r_max)
        lora = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
        bank = AdapterBank(lora=lora,
                           rank_mask=rank_mask(tuple(slot_ranks), r_max),
                           ranks=tuple(slot_ranks))
        return cls(bank=bank, store=store, slot_tenant=slot_tenant)

    # -------------------------------------------------------------- lifecycle

    def publish(self, tenant, aset: AdapterSet) -> int:
        """Publish a new adapter version for ``tenant`` (new tenants
        register on first publish).  The host store is updated first —
        authoritative, so demotion never needs a device->host copy — and a
        RESIDENT tenant's device slot is hot-swapped atomically via
        :meth:`AdapterBank.publish` (zero recompiles; in-flight decode
        chunks finish on the adapters they gathered, the next chunk serves
        the new version).  Returns the tenant's new version number."""
        tenant = int(tenant)
        prepared = aset.prepared()
        r = adapter_rank(prepared.lora)
        if r > self.r_max:
            raise ValueError(
                f"tenant {tenant}: published rank {r} exceeds the bank's "
                f"r_max={self.r_max} — shapes are padded-stable; rebuild "
                "the live bank to grow the rank ceiling")
        padded = pad_rank_tree(prepared.lora, self.r_max)
        ver = (self.store[tenant]["version"] + 1 if tenant in self.store
               else 0)
        self.store[tenant] = {"lora": jax.tree.map(onp.asarray, padded),
                              "rank": r, "version": ver}
        self.version += 1
        s = self.tenant_slot.get(tenant)
        if s is not None:
            self.bank = self.bank.publish(s, AdapterSet(lora=padded))
            self.swaps += 1
        return ver

    def touch(self, tenants) -> None:
        """Advance the LRU clock for every resident tenant in ``tenants`` —
        called with the ids observed at each admission / decode chunk."""
        self._tick += 1
        for t in tenants:
            s = self.tenant_slot.get(int(t))
            if s is not None:
                self._last_used[s] = self._tick

    def acquire(self, tenants, pinned=()):
        """Device slots for ``tenants``, promoting non-resident ones from
        the host store into free or least-recently-used slots.  ``pinned``
        slots (those gathered by still-running requests) are never evicted.
        Returns {tenant: slot}, or None when the distinct tenants cannot
        all be made resident without evicting a pinned slot — the caller
        defers admission to a later chunk boundary (running requests finish
        and unpin, so deferral always makes progress)."""
        want = list(dict.fromkeys(int(t) for t in tenants))
        for t in want:
            if t not in self.store:
                raise KeyError(f"unknown tenant {t}: store holds "
                               f"{self.tenants}")
        keep = {int(p) for p in pinned}
        keep |= {self.tenant_slot[t] for t in want if t in self.tenant_slot}
        missing = [t for t in want if t not in self.tenant_slot]
        free = [s for s in range(self.hot_slots)
                if self.slot_tenant[s] < 0 and s not in keep]
        victims = sorted((s for s in range(self.hot_slots)
                          if self.slot_tenant[s] >= 0 and s not in keep),
                         key=lambda s: self._last_used[s])
        if len(missing) > len(free) + len(victims):
            return None
        for t in missing:
            s = free.pop(0) if free else victims.pop(0)
            self._promote(t, s)
        self.touch(want)
        return {t: self.tenant_slot[t] for t in want}

    def _promote(self, tenant: int, slot: int) -> None:
        old = self.slot_tenant[slot]
        if old >= 0:
            # demotion is free: the host store already holds the evictee
            del self.tenant_slot[old]
            self.demotions += 1
        rec = self.store[tenant]
        self.bank = self.bank.publish(slot, AdapterSet(lora=rec["lora"]))
        self.slot_tenant[slot] = tenant
        self.tenant_slot[tenant] = slot
        self.promotions += 1


def as_adapter_set(adapters):
    """Normalize an ``adapters=`` argument.

    Returns None when no adapters were given.  A raw A/B dict passed as
    ``adapters`` is wrapped with scale 1 (it is already a prepared tree).
    (The PR 4 ``lora=``/``gamma=`` kwarg shim lived here for one release
    and is gone — pass an AdapterSet.)"""
    if adapters is None:
        return None
    if isinstance(adapters, AdapterSet):
        return adapters
    return AdapterSet(lora=adapters)

"""Deterministic, on-device fault injection for the federated engine.

Real cross-device fleets never deliver the regime the synchronous engine
assumes (every sampled client returns a finite, fresh update every round).
This module injects the three failure modes that break it — dropped
uploads, straggling (delayed) uploads, and corrupted uploads — as pure
functions of the round's PRNG key, so:

  - the fault stream is SEEDED and reproducible: the same
    :class:`FaultConfig` and engine seed replay the identical failure
    schedule, which is what makes crash-resume-under-faults bit-exact and
    chaos tests deterministic;
  - everything runs inside the compiled scan (``jax.random`` on traced
    keys — no host RNG, no wall clocks), keeping the engine one dispatch
    per chunk and the trace-safety lint (R1/R2) green;
  - zero-rate faults are STATIC no-ops: the masks collapse to constant
    ``False`` arrays at trace time, so a null fault model adds no RNG
    consumption and the buffered engine stays bit-identical to the
    synchronous engine (the staleness-0 conformance guarantee).

The per-round straggle draw composes into a geometric delay distribution:
an upload that straggles stays in flight and is re-drawn next round, so
``P(delay = k) = p^k (1-p)`` — the ``straggle=geom:P`` CLI syntax names
it explicitly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

CORRUPT_MODES = ("nan", "noise")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-round fault rates (all probabilities in [0, 1]).

    ``dropout``   P(an attempted upload is lost this round) — the client
                  resyncs from the broadcast next round, its pending
                  update (fresh or stale) is gone.
    ``straggle``  P(an attempted upload is delayed) — the update stays in
                  flight with staleness tau+1 and retries next round
                  (geometric delay; see module docstring).
    ``corrupt``   P(an ARRIVING upload is corrupted in transit).  The
                  corruption touches only the uploaded copy, never the
                  client's local state.
    ``corrupt_mode``  "nan": leaves overwritten with NaN/Inf (what server
                  screening must catch); "noise": leaves perturbed by
                  ``noise_scale`` x their RMS — finite but norm-outlying.
    ``seed``      folded into the round key so two fault models on the
                  same engine key stream draw independent schedules.
    """
    dropout: float = 0.0
    straggle: float = 0.0
    corrupt: float = 0.0
    corrupt_mode: str = "nan"
    noise_scale: float = 10.0
    seed: int = 0

    def __post_init__(self):
        for f in ("dropout", "straggle", "corrupt"):
            v = getattr(self, f)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"FaultConfig.{f} must be in [0, 1], "
                                 f"got {v}")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"unknown corrupt_mode '{self.corrupt_mode}'; options "
                f"{CORRUPT_MODES}")

    @property
    def null(self) -> bool:
        """True when every fault rate is zero (static no-op model)."""
        return self.dropout == 0.0 and self.straggle == 0.0 \
            and self.corrupt == 0.0


def parse_faults(spec: str) -> FaultConfig:
    """Parse the ``--faults`` CLI syntax into a :class:`FaultConfig`.

    ``"dropout=0.1,straggle=geom:0.3,corrupt=0.01"`` — keys are
    ``dropout`` / ``straggle`` (optionally ``geom:P``; geometric is the
    only distribution, named for explicitness) / ``corrupt`` / ``mode``
    (nan|noise) / ``noise`` (scale) / ``seed``.  An empty spec is the
    null model.
    """
    kw = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise ValueError(
                f"bad --faults entry '{part}': expected key=value")
        k, v = part.split("=", 1)
        k = k.strip()
        v = v.strip()
        if k == "straggle":
            if v.startswith("geom:"):
                v = v[len("geom:"):]
            kw["straggle"] = float(v)
        elif k in ("dropout", "corrupt"):
            kw[k] = float(v)
        elif k == "mode":
            kw["corrupt_mode"] = v
        elif k == "noise":
            kw["noise_scale"] = float(v)
        elif k == "seed":
            kw["seed"] = int(v)
        else:
            raise ValueError(
                f"unknown --faults key '{k}'; expected one of dropout, "
                "straggle, corrupt, mode, noise, seed")
    return FaultConfig(**kw)


class FaultModel:
    """Trace-safe sampler for one :class:`FaultConfig`.

    Stateless: every draw is a pure function of the caller's key (the
    engine derives one per round from its carried scan key), so fault
    schedules are chunking-invariant and resume bit-exactly from a
    checkpointed key.
    """

    def __init__(self, cfg: FaultConfig = None):
        self.cfg = cfg or FaultConfig()

    def sample(self, key, n: int) -> dict:
        """Per-client fault masks for one round: ``{"drop", "straggle",
        "corrupt"}``, each a (n,) bool array.  Zero-rate masks are
        constant ``False`` at trace time (no RNG consumed)."""
        cfg = self.cfg
        key = jax.random.fold_in(key, cfg.seed)
        kd, ks, kc = jax.random.split(key, 3)
        off = jnp.zeros((n,), bool)
        return {
            "drop": (jax.random.bernoulli(kd, cfg.dropout, (n,))
                     if cfg.dropout > 0 else off),
            "straggle": (jax.random.bernoulli(ks, cfg.straggle, (n,))
                         if cfg.straggle > 0 else off),
            "corrupt": (jax.random.bernoulli(kc, cfg.corrupt, (n,))
                        if cfg.corrupt > 0 else off),
        }

    def corrupt_tree(self, key, tree, mask):
        """Corrupt the masked clients' rows of a client-stacked tree.

        ``mask`` is (n,) bool; only those clients' leaves change — the
        corruption models an upload damaged in transit, so it must apply
        to a COPY of the update, never the client's local state (the
        caller passes the upload tree).  "nan" mode alternates NaN / Inf
        across leaves; "noise" adds ``noise_scale`` x leaf-RMS Gaussian
        noise (finite, but a norm outlier the screen should reject)."""
        cfg = self.cfg
        if cfg.corrupt <= 0:
            return tree
        leaves, treedef = jax.tree.flatten(tree)
        out = []
        for i, x in enumerate(leaves):
            row = mask.reshape((-1,) + (1,) * (x.ndim - 1))
            if cfg.corrupt_mode == "nan":
                bad = jnp.asarray(
                    jnp.inf if i % 2 else jnp.nan, x.dtype)
                out.append(jnp.where(row, bad, x))
            else:
                rms = jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-12)
                noise = jax.random.normal(
                    jax.random.fold_in(key, i), x.shape, x.dtype)
                out.append(x + jnp.where(row, cfg.noise_scale * rms * noise,
                                         jnp.zeros((), x.dtype)))
        return jax.tree.unflatten(treedef, out)

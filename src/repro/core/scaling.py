"""LoRA scaling factors — the paper's central object.

gamma multiplies the adapter product BA in  h = W0 x + gamma * B A x.

  lora      gamma = alpha / r            (Hu et al., 2022)
  rslora    gamma = alpha / sqrt(r)      (Kalajdzievski, 2023)
  sfedlora  gamma = alpha * sqrt(N / r)  (this paper, Theorem 4.2)
  za        gamma = 1 / (sqrt(N)*sqrt(r))  (paper App. B.3 — too small)
  zb        gamma = N^2 / sqrt(r)          (paper App. B.3 — too large)

The paper's derivation (App. A): with FedSA split aggregation the effective
adapter magnitude carries E[A_bar^T A_bar] = (r/N) sigma_A^2 I, so moments
scale as (gamma^2 * r / N)^h — Theta(1) iff gamma ~ sqrt(N/r).
"""
from __future__ import annotations

import math


def gamma_lora(alpha: float, r: int, n_clients: int = 1) -> float:
    return alpha / r


def gamma_rslora(alpha: float, r: int, n_clients: int = 1) -> float:
    return alpha / math.sqrt(r)


def gamma_sfedlora(alpha: float, r: int, n_clients: int) -> float:
    return alpha * math.sqrt(n_clients / r)


def gamma_za(alpha: float, r: int, n_clients: int) -> float:
    # paper defines this candidate without alpha (eq. 24); keep it literal
    return 1.0 / (math.sqrt(n_clients) * math.sqrt(r))


def gamma_zb(alpha: float, r: int, n_clients: int) -> float:
    # eq. 25
    return n_clients ** 2 / math.sqrt(r)


SCALINGS = {
    "lora": gamma_lora,
    "rslora": gamma_rslora,
    "sfedlora": gamma_sfedlora,
    "za": gamma_za,
    "zb": gamma_zb,
}


def scaling_factor(name: str, alpha: float, r: int, n_clients: int) -> float:
    """The adapter scale gamma for a given scheme.

    ``r`` and ``n_clients`` must be >= 1: every scheme divides by r or
    sqrt(r), and sqrt(N/r) of a non-positive client count is meaningless
    (gamma would silently come out 0, inf, or nan and poison the run).
    """
    if r < 1:
        raise ValueError(
            f"scaling_factor needs rank r >= 1, got r={r} (every gamma "
            "scheme divides by r or sqrt(r))")
    if n_clients < 1:
        raise ValueError(
            f"scaling_factor needs n_clients >= 1, got n_clients="
            f"{n_clients} (gamma = alpha*sqrt(N/r) degenerates at N <= 0)")
    try:
        return SCALINGS[name](alpha, r, n_clients)
    except KeyError:
        raise ValueError(f"unknown scaling '{name}'; options {list(SCALINGS)}")


def per_client_gammas(name: str, alpha: float, ranks, n_clients: int):
    """Per-client scaling factors for heterogeneous ranks.

    With per-client ranks r_i the paper's Theorem 4.2 scaling becomes
    gamma_i = alpha * sqrt(N / r_i): N is still the federation size (the
    aggregation averages over all N clients), while the rank in the
    denominator is the client's own adapter rank.  Uniform ranks collapse
    to the homogeneous scaling_factor for every scheme.
    """
    return tuple(scaling_factor(name, alpha, int(r), n_clients)
                 for r in ranks)


def staleness_corrected_gamma(gamma: float, n_eff, n_clients: int):
    """gamma_eff for a round that effectively aggregated ``n_eff`` fresh
    clients (buffered/async aggregation: rejected, dropped, and
    staleness-discounted uploads all shrink N_eff below N).

    Theorem 4.2's moment scale is gamma^2 * r / N for a mean over N
    clients; with the weighted buffered mean the variance reduction goes
    as 1/N_eff instead, so the stabilizing factor is
    gamma_eff = alpha * sqrt(N_eff / r) = gamma * sqrt(N_eff / N).
    Works on floats and traced arrays; degrades to exactly ``gamma`` at
    N_eff = N (the staleness-0 bit-identity guarantee relies on the
    engine's on-device form of this being 1.0 exactly there).
    """
    if n_clients < 1:
        raise ValueError(
            f"staleness_corrected_gamma needs n_clients >= 1, got "
            f"{n_clients}")
    return gamma * (n_eff / n_clients) ** 0.5


def predicted_moment_scale(gamma: float, r: int, n_clients: int) -> float:
    """Theory (App. A eq. 23): adapter output first-moment scale after
    aggregation goes as gamma^2 * r / N.  SFed-LoRA makes this alpha^2
    independent of (N, r)."""
    return gamma ** 2 * r / n_clients

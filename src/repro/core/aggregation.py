"""Federated aggregation strategies over client-stacked LoRA trees.

A client-stacked LoRA tree has a leading client dim N on every leaf:
``a: (N, ..., r, d_in)``, ``b: (N, ..., d_out, r)``.

  fedit   aggregate A and B (FedIT, Zhang et al. 2024)
  ffa     A frozen at init (never trained), aggregate B (FFA-LoRA, Sun 2024)
  fedsa   aggregate A only, B stays local (FedSA-LoRA, Guo 2025 — the
          substrate for SFed-LoRA)
  rolora  alternating rounds: train+aggregate A with B frozen, then B with A
          frozen (RoLoRA, Chen 2025)

Strategies are expressed as two traced-bool pairs so one jitted round step
serves every method:
  train flags  (train_a, train_b): gradient mask during local steps
  agg flags    (agg_a, agg_b):     server-side mean over the client dim
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("fedit", "ffa", "fedsa", "rolora")


def strategy_flags(name: str, round_idx):
    """Returns ((train_a, train_b), (agg_a, agg_b)); entries may be traced."""
    if name == "fedit":
        return (True, True), (True, True)
    if name == "ffa":
        return (False, True), (False, True)
    if name == "fedsa":
        return (True, True), (True, False)
    if name == "rolora":
        a_round = (round_idx % 2 == 0)
        return (a_round, ~a_round if hasattr(a_round, "dtype")
                else not a_round), (a_round, ~a_round if
                                    hasattr(a_round, "dtype") else not a_round)
    raise ValueError(f"unknown strategy '{name}'")


def _map_ab(tree, fn_a, fn_b):
    """Apply fn_a to 'a' leaves and fn_b to 'b' leaves of a LoRA tree."""
    def walk(node):
        if isinstance(node, dict):
            if set(node) <= {"a", "b"} and node:
                out = {}
                if "a" in node:
                    out["a"] = fn_a(node["a"])
                if "b" in node:
                    out["b"] = fn_b(node["b"])
                return out
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def mask_grads(grads, train_a, train_b):
    """Zero out gradients of frozen matrices (flags may be traced bools)."""
    fa = lambda g: g * jnp.asarray(train_a, g.dtype)
    fb = lambda g: g * jnp.asarray(train_b, g.dtype)
    return _map_ab(grads, fa, fb)


def aggregate_clients(lora_stacked, agg_a, agg_b, *, axis: int = 0,
                      weights=None):
    """Server step: replace selected leaves by their (optionally weighted)
    client mean, broadcast back to every client (flags may be traced).

    ``weights`` (N,) supports partial participation: non-participants get
    weight 0 in the mean but still receive the broadcast aggregate."""
    def agg(flag):
        def f(x):
            if weights is None:
                mean = x.mean(axis=axis, keepdims=True)
            else:
                w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
                mean = (x * w).sum(axis=axis, keepdims=True) / jnp.maximum(
                    w.sum(), 1e-9)
            mean = jnp.broadcast_to(mean, x.shape)
            return jnp.where(jnp.asarray(flag, bool), mean, x)
        return f
    return _map_ab(lora_stacked, agg(agg_a), agg(agg_b))


def _concrete_flag(flag, name: str) -> bool:
    if isinstance(flag, jax.core.Tracer):
        raise TypeError(
            f"upload_bytes is host-only: '{name}' is a traced value (e.g. "
            "rolora flags derived from a traced round_idx inside jit). "
            "Evaluate strategy_flags with a concrete round_idx on the host "
            "before calling upload_bytes.")
    return bool(flag)


def upload_bytes(lora_stacked, agg_a, agg_b) -> int:
    """Per-round client->server communication volume (for the comm table).

    Host-only accounting: ``agg_a``/``agg_b`` must be concrete bools/ints
    (0/1).  The rolora strategy's flags are traced inside the jitted round
    step — recompute them with a concrete round index for reporting.
    """
    agg_a = _concrete_flag(agg_a, "agg_a")
    agg_b = _concrete_flag(agg_b, "agg_b")
    total = 0
    def count(flag):
        def f(x):
            nonlocal total
            if flag:
                total += x[0].size * x.dtype.itemsize
            return x
        return f
    _map_ab(lora_stacked, count(agg_a), count(agg_b))
    return total

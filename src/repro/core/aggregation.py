"""Federated aggregation strategies over client-stacked LoRA trees.

A client-stacked LoRA tree has a leading client dim N on every leaf:
``a: (N, ..., r, d_in)``, ``b: (N, ..., d_out, r)``.

Each strategy is a frozen dataclass in :data:`REGISTRY` bundling the three
server-side concerns the engine needs:

  - ``mask_grads``   which adapter matrices train during local steps,
  - ``aggregate``    the server-side update over the client dim,
  - ``upload_bytes`` per-round client->server communication accounting.

Registered strategies:

  fedit   aggregate A and B (FedIT, Zhang et al. 2024)
  ffa     A frozen at init (never trained), aggregate B (FFA-LoRA, Sun 2024)
  fedsa   aggregate A only, B stays local (FedSA-LoRA, Guo 2025 — the
          substrate for SFed-LoRA)
  rolora  alternating rounds: train+aggregate A with B frozen, then B with A
          frozen (RoLoRA, Chen 2025)
  flora   stacking aggregation (FLoRA, arXiv 2409.05976): clients upload both
          matrices, the server forms the exact mean update mean_i(B_i A_i)
          via the stacked product and redistributes a rank-r refactoring of
          it to every client — proof the registry expresses aggregators the
          old (agg_a, agg_b) flag tuples could not.

The first four are :class:`FlagStrategy`/:class:`AlternatingStrategy`
instances expressed as two traced-bool pairs so one jitted round step serves
every method:
  train flags  (train_a, train_b): gradient mask during local steps
  agg flags    (agg_a, agg_b):     server-side mean over the client dim
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def negate_flag(flag):
    """NOT over a strategy flag, uniform across concrete Python bools and
    traced / 0-d device bools (``not`` would raise on tracers)."""
    out = jnp.logical_not(flag)
    return out if isinstance(flag, jax.Array) else bool(out)


def _map_ab(tree, fn_a, fn_b):
    """Apply fn_a to 'a' leaves and fn_b to 'b' leaves of a LoRA tree."""
    def walk(node):
        if isinstance(node, dict):
            if set(node) <= {"a", "b"} and node:
                out = {}
                if "a" in node:
                    out["a"] = fn_a(node["a"])
                if "b" in node:
                    out["b"] = fn_b(node["b"])
                return out
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def _map_ab_pairs(tree, fn_pair):
    """Apply ``fn_pair({"a": .., "b": ..}) -> node`` to every adapter node.

    Strategies that couple A and B (e.g. stacking) need both matrices;
    a-only / b-only adapter nodes (which ``_map_ab`` tolerates) are an
    error here — silently skipping them would leave those adapters
    unaggregated and let clients diverge."""
    def walk(node):
        if isinstance(node, dict):
            if set(node) <= {"a", "b"} and node:
                if set(node) != {"a", "b"}:
                    raise ValueError(
                        "pair-coupled aggregation (e.g. flora stacking) "
                        f"needs both 'a' and 'b'; got {sorted(node)}")
                return fn_pair(node)
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def mask_grads(grads, train_a, train_b):
    """Zero out gradients of frozen matrices (flags may be traced bools)."""
    fa = lambda g: g * jnp.asarray(train_a, g.dtype)
    fb = lambda g: g * jnp.asarray(train_b, g.dtype)
    return _map_ab(grads, fa, fb)


def aggregate_clients(lora_stacked, agg_a, agg_b, *, axis: int = 0,
                      weights=None):
    """Server step: replace selected leaves by their (optionally weighted)
    client mean, broadcast back to every client (flags may be traced).

    ``weights`` (N,) supports partial participation: non-participants get
    weight 0 in the mean but still receive the broadcast aggregate."""
    def agg(flag):
        def f(x):
            if weights is None:
                mean = x.mean(axis=axis, keepdims=True)
            else:
                w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
                mean = (x * w).sum(axis=axis, keepdims=True) / jnp.maximum(
                    w.sum(), 1e-9)
            mean = jnp.broadcast_to(mean, x.shape)
            return jnp.where(jnp.asarray(flag, bool), mean, x)
        return f
    return _map_ab(lora_stacked, agg(agg_a), agg(agg_b))


def _concrete_flag(flag, name: str) -> bool:
    if isinstance(flag, jax.core.Tracer):
        raise TypeError(
            f"upload_bytes is host-only: '{name}' is a traced value (e.g. "
            "rolora flags derived from a traced round_idx inside jit). "
            "Evaluate strategy_flags with a concrete round_idx on the host "
            "before calling upload_bytes.")
    return bool(flag)


def upload_bytes(lora_stacked, agg_a, agg_b) -> int:
    """Per-round client->server communication volume (for the comm table).

    Host-only accounting: ``agg_a``/``agg_b`` must be concrete bools/ints
    (0/1).  The rolora strategy's flags are traced inside the jitted round
    step — recompute them with a concrete round index for reporting.
    """
    agg_a = _concrete_flag(agg_a, "agg_a")
    agg_b = _concrete_flag(agg_b, "agg_b")
    total = 0
    def count(flag):
        def f(x):
            nonlocal total
            if flag:
                total += x[0].size * x.dtype.itemsize
            return x
        return f
    _map_ab(lora_stacked, count(agg_a), count(agg_b))
    return total


# ----------------------------------------------------------------- registry

@dataclasses.dataclass(frozen=True)
class Strategy:
    """One server-side federated LoRA strategy.

    Subclasses override the flag accessors (flag-expressible strategies) or
    :meth:`aggregate` directly (structural aggregators like stacking).
    ``round_idx`` may be a traced scalar everywhere except
    :meth:`upload_bytes`, which is host-only accounting.
    """
    name: str

    def train_flags(self, round_idx):
        return (True, True)

    def agg_flags(self, round_idx):
        return (True, True)

    def mask_grads(self, grads, round_idx):
        ta, tb = self.train_flags(round_idx)
        return mask_grads(grads, ta, tb)

    def aggregate(self, lora_stacked, round_idx, *, weights=None):
        aa, ab = self.agg_flags(round_idx)
        return aggregate_clients(lora_stacked, aa, ab, weights=weights)

    def upload_bytes(self, lora_stacked, round_idx: int = 0) -> int:
        """Per-round client->server bytes (host-only; concrete round_idx)."""
        aa, ab = self.agg_flags(round_idx)
        return upload_bytes(lora_stacked, aa, ab)


@dataclasses.dataclass(frozen=True)
class FlagStrategy(Strategy):
    """A strategy fully described by static train/aggregate flag pairs."""
    train_a: bool = True
    train_b: bool = True
    agg_a: bool = True
    agg_b: bool = True

    def train_flags(self, round_idx):
        return (self.train_a, self.train_b)

    def agg_flags(self, round_idx):
        return (self.agg_a, self.agg_b)


@dataclasses.dataclass(frozen=True)
class AlternatingStrategy(Strategy):
    """RoLoRA: even rounds train+aggregate A (B frozen), odd rounds B."""

    def train_flags(self, round_idx):
        a_round = round_idx % 2 == 0
        return (a_round, negate_flag(a_round))

    def agg_flags(self, round_idx):
        return self.train_flags(round_idx)


@dataclasses.dataclass(frozen=True)
class StackingStrategy(Strategy):
    """FLoRA-style concat-then-redistribute aggregation.

    Clients upload both matrices.  Stacking the A_i along rows and the B_i
    along columns makes the stacked product the exact sum of client updates:
    ``B_stack @ A_stack = sum_i B_i A_i`` — no averaging error from
    aggregating the factors independently (FLoRA's core argument).  The
    (weighted) mean update is then redistributed as a rank-r factorization
    (truncated SVD) so every client continues from identical adapters of the
    original shape, without touching the frozen base weights.
    """

    def aggregate(self, lora_stacked, round_idx, *, weights=None):
        def redistribute(node):
            a, b = node["a"], node["b"]          # (N,...,r,di), (N,...,do,r)
            n, r = a.shape[0], a.shape[-2]
            if weights is None:
                w = jnp.full((n,), 1.0 / n, jnp.float32)
            else:
                w = weights.astype(jnp.float32)
                w = w / jnp.maximum(w.sum(), 1e-9)
            # stacked product == sum_i B_i A_i, here with participation weights
            m = jnp.einsum("n,n...or,n...ri->...oi",
                           w, b.astype(jnp.float32), a.astype(jnp.float32))
            u, s, vh = jnp.linalg.svd(m, full_matrices=False)
            k = min(r, s.shape[-1])
            sr = jnp.sqrt(s[..., :k])
            a_new = sr[..., :, None] * vh[..., :k, :]
            b_new = u[..., :, :k] * sr[..., None, :]
            if k < r:                             # rank exceeds matrix dims
                pad = [(0, 0)] * a_new.ndim
                pad[-2] = (0, r - k)
                a_new = jnp.pad(a_new, pad)
                pad = [(0, 0)] * b_new.ndim
                pad[-2] = (0, 0)
                pad[-1] = (0, r - k)
                b_new = jnp.pad(b_new, pad)
            tile = lambda x, like: jnp.broadcast_to(
                x[None], (n,) + x.shape).astype(like.dtype)
            return {"a": tile(a_new, a), "b": tile(b_new, b)}
        return _map_ab_pairs(lora_stacked, redistribute)


REGISTRY = {
    "fedit": FlagStrategy("fedit", True, True, True, True),
    "ffa": FlagStrategy("ffa", False, True, False, True),
    "fedsa": FlagStrategy("fedsa", True, True, True, False),
    "rolora": AlternatingStrategy("rolora"),
    "flora": StackingStrategy("flora"),
}

STRATEGIES = tuple(REGISTRY)


def get_strategy(name) -> Strategy:
    """Look up a strategy by name (a Strategy instance passes through)."""
    if isinstance(name, Strategy):
        return name
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy '{name}'; options {STRATEGIES}") \
            from None


def strategy_flags(name: str, round_idx):
    """Back-compat view of a flag-expressible strategy:
    ((train_a, train_b), (agg_a, agg_b)); entries may be traced.

    Raises for strategies whose server step is NOT expressible as agg
    flags (e.g. flora's stacking aggregate): feeding their train/agg flags
    to ``aggregate_clients`` would silently compute plain means — use
    ``get_strategy(name).aggregate(...)`` instead."""
    s = get_strategy(name)
    if type(s).aggregate is not Strategy.aggregate:
        raise ValueError(
            f"strategy '{s.name}' is not flag-expressible (it overrides "
            "aggregate()); use get_strategy(name) and its "
            "mask_grads/aggregate/upload_bytes methods")
    return s.train_flags(round_idx), s.agg_flags(round_idx)

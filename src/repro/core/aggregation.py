"""Federated aggregation strategies over client-stacked LoRA trees.

A client-stacked LoRA tree has a leading client dim N on every leaf:
``a: (N, ..., r, d_in)``, ``b: (N, ..., d_out, r)``.

Each strategy is a frozen dataclass in :data:`REGISTRY` bundling the three
server-side concerns the engine needs:

  - ``mask_grads``   which adapter matrices train during local steps,
  - ``aggregate``    the server-side update over the client dim,
  - ``upload_bytes`` per-round client->server communication accounting.

Registered strategies:

  fedit   aggregate A and B (FedIT, Zhang et al. 2024)
  ffa     A frozen at init (never trained), aggregate B (FFA-LoRA, Sun 2024)
  fedsa   aggregate A only, B stays local (FedSA-LoRA, Guo 2025 — the
          substrate for SFed-LoRA)
  rolora  alternating rounds: train+aggregate A with B frozen, then B with A
          frozen (RoLoRA, Chen 2025)
  flora   stacking aggregation (FLoRA, arXiv 2409.05976): clients upload both
          matrices, the server forms the exact mean update mean_i(B_i A_i)
          via the stacked product and redistributes a rank-r refactoring of
          it to every client — proof the registry expresses aggregators the
          old (agg_a, agg_b) flag tuples could not.

The first four are :class:`FlagStrategy`/:class:`AlternatingStrategy`
instances expressed as two traced-bool pairs so one jitted round step serves
every method:
  train flags  (train_a, train_b): gradient mask during local steps
  agg flags    (agg_a, agg_b):     server-side mean over the client dim
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# the canonical adapter-tree walker and rank-mask broadcaster live with the
# LoRA tree utilities; re-exported here under the names this module always
# used (tests and callers import aggregation._map_ab)
from repro.core.lora import AdapterSet
from repro.core.lora import _walk_ab as _map_ab
from repro.core.lora import rank_leaf_mask as _rank_weight


def _unwrap_adapters(tree, rank_mask):
    """Strategies take either a raw client-stacked A/B tree (+ explicit
    ``rank_mask``) or an :class:`AdapterSet`, whose own mask is used unless
    one is passed explicitly.  Returns (lora, rank_mask, set_or_None)."""
    if isinstance(tree, AdapterSet):
        return (tree.lora,
                tree.rank_mask if rank_mask is None else rank_mask, tree)
    return tree, rank_mask, None


def negate_flag(flag):
    """NOT over a strategy flag, uniform across concrete Python bools and
    traced / 0-d device bools (``not`` would raise on tracers)."""
    out = jnp.logical_not(flag)
    return out if isinstance(flag, jax.Array) else bool(out)


def _map_ab_pairs(tree, fn_pair):
    """Apply ``fn_pair({"a": .., "b": ..}) -> node`` to every adapter node.

    Strategies that couple A and B (e.g. stacking) need both matrices;
    a-only / b-only adapter nodes (which ``_map_ab`` tolerates) are an
    error here — silently skipping them would leave those adapters
    unaggregated and let clients diverge."""
    def walk(node):
        if isinstance(node, dict):
            if set(node) <= {"a", "b"} and node:
                if set(node) != {"a", "b"}:
                    raise ValueError(
                        "pair-coupled aggregation (e.g. flora stacking) "
                        f"needs both 'a' and 'b'; got {sorted(node)}")
                return fn_pair(node)
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(tree)


def _map_ab2(t1, t2, fn_a, fn_b):
    """Two-tree variant of ``_map_ab``: apply ``fn_a(x1, x2)`` /
    ``fn_b(x1, x2)`` to corresponding a / b leaves of two structurally
    identical adapter trees (e.g. a client-local tree and its server
    aggregate)."""
    def walk(n1, n2):
        if isinstance(n1, dict):
            if n1 and set(n1) <= {"a", "b"}:
                out = {}
                if "a" in n1:
                    out["a"] = fn_a(n1["a"], n2["a"])
                if "b" in n1:
                    out["b"] = fn_b(n1["b"], n2["b"])
                return out
            return {k: walk(v, n2[k]) for k, v in n1.items()}
        return n1
    return walk(t1, t2)


def combine_received(local, aggregated, receive, agg_a, agg_b):
    """Per-client broadcast step for the buffered engine.

    ``receive`` is a (N,) bool row mask: clients holding an in-flight
    update (stragglers, buffer overflow) keep their LOCAL state on every
    leaf; everyone else takes the server ``aggregated`` value — but only
    on the leaves the strategy actually aggregates (``agg_a``/``agg_b``
    may be traced, e.g. rolora's parity flags).  Non-aggregated leaves
    (e.g. B under fedsa) always stay local."""
    def comb(flag):
        def f(lo, ag):
            keep = jnp.asarray(flag, bool) & receive.reshape(
                (-1,) + (1,) * (lo.ndim - 1))
            return jnp.where(keep, ag, lo)
        return f
    return _map_ab2(local, aggregated, comb(agg_a), comb(agg_b))


def per_client_finite(tree):
    """(N,) bool: does client i's slice of every leaf hold only finite
    values?  The server-side non-finite screen over a stacked upload."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    ok = jnp.ones((n,), bool)
    for x in leaves:
        ok = ok & jnp.isfinite(x).reshape(n, -1).all(axis=1)
    return ok


def per_client_norm(tree):
    """(N,) global L2 norm of client i's slice across all leaves."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n,), jnp.float32)
    for x in leaves:
        sq = sq + jnp.square(x.astype(jnp.float32)).reshape(n, -1).sum(axis=1)
    return jnp.sqrt(sq)


def mask_grads(grads, train_a, train_b):
    """Zero out gradients of frozen matrices (flags may be traced bools)."""
    fa = lambda g: g * jnp.asarray(train_a, g.dtype)
    fb = lambda g: g * jnp.asarray(train_b, g.dtype)
    return _map_ab(grads, fa, fb)


def aggregate_clients(lora_stacked, agg_a, agg_b, *, axis: int = 0,
                      weights=None, rank_mask=None):
    """Server step: replace selected leaves by their (optionally weighted)
    client mean, broadcast back to every client (flags may be traced).

    ``weights`` (N,) supports partial participation and size-weighted
    aggregation: weight-0 clients are excluded from the mean but still
    receive the broadcast aggregate.

    ``rank_mask`` (N, r) supports heterogeneous per-client ranks in the
    padded representation: each rank row is averaged only over the clients
    whose mask is 1 there, and each client receives the aggregate re-masked
    to its own active rows, so inactive rows stay exactly zero.  Rank rows
    whose total weight is zero (no active client sampled this round) keep
    their previous per-client values instead of collapsing to 0."""
    def agg(flag, which):
        def f(x):
            if weights is None and rank_mask is None:
                mean = jnp.broadcast_to(x.mean(axis=axis, keepdims=True),
                                        x.shape)
                return jnp.where(jnp.asarray(flag, bool), mean, x)
            w = jnp.ones((1,) * x.ndim, x.dtype)
            if weights is not None:
                w = w * weights.reshape(
                    (-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            if rank_mask is not None:
                w = w * _rank_weight(rank_mask, x, which)
            den = w.sum(axis=axis, keepdims=True)
            # multiply by the reciprocal rather than divide: x.mean() lowers
            # to sum * (1/n), so this keeps the all-ones weighted mean
            # BIT-identical to the unweighted fast path above (the buffered
            # engine's staleness-0 conformance guarantee rests on it)
            mean = (x * w).sum(axis=axis, keepdims=True) * (
                1.0 / jnp.maximum(den, 1e-9))
            mean = jnp.broadcast_to(mean, x.shape)
            if rank_mask is not None:
                mean = mean * _rank_weight(rank_mask, x, which)
            keep = jnp.asarray(flag, bool) & (den > 0)
            return jnp.where(keep, mean, x)
        return f
    return _map_ab(lora_stacked, agg(agg_a, "a"), agg(agg_b, "b"))


def _concrete_flag(flag, name: str) -> bool:
    if isinstance(flag, jax.core.Tracer):
        raise TypeError(
            f"upload_bytes is host-only: '{name}' is a traced value (e.g. "
            "rolora flags derived from a traced round_idx inside jit). "
            "Evaluate strategy_flags with a concrete round_idx on the host "
            "before calling upload_bytes.")
    return bool(flag)


def upload_bytes(lora_stacked, agg_a, agg_b) -> int:
    """Per-round client->server communication volume (for the comm table).

    Host-only accounting: ``agg_a``/``agg_b`` must be concrete bools/ints
    (0/1).  The rolora strategy's flags are traced inside the jitted round
    step — recompute them with a concrete round index for reporting.
    """
    agg_a = _concrete_flag(agg_a, "agg_a")
    agg_b = _concrete_flag(agg_b, "agg_b")
    total = 0
    def count(flag):
        def f(x):
            nonlocal total
            if flag:
                total += x[0].size * x.dtype.itemsize
            return x
        return f
    _map_ab(lora_stacked, count(agg_a), count(agg_b))
    return total


# ----------------------------------------------------------------- registry

@dataclasses.dataclass(frozen=True)
class Strategy:
    """One server-side federated LoRA strategy.

    Subclasses override the flag accessors (flag-expressible strategies) or
    :meth:`aggregate` directly (structural aggregators like stacking).
    ``round_idx`` may be a traced scalar everywhere except
    :meth:`upload_bytes`, which is host-only accounting.
    """
    name: str

    def train_flags(self, round_idx):
        return (True, True)

    def agg_flags(self, round_idx):
        return (True, True)

    def agg_leaf_flags(self, round_idx):
        """Which (a, b) leaves the server WRITES when broadcasting its
        aggregate — what the buffered engine's receive step must honor so
        non-aggregated leaves (e.g. B under fedsa) stay local.  For
        flag-expressible strategies this is ``agg_flags``; structural
        aggregators that rewrite both matrices (flora) override it."""
        return self.agg_flags(round_idx)

    def mask_grads(self, grads, round_idx):
        ta, tb = self.train_flags(round_idx)
        return mask_grads(grads, ta, tb)

    def aggregate(self, lora_stacked, round_idx, *, weights=None,
                  rank_mask=None):
        """Server step over a client-stacked A/B tree or an AdapterSet
        (whose rank mask rides along; an AdapterSet comes back as one)."""
        lora, rank_mask, aset = _unwrap_adapters(lora_stacked, rank_mask)
        aa, ab = self.agg_flags(round_idx)
        out = aggregate_clients(lora, aa, ab, weights=weights,
                                rank_mask=rank_mask)
        return out if aset is None else dataclasses.replace(aset, lora=out)

    def upload_bytes(self, lora_stacked, round_idx: int = 0) -> int:
        """Per-round client->server bytes (host-only; concrete round_idx)."""
        lora, _, _ = _unwrap_adapters(lora_stacked, None)
        aa, ab = self.agg_flags(round_idx)
        return upload_bytes(lora, aa, ab)

    def upload_bytes_per_client(self, lora_stacked, round_idx: int = 0, *,
                                ranks):
        """(N,) per-client upload bytes counting only ACTIVE parameters.

        Heterogeneous clients in the padded representation carry r_max-
        shaped adapters but only transmit their own r_i active rank rows of
        A / columns of B; ``ranks`` is the per-client rank list.  Host-only
        accounting, like :meth:`upload_bytes` (which it reproduces when all
        ranks equal the padded rank)."""
        lora_stacked, _, _ = _unwrap_adapters(lora_stacked, None)
        aa, ab = self.agg_flags(round_idx)
        aa = _concrete_flag(aa, "agg_a")
        ab = _concrete_flag(ab, "agg_b")
        ranks = np.asarray([int(r) for r in ranks], np.int64)
        totals = np.zeros(len(ranks), np.int64)

        def count(flag, which):
            def f(x):
                nonlocal totals
                if flag:
                    r_pad = x.shape[-2] if which == "a" else x.shape[-1]
                    if (ranks > r_pad).any():
                        raise ValueError(
                            f"rank {int(ranks.max())} exceeds the padded "
                            f"adapter rank {r_pad}")
                    per_rank_row = x[0].size // r_pad * x.dtype.itemsize
                    totals = totals + per_rank_row * ranks
                return x
            return f
        _map_ab(lora_stacked, count(aa, "a"), count(ab, "b"))
        return totals


@dataclasses.dataclass(frozen=True)
class FlagStrategy(Strategy):
    """A strategy fully described by static train/aggregate flag pairs."""
    train_a: bool = True
    train_b: bool = True
    agg_a: bool = True
    agg_b: bool = True

    def train_flags(self, round_idx):
        return (self.train_a, self.train_b)

    def agg_flags(self, round_idx):
        return (self.agg_a, self.agg_b)


@dataclasses.dataclass(frozen=True)
class AlternatingStrategy(Strategy):
    """RoLoRA: even rounds train+aggregate A (B frozen), odd rounds B."""

    def train_flags(self, round_idx):
        a_round = round_idx % 2 == 0
        return (a_round, negate_flag(a_round))

    def agg_flags(self, round_idx):
        return self.train_flags(round_idx)


@dataclasses.dataclass(frozen=True)
class StackingStrategy(Strategy):
    """FLoRA-style concat-then-redistribute aggregation.

    Clients upload both matrices.  Stacking the A_i along rows and the B_i
    along columns makes the stacked product the exact sum of client updates:
    ``B_stack @ A_stack = sum_i B_i A_i`` — no averaging error from
    aggregating the factors independently (FLoRA's core argument).  The
    (weighted) mean update is then redistributed as a rank-r factorization
    (truncated SVD) so every client continues from identical adapters of the
    original shape, without touching the frozen base weights.

    Heterogeneous ranks (``rank_mask`` given, padded representation): each
    client's inactive rank rows are exactly zero, so the stacked product is
    automatically the sum of the TRUE rank-r_i products — concatenating the
    active ranks costs nothing extra.  The redistribution step re-masks the
    SVD factors per client: the components are ordered by singular value,
    so client i keeps the top-r_i components — the best rank-r_i
    approximation of the mean update at that client's own rank.
    """

    def aggregate(self, lora_stacked, round_idx, *, weights=None,
                  rank_mask=None):
        lora_stacked, rank_mask, aset = _unwrap_adapters(lora_stacked,
                                                         rank_mask)
        def redistribute(node):
            a, b = node["a"], node["b"]          # (N,...,r,di), (N,...,do,r)
            n, r = a.shape[0], a.shape[-2]
            if weights is None:
                w = jnp.full((n,), 1.0 / n, jnp.float32)
            else:
                w = weights.astype(jnp.float32)
                w = w / jnp.maximum(w.sum(), 1e-9)
            # stacked product == sum_i B_i A_i, here with participation weights
            m = jnp.einsum("n,n...or,n...ri->...oi",
                           w, b.astype(jnp.float32), a.astype(jnp.float32))
            u, s, vh = jnp.linalg.svd(m, full_matrices=False)
            k = min(r, s.shape[-1])
            sr = jnp.sqrt(s[..., :k])
            a_new = sr[..., :, None] * vh[..., :k, :]
            b_new = u[..., :, :k] * sr[..., None, :]
            if k < r:                             # rank exceeds matrix dims
                pad = [(0, 0)] * a_new.ndim
                pad[-2] = (0, r - k)
                a_new = jnp.pad(a_new, pad)
                pad = [(0, 0)] * b_new.ndim
                pad[-2] = (0, 0)
                pad[-1] = (0, r - k)
                b_new = jnp.pad(b_new, pad)
            tile = lambda x, like: jnp.broadcast_to(
                x[None], (n,) + x.shape).astype(like.dtype)
            out = {"a": tile(a_new, a), "b": tile(b_new, b)}
            if rank_mask is not None:
                out["a"] = out["a"] * _rank_weight(rank_mask, out["a"], "a")
                out["b"] = out["b"] * _rank_weight(rank_mask, out["b"], "b")
            return out
        out = _map_ab_pairs(lora_stacked, redistribute)
        return out if aset is None else dataclasses.replace(aset, lora=out)

    def agg_leaf_flags(self, round_idx):
        # the SVD redistribution rewrites BOTH factors for every client,
        # even though train_flags/agg_flags describe it as coupled
        return (True, True)


@dataclasses.dataclass(frozen=True)
class BufferedStrategy(Strategy):
    """FedBuff-style async wrapper around any registered strategy.

    The buffered engine (``core/federated.py``) aggregates a buffer of at
    most ``buffer_size`` accepted uploads per round; each upload carries a
    staleness counter tau (rounds spent in flight) and is discounted by
    ``(1 + tau)^-beta`` in the server mean.  The wrapper itself only
    bundles the server-side policy knobs and delegates every strategy
    concern (train/agg flags, the aggregate, comm accounting) to
    ``inner`` — so one buffered engine serves every registered method.

    ``screen`` enables server-side update screening before aggregation:
    non-finite uploads are always rejected, and finite uploads whose
    update norm exceeds ``screen_mult`` x the round's mean accepted norm
    are rejected as outliers (only when more than one candidate arrived —
    a single upload has no population to be an outlier of).  Rejected and
    stale uploads shrink the round's effective client count N_eff, and
    the engine's staleness-corrected gamma_eff = gamma * sqrt(N_eff / N)
    tracks it (Theorem 4.2 with N_eff in place of N).
    """
    inner: Strategy = None
    buffer_size: int = 0          # max accepted uploads per round; 0 = M=N
    beta: float = 0.5             # staleness discount exponent
    screen: bool = True
    screen_mult: float = 10.0

    def __post_init__(self):
        if not isinstance(self.inner, Strategy):
            raise ValueError(
                "BufferedStrategy needs inner=<Strategy>; build one via "
                "aggregation.buffered(name, ...)")
        if self.buffer_size < 0:
            raise ValueError(
                f"buffer_size must be >= 0 (0 = no cap), got "
                f"{self.buffer_size}")

    def train_flags(self, round_idx):
        return self.inner.train_flags(round_idx)

    def agg_flags(self, round_idx):
        return self.inner.agg_flags(round_idx)

    def agg_leaf_flags(self, round_idx):
        return self.inner.agg_leaf_flags(round_idx)

    def mask_grads(self, grads, round_idx):
        return self.inner.mask_grads(grads, round_idx)

    def aggregate(self, lora_stacked, round_idx, *, weights=None,
                  rank_mask=None):
        return self.inner.aggregate(lora_stacked, round_idx,
                                    weights=weights, rank_mask=rank_mask)

    def upload_bytes(self, lora_stacked, round_idx: int = 0) -> int:
        return self.inner.upload_bytes(lora_stacked, round_idx)

    def upload_bytes_per_client(self, lora_stacked, round_idx: int = 0, *,
                                ranks):
        return self.inner.upload_bytes_per_client(lora_stacked, round_idx,
                                                  ranks=ranks)


def buffered(inner, **kwargs) -> BufferedStrategy:
    """Wrap a strategy (name or instance) for the async buffered engine."""
    inner = get_strategy(inner)
    return BufferedStrategy(name=f"buffered:{inner.name}", inner=inner,
                            **kwargs)


REGISTRY = {
    "fedit": FlagStrategy("fedit", True, True, True, True),
    "ffa": FlagStrategy("ffa", False, True, False, True),
    "fedsa": FlagStrategy("fedsa", True, True, True, False),
    "rolora": AlternatingStrategy("rolora"),
    "flora": StackingStrategy("flora"),
}

STRATEGIES = tuple(REGISTRY)


def get_strategy(name) -> Strategy:
    """Look up a strategy by name (a Strategy instance passes through)."""
    if isinstance(name, Strategy):
        return name
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy '{name}'; options {STRATEGIES}") \
            from None


def strategy_flags(name: str, round_idx):
    """Back-compat view of a flag-expressible strategy:
    ((train_a, train_b), (agg_a, agg_b)); entries may be traced.

    Raises for strategies whose server step is NOT expressible as agg
    flags (e.g. flora's stacking aggregate): feeding their train/agg flags
    to ``aggregate_clients`` would silently compute plain means — use
    ``get_strategy(name).aggregate(...)`` instead."""
    s = get_strategy(name)
    if type(s).aggregate is not Strategy.aggregate:
        raise ValueError(
            f"strategy '{s.name}' is not flag-expressible (it overrides "
            "aggregate()); use get_strategy(name) and its "
            "mask_grads/aggregate/upload_bytes methods")
    return s.train_flags(round_idx), s.agg_flags(round_idx)

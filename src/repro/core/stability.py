"""Instrumentation for the paper's Definition 4.1 ((N, r)-federated-stabilized
adapters): forward output moments and backward input-gradient magnitudes of
the scaled adapter gamma*B*A, plus activation-moment probes (paper App. B.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adapter_forward_moment(a, b, gamma, key, h: int = 2, n_probe: int = 64):
    """E[(gamma B A x)^h] per entry for x ~ N(0, I).  a (r, d_in), b (d_out, r)."""
    d_in = a.shape[-1]
    x = jax.random.normal(key, (n_probe, d_in), jnp.float32)
    y = gamma * (x @ a.astype(jnp.float32).T) @ b.astype(jnp.float32).T
    return jnp.mean(jnp.abs(y) ** h)


def adapter_backward_moment(a, b, gamma, key, n_probe: int = 64):
    """||dL/dx|| per entry for dL/dy ~ N(0, I) — backward stability probe."""
    d_out = b.shape[-2]
    v = jax.random.normal(key, (n_probe, d_out), jnp.float32)
    gx = gamma * (v @ b.astype(jnp.float32)) @ a.astype(jnp.float32)
    return jnp.sqrt(jnp.mean(jnp.square(gx)))


def aggregated_moment_sweep(key, *, d: int = 512, ranks=(4, 32, 128, 512),
                            clients=(1, 4, 16), scaling_fns=None,
                            sigma: float = 0.02, eta: float = 0.1):
    """Simulate one FedSA step analytically (paper App. A, n=1):
    B_i^(1) = -eta*gamma*v x^T A_i^(0)T ; A^(1) = A_bar.  Measures the
    forward moment of gamma * B^(1) A_bar vs (N, r) for each scaling.

    Returns {scaling: {(N, r): moment}} — theory says sfedlora is ~const.
    """
    from repro.core.scaling import scaling_factor
    out = {}
    for name in (scaling_fns or ("lora", "rslora", "sfedlora")):
        res = {}
        for n in clients:
            for r in ranks:
                g = scaling_factor(name, 8.0, r, n)
                ks = jax.random.split(jax.random.fold_in(key, r * 131 + n), n + 2)
                a_i = [sigma * jax.random.normal(ks[i], (r, d)) for i in range(n)]
                a_bar = sum(a_i) / n
                x = jax.random.normal(ks[-2], (d,))
                v = jax.random.normal(ks[-1], (d,))
                # B^(1) = -eta*g * v (x^T A0^T)  (outer product, client 0)
                b1 = -eta * g * jnp.outer(v, a_i[0] @ x)
                # evaluate on the training input itself: the paper's eq. 21
                # assumes test/train inputs with Theta(1) correlation, and the
                # r/N factor comes from E[A0^T A_bar] = (r/N) sigma^2 I.
                y = g * b1 @ (a_bar @ x)
                res[(n, r)] = float(jnp.sqrt(jnp.mean(jnp.square(y))))
        out[name] = res
    return out


def activation_moments(model, params, batch, adapters):
    """Mean/variance of post-adapter pre-norm activations (paper Fig. 9
    proxy): final hidden statistics.  ``adapters`` is an AdapterSet."""
    logits, _ = model.forward(params, batch, adapters=adapters)
    return {"mean": float(jnp.mean(logits)), "var": float(jnp.var(logits))}

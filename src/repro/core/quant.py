"""Quantized storage for the frozen base weights.

The paper's design keeps the base frozen — only the tiny LoRA factors train
and travel — so the base weight bytes are pure dead freight in HBM: they are
read once per projection and never written.  This module stores them packed:

  int8   per-output-channel symmetric absmax.  data int8 (..., k, n),
         scales fp32 (..., 1, n) — scale_j = max_i |w_ij| / 127.
  int4   grouped absmax along the contraction dim (NF4-style group scaling
         without the nonlinear codebook: the paper's bases are
         normal-ranged, absmax groups stay within the fp round-trip bounds
         pinned in tests).  k is padded up to a multiple of ``group_size``,
         two 4-bit values pack per byte along k: data uint8 (..., k/2, n),
         scales fp32 (..., k/G, n) — scale_gj = max_{i in g} |w_ij| / 7.

Only GEMM weights that route through ``kernels/dispatch`` quantize (attention
q/k/v/o, cross-attention, MLP up/gate/down, RG-LRU wx/wy).  Embedding, head,
norms, gates, routers and every LoRA / optimizer / federated leaf stay fp —
the quantized tree is a drop-in ``params`` pytree where some leaves are
:class:`QuantizedLinear` nodes instead of arrays.

Tier policy (mirrored in ``kernels/dispatch``): the reference tier
dequantizes to fp up front — bit-exact against :func:`dequantize`, so parity
bounds are pinned once here — while the fused Pallas tiers DMA the packed
tiles and dequantize in VMEM (``kernels/lora_matmul.dequant_block``), never
materializing fp base weights in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MODES = ("none", "int8", "int4")

# group size must be a power of two <= 128 so it always divides the kernel
# k-blocks (multiples of the 128 lane tile — see kernels/tiling.py)
GROUP_SIZES = (2, 4, 8, 16, 32, 64, 128)
DEFAULT_GROUP = 64


@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    """A packed frozen GEMM weight: pytree children (data, scales), static
    aux (bits, group_size, logical k, dequantized dtype).

    Behaves shape/dtype-wise like the fp array it replaced (``.shape`` /
    ``.dtype`` / ``.ndim`` report the LOGICAL view), so shape-walking code
    (LoRA init, roofline param counting) works unchanged.  Leading stacked
    dims (the repeat-layer scan layout) ride along: ``lax.scan`` slices the
    data/scales children per layer like any other stacked leaf.
    """
    data: Any       # int8 (..., k, n) | uint8 (..., kq/2, n) packed pairs
    scales: Any     # fp32 (..., 1, n) | fp32 (..., kq/G, n)
    bits: int = 8
    group_size: int = 0   # 0 = per-channel (one k-sized group)
    k: int = 0            # logical contraction dim (pre-padding)
    out_dtype: str = "float32"

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.data.shape[:-2] + (self.k, self.data.shape[-1])

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def dtype(self):
        return jnp.dtype(self.out_dtype)

    @property
    def nbytes(self) -> int:
        """Packed bytes (data + scales) — works on ShapeDtypeStruct leaves
        too, so roofline accounting never needs real buffers."""
        return (int(np.prod(self.data.shape)) * np.dtype(
                    jnp.int8 if self.bits == 8 else jnp.uint8).itemsize
                + int(np.prod(self.scales.shape)) * 4)

    def dequantize(self):
        return dequantize(self)

    def tree_flatten(self):
        return ((self.data, self.scales),
                (self.bits, self.group_size, self.k, self.out_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scales = children
        return cls(data, scales, *aux)


jax.tree_util.register_pytree_node(
    QuantizedLinear,
    lambda q: q.tree_flatten(),
    QuantizedLinear.tree_unflatten)


# --------------------------------------------------------------- quant / deq

def quantize(w, bits: int = 8, group_size: int = DEFAULT_GROUP
             ) -> QuantizedLinear:
    """One-shot post-load quantization of a (..., k, n) GEMM weight."""
    w = jnp.asarray(w)
    if w.ndim < 2:
        raise ValueError(f"quantize expects a >=2-D GEMM weight, got {w.shape}")
    out_dtype = str(w.dtype)
    k = w.shape[-2]
    wf = w.astype(jnp.float32)
    if bits == 8:
        amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)     # (..., 1, n)
        scales = jnp.maximum(amax, 1e-12) / 127.0
        data = jnp.clip(jnp.round(wf / scales), -127, 127).astype(jnp.int8)
        return QuantizedLinear(data, scales, 8, 0, k, out_dtype)
    if bits != 4:
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if group_size not in GROUP_SIZES:
        raise ValueError(
            f"group_size must be a power of two <= 128 (got {group_size}) "
            "so scale tiles align with the kernel k-blocks")
    kq = -(-k // group_size) * group_size
    if kq != k:       # pad k to a group multiple; zero rows dequantize to 0
        pad = [(0, 0)] * (wf.ndim - 2) + [(0, kq - k), (0, 0)]
        wf = jnp.pad(wf, pad)
    lead = wf.shape[:-2]
    n = wf.shape[-1]
    wg = wf.reshape(*lead, kq // group_size, group_size, n)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)   # (..., ng, 1, n)
    scales = jnp.maximum(amax, 1e-12) / 7.0
    q = jnp.clip(jnp.round(wg / scales), -7, 7).astype(jnp.int32)
    q = q.reshape(*lead, kq, n)
    # pack row pairs: even row -> low nibble, odd row -> high nibble
    qu = q & 0xF
    data = (qu[..., 0::2, :] | (qu[..., 1::2, :] << 4)).astype(jnp.uint8)
    return QuantizedLinear(data, scales[..., 0, :], 4, group_size, k,
                           out_dtype)


def unpack_int4(data):
    """uint8 (..., kq/2, n) packed pairs -> int32 (..., kq, n) in [-8, 7]."""
    wi = data.astype(jnp.int32)
    lo = wi & 0xF
    hi = (wi >> 4) & 0xF
    lo = lo - 2 * (lo & 0x8)    # sign-extend the 4-bit two's complement
    hi = hi - 2 * (hi & 0x8)
    vals = jnp.stack([lo, hi], axis=-2)            # (..., kq/2, 2, n)
    return vals.reshape(*data.shape[:-2], data.shape[-2] * 2, data.shape[-1])


def dequantize(q: QuantizedLinear):
    """Packed -> fp (..., k, n) in the original dtype; the reference-tier
    and parity-bound ground truth."""
    if q.bits == 8:
        w = q.data.astype(jnp.float32) * q.scales.astype(jnp.float32)
    else:
        vals = unpack_int4(q.data).astype(jnp.float32)
        lead = vals.shape[:-2]
        kq, n = vals.shape[-2:]
        ng = kq // q.group_size
        w = (vals.reshape(*lead, ng, q.group_size, n)
             * q.scales.astype(jnp.float32)[..., :, None, :])
        w = w.reshape(*lead, kq, n)
        if kq != q.k:
            w = w[..., :q.k, :]
    return w.astype(jnp.dtype(q.out_dtype))


# ----------------------------------------------------------------- tree ops

# (parent key, leaf key) pairs eligible for quantization: exactly the frozen
# GEMM weights that route through kernels/dispatch.lora_linear.  Everything
# else (embed/head, norms, recurrent gates, MoE routers, xLSTM projections)
# stays fp.
ELIGIBLE = {
    "attn": ("q", "k", "v", "o"),
    "cross": ("q", "k", "v", "o"),
    "mlp": ("w_up", "w_gate", "w_down"),
    "rglru": ("wx", "wy"),
}


def _walk(node, fn, path=()):
    if isinstance(node, dict):
        return {key: _walk(v, fn, path + (key,)) for key, v in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_walk(v, fn, path + (str(i),))
                          for i, v in enumerate(node))
    return fn(path, node)


def quantize_tree(params, mode: str, group_size: int = DEFAULT_GROUP):
    """Replace every eligible frozen GEMM leaf with a QuantizedLinear node.

    ``mode`` is "int8" / "int4" ("none" returns the tree unchanged).  Leading
    stacked dims (scan layout) quantize along the last two dims per layer.
    """
    if mode in (None, "none"):
        return params
    if mode not in ("int8", "int4"):
        raise ValueError(f"quant mode must be one of {MODES}, got '{mode}'")
    bits = 8 if mode == "int8" else 4

    def fn(path, leaf):
        if isinstance(leaf, QuantizedLinear):
            raise ValueError(
                f"leaf {'/'.join(path)} is already quantized — quantize_tree "
                "expects an fp base (dequantize first to requantize)")
        if len(path) >= 2 and path[-1] in ELIGIBLE.get(path[-2], ()):
            if getattr(leaf, "ndim", 0) >= 2:
                return quantize(leaf, bits, group_size)
        return leaf

    return _walk(params, fn)


def dequantize_tree(params):
    """fp view of a (possibly) quantized tree — the reference tier's up-front
    dequantization and the merge/export path."""
    return jax.tree.map(
        lambda leaf: dequantize(leaf) if isinstance(leaf, QuantizedLinear)
        else leaf,
        params, is_leaf=lambda x: isinstance(x, QuantizedLinear))


def requantize_merged(merged, ref):
    """Re-pack a merged (fp) tree onto ``ref``'s quantization grid.

    ``merge_lora`` dequantizes packed leaves before folding the adapter in
    (by design — the merge must happen in fp), which silently loses the
    quantized footprint.  This walks ``merged`` alongside the original
    quantized ``ref`` and re-quantizes exactly the leaves that were packed
    there, with the same bits / group size, so ``--merge --quant`` keeps
    the claimed memory win.
    """
    def walk(m, r):
        if isinstance(r, QuantizedLinear):
            if isinstance(m, QuantizedLinear):
                return m          # not dequantized by the merge (no adapter)
            return quantize(m, r.bits, r.group_size or DEFAULT_GROUP)
        if isinstance(r, dict):
            return {key: walk(m[key], v) for key, v in r.items()}
        if isinstance(r, (list, tuple)):
            return type(r)(walk(mv, rv) for mv, rv in zip(m, r))
        return m

    return walk(merged, ref)


def has_quantized(params) -> bool:
    return any(isinstance(leaf, QuantizedLinear)
               for leaf in jax.tree.leaves(
                   params, is_leaf=lambda x: isinstance(x, QuantizedLinear)))


def tree_quant_mode(params):
    """"int8" / "int4" when the tree holds quantized leaves, else None.
    Mixed-bits trees are rejected — checkpoints are quantized one-shot."""
    bits = {leaf.bits for leaf in jax.tree.leaves(
                params, is_leaf=lambda x: isinstance(x, QuantizedLinear))
            if isinstance(leaf, QuantizedLinear)}
    if not bits:
        return None
    if len(bits) > 1:
        raise ValueError(f"mixed quantization bits in one tree: {bits}")
    return "int8" if bits.pop() == 8 else "int4"


def quant_footprint(params) -> dict:
    """Byte accounting over the ELIGIBLE (base GEMM) leaves: fp bytes they
    would occupy, the bytes they actually occupy, and the whole-tree total.
    Works on trees of arrays or ShapeDtypeStructs."""
    acc = {"base_fp_bytes": 0, "base_bytes": 0, "total_bytes": 0}

    def leaf_bytes(leaf):
        return int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize

    def fn(path, leaf):
        if isinstance(leaf, QuantizedLinear):
            acc["base_fp_bytes"] += (int(np.prod(leaf.shape))
                                     * jnp.dtype(leaf.out_dtype).itemsize)
            acc["base_bytes"] += leaf.nbytes
            acc["total_bytes"] += leaf.nbytes
        else:
            b = leaf_bytes(leaf)
            acc["total_bytes"] += b
            if len(path) >= 2 and path[-1] in ELIGIBLE.get(path[-2], ()):
                acc["base_fp_bytes"] += b
                acc["base_bytes"] += b
        return leaf

    _walk(params, fn)
    return acc


def apply_quant_flag(base, mode, group_size: int = DEFAULT_GROUP, *,
                     source: str = "checkpoint"):
    """Reconcile a restored/built base with a ``--quant`` flag.

    fp base + a quant mode -> one-shot quantize; already-matching tree ->
    returned as-is; a packed tree under a *different* flag is an error (the
    fp weights are gone — re-quantizing or silently serving the wrong format
    would corrupt results).
    """
    have = tree_quant_mode(base)
    want = None if mode in (None, "none") else mode
    if have == want:
        return base
    if have is None:
        return quantize_tree(base, want, group_size)
    raise ValueError(
        f"{source} holds a {have}-quantized base but --quant "
        f"{mode or 'none'} was requested — restore it with --quant {have}")

"""Flat-npz pytree checkpointing (orbax is not available offline)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hostcheck import host_only
from repro.core.quant import QuantizedLinear

_SEP = "::"
_QUANT = "__quant__"


@host_only
def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    elif isinstance(tree, QuantizedLinear):
        # packed base leaf (core/quant.py): a sentinel subtree holding the
        # packed data + scales plus the static aux, restored in _unlistify
        enc = {"data": tree.data, "scales": tree.scales,
               "bits": np.asarray(tree.bits),
               "group_size": np.asarray(tree.group_size),
               "k": np.asarray(tree.k),
               "out_dtype": np.asarray(tree.out_dtype)}
        out.update(_flatten(enc, f"{prefix}{_QUANT}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(path: str):
    data = np.load(path)
    tree = {}
    for key in data.files:
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        arr = data[key]
        # string leaves (e.g. serialized RNG stream state) stay host-side
        node[parts[-1]] = arr if arr.dtype.kind in "SU" else jnp.asarray(arr)
    return _unlistify(tree)


def _unlistify(node):
    if isinstance(node, dict):
        if set(node) == {_QUANT}:
            q = node[_QUANT]
            return QuantizedLinear(
                jnp.asarray(q["data"]), jnp.asarray(q["scales"]),
                int(q["bits"]), int(q["group_size"]), int(q["k"]),
                str(np.asarray(q["out_dtype"])))
        if node and all(k.startswith("#") for k in node):
            return [_unlistify(node[f"#{i}"]) for i in range(len(node))]
        return {k: _unlistify(v) for k, v in node.items()}
    return node


@host_only
def save_federated_state(path: str, base, lora, opt_state, round_idx: int,
                         *, key=None, data_state: str = None,
                         rank_mask=None, partition_state: str = None,
                         adapter_meta: dict = None, async_state: dict = None):
    """Checkpoint one federated run.

    ``key`` (the trainer's carried JAX PRNG key) and ``data_state`` (the host
    dataset's serialized RNG stream state) make chunked runs resume
    bit-exactly: the restored engine continues the identical random stream
    from ``round_idx``.

    ``rank_mask`` ((N, r_max), heterogeneous per-client ranks) and
    ``partition_state`` (the dataset's serialized client partition — topic
    mixtures + per-client example counts) round-trip the heterogeneity
    config, so a restored run can verify it resumes under the same clients.

    ``adapter_meta`` ({"gammas", "alpha", "rank", "ranks", "scaling"})
    completes the AdapterSet serialization: a consumer with no trainer (the
    serving path) can rebuild every client's scaled adapters from the
    checkpoint alone — see :func:`load_adapter_state`.

    ``async_state`` ({"tau": (N,) staleness counters, "rho": scalar gamma
    correction}) is the buffered engine's extra carry; without it a
    restored async run would resume with every in-flight upload silently
    declared fresh.
    """
    tree = {"base": base, "lora": lora, "opt": opt_state,
            "round": np.asarray(round_idx)}
    if key is not None:
        tree["prng_key"] = np.asarray(jax.random.key_data(key))
    if data_state is not None:
        tree["data_state"] = np.asarray(data_state)
    if rank_mask is not None:
        tree["rank_mask"] = np.asarray(rank_mask)
    if partition_state is not None:
        tree["partition_state"] = np.asarray(partition_state)
    if adapter_meta is not None:
        tree["adapter_meta"] = {k: np.asarray(v)
                                for k, v in adapter_meta.items()}
    if async_state is not None:
        tree["async_state"] = {k: np.asarray(v)
                               for k, v in async_state.items()}
    save_pytree(path, tree)


def load_federated_state(path: str, *, full: bool = False):
    """Returns (base, lora, opt, round) — or, with ``full=True``,
    (base, lora, opt, round, key, data_state, extras): key/data_state are
    None for checkpoints written without them, and ``extras`` is a dict
    holding "rank_mask" / "partition_state" / "adapter_meta" when present."""
    t = load_pytree(path)
    out = (t["base"], t["lora"], t.get("opt", {}), int(t["round"]))
    if not full:
        return out
    key = None
    if "prng_key" in t:
        key = jax.random.wrap_key_data(jnp.asarray(t["prng_key"]))
    data_state = None
    if "data_state" in t:
        data_state = str(np.asarray(t["data_state"]))
    extras = {}
    if "rank_mask" in t:
        extras["rank_mask"] = np.asarray(t["rank_mask"])
    if "partition_state" in t:
        extras["partition_state"] = str(np.asarray(t["partition_state"]))
    if "adapter_meta" in t:
        extras["adapter_meta"] = {k: np.asarray(v)
                                  for k, v in t["adapter_meta"].items()}
    if "async_state" in t:
        extras["async_state"] = {k: np.asarray(v)
                                 for k, v in t["async_state"].items()}
    return out + (key, data_state, extras)


def load_adapter_state(path: str, *, lora_cfg=None, n_clients: int = None):
    """Restore ``(base_params, stacked AdapterSet)`` from a checkpoint —
    the serving entry point: no trainer, dataset, or optimizer state needed.

    New checkpoints carry ``adapter_meta`` and rebuild the exact trained
    AdapterSet (per-client gammas, rank mask, rank/alpha).  Legacy
    checkpoints (written before the adapter API) are upgraded from
    ``lora_cfg`` (+ ``n_clients``, default: the checkpoint's client dim):
    gamma is recomputed as scaling(alpha, rank, N) — the same value the
    legacy trainer derived — and a stored rank mask is honored either way.
    """
    from repro.core.lora import AdapterSet, adapter_rank
    from repro.core.scaling import per_client_gammas
    base, lora, _, _, _, _, extras = load_federated_state(path, full=True)
    mask = extras.get("rank_mask")
    meta = extras.get("adapter_meta")
    n = jax.tree.leaves(lora)[0].shape[0]
    r_pad = adapter_rank(lora)
    if meta is not None:
        gammas = tuple(float(g) for g in np.asarray(meta["gammas"]).reshape(-1))
        if len(gammas) == 1:
            gammas = gammas * n
        aset = AdapterSet(lora=lora, gamma=gammas,
                          rank_mask=None if mask is None
                          else jnp.asarray(mask, jnp.float32),
                          rank=int(meta["rank"]), alpha=float(meta["alpha"]))
        return base, aset
    if lora_cfg is None:
        raise ValueError(
            f"checkpoint '{path}' predates adapter_meta — pass lora_cfg "
            "(rank/alpha/scaling) to upgrade it to an AdapterSet")
    import warnings
    warnings.warn(
        f"legacy checkpoint '{path}': no adapter_meta; rebuilding gammas "
        f"from lora_cfg ({lora_cfg.scaling}, alpha={lora_cfg.alpha})",
        stacklevel=2)
    n_clients = n_clients or n
    if mask is not None:
        ranks = tuple(int(r) for r in np.asarray(mask).sum(axis=-1))
    else:
        ranks = (r_pad,) * n
    gammas = per_client_gammas(lora_cfg.scaling, lora_cfg.alpha, ranks,
                               n_clients)
    return base, AdapterSet(lora=lora, gamma=gammas,
                            rank_mask=None if mask is None
                            else jnp.asarray(mask, jnp.float32),
                            rank=r_pad, alpha=lora_cfg.alpha)


def publish_adapter_state(path: str, live, *, lora_cfg=None, clients=None):
    """Stream a federated checkpoint's adapters into a live serving bank —
    the round-boundary handoff: the trainer saves, the server publishes,
    traffic keeps flowing.

    ``live`` is a :class:`~repro.core.lora.LiveAdapterBank`.  Every client
    in the checkpoint (or just ``clients``) is published under its client
    index as the tenant id; resident tenants hot-swap on device, the rest
    update the host store.  Returns ``(base_params, n_published)`` so the
    caller can verify the base still matches what it is serving."""
    base, aset = load_adapter_state(path, lora_cfg=lora_cfg)
    n_clients = jax.tree.leaves(aset.lora)[0].shape[0]
    clients = range(n_clients) if clients is None else clients
    n = 0
    for c in clients:
        live.publish(int(c), aset.client(int(c)))
        n += 1
    return base, n

"""Flat-npz pytree checkpointing (orbax is not available offline)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(path: str):
    data = np.load(path)
    tree = {}
    for key in data.files:
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(data[key])
    return _unlistify(tree)


def _unlistify(node):
    if isinstance(node, dict):
        if node and all(k.startswith("#") for k in node):
            return [_unlistify(node[f"#{i}"]) for i in range(len(node))]
        return {k: _unlistify(v) for k, v in node.items()}
    return node


def save_federated_state(path: str, base, lora, opt_state, round_idx: int):
    save_pytree(path, {"base": base, "lora": lora, "opt": opt_state,
                       "round": np.asarray(round_idx)})


def load_federated_state(path: str):
    t = load_pytree(path)
    return t["base"], t["lora"], t.get("opt", {}), int(t["round"])

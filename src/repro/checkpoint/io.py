"""Flat-npz pytree checkpointing (orbax is not available offline)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "::"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(path: str):
    data = np.load(path)
    tree = {}
    for key in data.files:
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        arr = data[key]
        # string leaves (e.g. serialized RNG stream state) stay host-side
        node[parts[-1]] = arr if arr.dtype.kind in "SU" else jnp.asarray(arr)
    return _unlistify(tree)


def _unlistify(node):
    if isinstance(node, dict):
        if node and all(k.startswith("#") for k in node):
            return [_unlistify(node[f"#{i}"]) for i in range(len(node))]
        return {k: _unlistify(v) for k, v in node.items()}
    return node


def save_federated_state(path: str, base, lora, opt_state, round_idx: int,
                         *, key=None, data_state: str = None,
                         rank_mask=None, partition_state: str = None):
    """Checkpoint one federated run.

    ``key`` (the trainer's carried JAX PRNG key) and ``data_state`` (the host
    dataset's serialized RNG stream state) make chunked runs resume
    bit-exactly: the restored engine continues the identical random stream
    from ``round_idx``.

    ``rank_mask`` ((N, r_max), heterogeneous per-client ranks) and
    ``partition_state`` (the dataset's serialized client partition — topic
    mixtures + per-client example counts) round-trip the heterogeneity
    config, so a restored run can verify it resumes under the same clients.
    """
    tree = {"base": base, "lora": lora, "opt": opt_state,
            "round": np.asarray(round_idx)}
    if key is not None:
        tree["prng_key"] = np.asarray(jax.random.key_data(key))
    if data_state is not None:
        tree["data_state"] = np.asarray(data_state)
    if rank_mask is not None:
        tree["rank_mask"] = np.asarray(rank_mask)
    if partition_state is not None:
        tree["partition_state"] = np.asarray(partition_state)
    save_pytree(path, tree)


def load_federated_state(path: str, *, full: bool = False):
    """Returns (base, lora, opt, round) — or, with ``full=True``,
    (base, lora, opt, round, key, data_state, extras): key/data_state are
    None for checkpoints written without them, and ``extras`` is a dict
    holding "rank_mask" / "partition_state" when present."""
    t = load_pytree(path)
    out = (t["base"], t["lora"], t.get("opt", {}), int(t["round"]))
    if not full:
        return out
    key = None
    if "prng_key" in t:
        key = jax.random.wrap_key_data(jnp.asarray(t["prng_key"]))
    data_state = None
    if "data_state" in t:
        data_state = str(np.asarray(t["data_state"]))
    extras = {}
    if "rank_mask" in t:
        extras["rank_mask"] = np.asarray(t["rank_mask"])
    if "partition_state" in t:
        extras["partition_state"] = str(np.asarray(t["partition_state"]))
    return out + (key, data_state, extras)

"""Learning-rate schedules (callable lr support for the optimizers)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda t: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(t):
        t = jnp.asarray(t, jnp.float32)
        warm = peak_lr * t / max(warmup_steps, 1)
        prog = jnp.clip((t - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(t < warmup_steps, warm, cos)
    return lr


def step_decay(lr0: float, decay: float, every: int):
    def lr(t):
        return lr0 * decay ** (jnp.asarray(t, jnp.float32) // every)
    return lr


def make_schedule(name: str, lr: float, **kw):
    if name == "constant":
        return constant(lr)
    if name == "warmup_cosine":
        return warmup_cosine(lr, kw.get("warmup_steps", 50),
                             kw.get("total_steps", 1000))
    if name == "step":
        return step_decay(lr, kw.get("decay", 0.5), kw.get("every", 100))
    raise ValueError(f"unknown schedule '{name}'")

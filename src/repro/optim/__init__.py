from repro.optim.optimizers import make_optimizer, sgd, adamw, clip_by_global_norm

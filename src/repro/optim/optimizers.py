"""Minimal optax-like optimizers (optax is not installed in this container).

An optimizer is ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def _lr_at(lr, t):
    return lr(t) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0):
    """``lr`` may be a float or a schedule callable t -> lr."""
    def init(params):
        state = {"t": jnp.zeros((), jnp.int32)}
        if momentum != 0.0:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(grads, state, params=None):
        t = state["t"] + 1
        step = _lr_at(lr, t)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -step * g, grads), {"t": t}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        return jax.tree.map(lambda m: -step * m, mu), {"mu": mu, "t": t}

    return init, update


def adamw(lr, betas=(0.9, 0.999), eps: float = 1e-8,
          weight_decay: float = 0.0):
    b1, b2 = betas

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        step = _lr_at(lr, t)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            d = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
            return -step * (d + weight_decay * p)

        return (jax.tree.map(upd, m, v, params),
                {"m": m, "v": v, "t": t})

    return init, update


def make_optimizer(cfg):
    """cfg: OptimizerConfig (lr_schedule: constant | warmup_cosine | step)."""
    lr = cfg.lr
    if getattr(cfg, "lr_schedule", "constant") != "constant":
        from repro.optim.schedules import make_schedule
        lr = make_schedule(cfg.lr_schedule, cfg.lr,
                           **getattr(cfg, "lr_schedule_kwargs", {}) or {})
    if cfg.name == "sgd":
        return sgd(lr, cfg.momentum)
    if cfg.name == "adamw":
        return adamw(lr, cfg.betas, cfg.eps, cfg.weight_decay)
    raise ValueError(cfg.name)

"""recurrentgemma-9b [hybrid] — [arXiv:2402.19427] (Griffin).
38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention in a 1:2 pattern (2 recurrent blocks : 1 local-attn block),
local window 2048.  Natively sub-quadratic -> runs long_500k."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", num_layers=38,
        d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000, mlp_variant="geglu",
        block_pattern=("rglru", "rglru", "attn"), attn_window=2048,
        rglru_d_state=4096, tie_embeddings=True,
        lora_targets=("q", "v", "wx", "wy"),
        citation="arXiv:2402.19427")

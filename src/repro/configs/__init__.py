"""Architecture registry: ``get_config("<id>")`` / ``--arch <id>``.

The 10 assigned architectures plus the paper's own models.  ``long_500k``
support per arch is recorded in LONG_CONTEXT_OK (sub-quadratic requirement —
see DESIGN.md §5).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, FederatedConfig, InputShape,
                                LoRAConfig, ModelConfig, MoEConfig,
                                OptimizerConfig)

ARCHS = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "paligemma-3b": "paligemma_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gemma-2b": "gemma_2b",
    "whisper-medium": "whisper_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "stablelm-1.6b": "stablelm_1_6b",
    # the paper's own models
    "llama2-7b": "llama2_7b",
    "roberta-large": "roberta_large",
}

ASSIGNED = tuple(ARCHS)[:10]

# long_500k policy (DESIGN.md §5): native sub-quadratic or family-faithful
# sliding-window variant; None = skipped (pure full attention / enc-dec).
LONG_CONTEXT_OK = {
    "mistral-nemo-12b": "sliding_window",
    "gemma-2b": "sliding_window",
    "recurrentgemma-9b": "native",
    "xlstm-1.3b": "native",
    "paligemma-3b": None,
    "whisper-medium": None,
    "qwen3-8b": None,
    "qwen2-moe-a2.7b": None,
    "granite-moe-1b-a400m": None,
    "stablelm-1.6b": None,
    "llama2-7b": None,
    "roberta-large": None,
}

# encoder-only archs have no decode step at all
NO_DECODE = ("roberta-large",)


def get_config(arch: str, **kwargs) -> ModelConfig:
    if arch not in ARCHS:
        raise ValueError(f"unknown arch '{arch}'; options: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config(**kwargs)


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    """Config variant appropriate for an input shape (e.g. long_500k selects
    the sliding-window variant for dense archs that support it)."""
    if shape_name == "long_500k" and LONG_CONTEXT_OK.get(arch) == "sliding_window":
        return get_config(arch, sliding_window=True)
    return get_config(arch)


def supports_shape(arch: str, shape_name: str) -> bool:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode" and arch in NO_DECODE:
        return False
    if shape_name == "long_500k":
        return LONG_CONTEXT_OK.get(arch) is not None
    return True

"""gemma-2b [dense] — [arXiv:2403.08295].
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000; GeGLU, head_dim=256.
``long_500k`` uses the Gemma-2-family sliding-window variant (window=4096)."""
from repro.configs.base import ModelConfig


def config(*, sliding_window: bool = False) -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense", num_layers=18, d_model=2048,
        num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
        vocab_size=256000, mlp_variant="geglu", tie_embeddings=True,
        attn_window=4096 if sliding_window else None,
        citation="arXiv:2403.08295")

"""granite-moe-1b-a400m [moe] — [hf:ibm-granite/granite-3.0-1b-a400m-base].
24L d_model=1024 16H (GQA kv=8) d_ff_expert=512, 32 experts top-8,
vocab=49155."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe", num_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512,
        vocab_size=49155, tie_embeddings=True,
        moe=MoEConfig(num_experts=32, top_k=8, num_shared_experts=0,
                      d_ff_expert=512, d_ff_shared=0),
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base")

"""qwen2-moe-a2.7b [moe] — [hf:Qwen/Qwen1.5-MoE-A2.7B].
24L d_model=2048 16H (kv=16) d_ff_expert=1408, 60 routed experts top-4 +
4 shared experts (shared hidden 4*1408=5632), vocab=151936.
Experts padded 60->64 for 16-way expert parallelism (router masks the pads)."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
        vocab_size=151936, tie_embeddings=False,
        moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                      d_ff_expert=1408, d_ff_shared=5632),
        citation="hf:Qwen/Qwen1.5-MoE-A2.7B")

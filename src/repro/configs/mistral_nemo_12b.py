"""mistral-nemo-12b [dense] — [hf:mistralai/Mistral-Nemo-Base-2407].
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k ctx.
``long_500k`` uses the Mistral-family sliding-window variant (window=4096)."""
from repro.configs.base import ModelConfig


def config(*, sliding_window: bool = False) -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", num_layers=40, d_model=5120,
        num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=131072, rope_theta=1_000_000.0, tie_embeddings=False,
        mlp_variant="swiglu", attn_window=4096 if sliding_window else None,
        citation="hf:mistralai/Mistral-Nemo-Base-2407")

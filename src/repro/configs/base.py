"""Configuration dataclasses for models, input shapes, and federated runs.

Every assigned architecture gets one module in ``repro/configs`` that builds a
:class:`ModelConfig` with the exact assigned hyperparameters (citation included).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 0
    num_shared_experts: int = 0   # always-on experts
    d_ff_expert: int = 0          # per-expert hidden size
    d_ff_shared: int = 0          # shared-expert hidden size (total)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    # --- variants -----------------------------------------------------------
    mlp_variant: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qk_norm: bool = False
    attn_window: Optional[int] = None   # sliding-window size (None = full attention)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    attn_logit_softcap: Optional[float] = None
    parallel_residual: bool = False      # stablelm-style parallel attn+mlp

    # --- block pattern (hybrid / ssm) ----------------------------------------
    # repeated pattern of layer kinds; "attn" | "rglru" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- MoE ------------------------------------------------------------------
    moe: Optional[MoEConfig] = None

    # --- recurrent (RG-LRU / xLSTM) -------------------------------------------
    rglru_d_state: int = 0        # recurrence width (RecurrentGemma: d_model)
    mlstm_proj_factor: float = 2.0
    slstm_num_heads: int = 4

    # --- encoder-decoder (audio) ----------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 0       # stub frontend sequence length
    encoder_d_model: int = 0

    # --- VLM --------------------------------------------------------------------
    num_patches: int = 0          # stub vision frontend token count

    # --- numerics ----------------------------------------------------------------
    dtype: str = "float32"        # activation dtype ("bfloat16" on the mesh)
    param_dtype: str = "float32"

    # --- kernels ------------------------------------------------------------------
    # Route LoRA-adapted projections through repro/kernels/dispatch.py: fused
    # Pallas kernels (custom VJP) on TPU, interpreter tier when
    # REPRO_KERNEL_INTERPRET is set, pure-jnp reference otherwise.
    use_pallas: bool = False

    # --- LoRA defaults (paper: W_q, W_v) ------------------------------------------
    lora_targets: Tuple[str, ...] = ("q", "v")

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                vocab_size: int = 512, seq_cap: int = 128) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=512 d_model,
        2 layers, <=4 experts), preserving every structural switch."""
        num_heads = max(2, min(4, self.num_heads))
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        head_dim = max(16, d_model // num_heads)
        d_model = num_heads * head_dim
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(2, self.moe.top_k),
                num_shared_experts=min(1, self.moe.num_shared_experts),
                d_ff_expert=64, d_ff_shared=128)
        return dataclasses.replace(
            self, num_layers=num_layers, d_model=d_model, num_heads=num_heads,
            num_kv_heads=num_kv, head_dim=head_dim,
            d_ff=0 if self.d_ff == 0 else 4 * d_model,
            vocab_size=vocab_size, moe=moe,
            rglru_d_state=d_model if self.rglru_d_state else 0,
            encoder_layers=min(2, self.encoder_layers),
            encoder_frames=min(16, self.encoder_frames),
            encoder_d_model=d_model if self.encoder_d_model else 0,
            num_patches=min(8, self.num_patches),
            attn_window=None if self.attn_window is None
            else min(self.attn_window, seq_cap // 2),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: float = 8.0
    scaling: str = "sfedlora"      # lora | rslora | sfedlora | za | zb
    targets: Tuple[str, ...] = ("q", "v")
    init_std: float = 0.02
    # heterogeneous clients: one rank per client (len == num_clients);
    # overrides `rank` — all clients pad to max(ranks) with a rank mask and
    # train with their own gamma_i = scaling(alpha, r_i, N)
    ranks: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    num_clients: int = 3
    local_steps: int = 10
    rounds: int = 100
    aggregation: str = "fedsa"     # fedit | ffa | fedsa | rolora
    partition: str = "iid"         # iid | dirichlet
    dirichlet_alpha: float = 0.5
    participation: float = 1.0     # fraction of clients sampled per round
    # weight the server aggregate by per-client example counts
    # (dataset.size_weights) instead of a plain client mean
    weight_by_size: bool = False
    # --- async buffered aggregation (FedBuff-style; core/federated.py) ----
    # None = synchronous engine.  An int switches to the buffered engine
    # and caps how many accepted uploads aggregate per round (0 = no cap,
    # M = N — bit-identical to the synchronous engine at zero faults).
    buffer_size: Optional[int] = None
    staleness_beta: float = 0.5    # upload discount (1 + tau)^-beta
    # server-side screening before aggregation: reject non-finite uploads
    # and finite uploads whose norm exceeds screen_norm_mult x the round's
    # candidate median (robust to up to half the cohort corrupted)
    screen_updates: bool = True
    screen_norm_mult: float = 10.0
    # deterministic fault injection (repro.core.faults.FaultConfig);
    # a non-None value implies the buffered engine
    faults: Optional["FaultConfig"] = None  # noqa: F821 (core/faults.py)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgd"              # sgd | adamw
    lr: float = 5e-3
    momentum: float = 0.0
    betas: Tuple[float, float] = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    lr_schedule: str = "constant"     # constant | warmup_cosine | step
    lr_schedule_kwargs: Optional[dict] = None

"""roberta-large [encoder] — the paper's GLUE model [arXiv:1907.11692].
24L d_model=1024 16H d_ff=4096 vocab=50265; encoder-only (bidirectional).
No decode step (encoder-only): decode shapes are skipped for this arch."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="roberta-large", family="encoder", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096,
        vocab_size=50265, norm="layernorm", mlp_variant="gelu",
        tie_embeddings=True, citation="arXiv:1907.11692 (paper's GLUE model)")

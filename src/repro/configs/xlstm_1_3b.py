"""xlstm-1.3b [ssm] — [arXiv:2405.04517].
48L d_model=2048 4H d_ff=0 vocab=50304; alternating mLSTM (matrix memory,
chunkwise-parallel training form) and sLSTM (scalar memory, sequential scan)
blocks.  Constant-size state -> runs long_500k natively."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm", num_layers=48, d_model=2048,
        num_heads=4, num_kv_heads=4, head_dim=512, d_ff=0,
        vocab_size=50304, block_pattern=("mlstm", "slstm"),
        tie_embeddings=True, lora_targets=("q", "v"),
        citation="arXiv:2405.04517")

"""paligemma-3b [vlm] — [arXiv:2407.07726].
18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216; SigLIP + gemma.
Vision frontend is a stub: ``input_specs`` provides 256 precomputed SigLIP
patch embeddings; this config is the gemma decoder that consumes them."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
        num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
        vocab_size=257216, mlp_variant="geglu", tie_embeddings=True,
        num_patches=256, citation="arXiv:2407.07726")

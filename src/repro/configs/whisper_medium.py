"""whisper-medium [audio] — [arXiv:2212.04356].
24L (enc) + 24L (dec) d_model=1024 16H d_ff=4096 vocab=51865; enc-dec with
conv/mel frontend STUBBED: ``input_specs`` provides 1500 precomputed frame
embeddings (the conv2 output length for 30s audio)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096,
        vocab_size=51865, norm="layernorm", mlp_variant="gelu",
        block_pattern=("xattn",), encoder_layers=24, encoder_frames=1500,
        encoder_d_model=1024, tie_embeddings=True,
        lora_targets=("q", "v"), citation="arXiv:2212.04356")

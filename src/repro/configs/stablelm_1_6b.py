"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352; LayerNorm."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense", num_layers=24, d_model=2048,
        num_heads=32, num_kv_heads=32, head_dim=64, d_ff=5632,
        vocab_size=100352, norm="layernorm", tie_embeddings=False,
        citation="hf:stabilityai/stablelm-2-1_6b")

"""llama2-7b [dense] — the paper's own primary model [arXiv:2307.09288].
32L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=32000."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
        vocab_size=32000, tie_embeddings=False,
        citation="arXiv:2307.09288 (paper's primary model)")

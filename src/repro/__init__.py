"""repro: SFed-LoRA — stabilized federated LoRA fine-tuning in JAX."""
__version__ = "1.0.0"

"""Adapter lifecycle example: a federated trainer STREAMING per-round
adapters into a live continuous-batching server.

Production federated LoRA is two loops running at once — rounds finish and
publish new adapter versions while request traffic is being served.  This
example wires the repo's two halves together through the lifecycle
subsystem:

  * a ``LiveAdapterBank`` holds 2 device-resident hot slots backed by a
    host store of 4 tenants (the bank "doesn't fit" on device — tenants are
    LRU-promoted at admission and demoted to host RAM when evicted);
  * after every round ``FederatedTrainer.publish_adapters`` pushes each
    client's personalized AdapterSet into the bank — resident tenants
    hot-swap on device between decode chunks with ZERO recompiles;
  * requests keep flowing through ``serve_scheduled`` across the publishes,
    including one publish landing MID-SERVE through the ``on_boundary``
    swap window;
  * after each round, serving through the live (overflowing, freshly
    published) bank is asserted token-identical to a static AdapterBank
    stacked from the same round's adapters — train→serve parity at fixed
    shapes.

  PYTHONPATH=src python examples/train_serve_lifecycle.py

Set REPRO_KERNEL_INTERPRET=1 to run the fused-kernel interpret tier.
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.federated import FederatedTrainer
from repro.core.lora import AdapterBank, LiveAdapterBank
from repro.data.synthetic import FederatedDataset
from repro.launch.serve import Request, serve_scheduled
from repro.models.api import build_model

CLIENTS = 4
HOT_SLOTS = 2
ROUNDS = int(os.environ.get("LIFECYCLE_ROUNDS", "3"))
STEPS = int(os.environ.get("LIFECYCLE_STEPS", "6"))
interpret = os.environ.get("REPRO_KERNEL_INTERPRET", "") not in ("", "0")

cfg = get_config("gemma-2b").reduced()
if interpret:
    cfg = dataclasses.replace(cfg, use_pallas=True)
model = build_model(cfg)

ds = FederatedDataset(cfg.vocab_size, CLIENTS, seq_len=32, batch_per_client=2)
tr = FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=8),
                      fed_cfg=FederatedConfig(num_clients=CLIENTS,
                                              local_steps=1),
                      opt_cfg=OptimizerConfig())

# round 0 adapters seed the bank; only HOT_SLOTS of CLIENTS fit on device
live = LiveAdapterBank.from_sets(
    [tr.client_adapters(c) for c in range(CLIENTS)], hot_slots=HOT_SLOTS)
print(f"live bank: {len(live.tenants)} tenants, {live.hot_slots} hot slots "
      f"(r_max={live.r_max}) — overflow tenants live in host RAM")


def request_stream(seed):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    steps=STEPS, adapter_id=i % CLIENTS, arrival=0.0)
            for i in range(2 * CLIENTS)]


for rnd in range(ROUNDS):
    m = tr.run_round()
    n = tr.publish_adapters(live)
    print(f"round {m['round']}: loss {m['loss']:.4f} — published {n} "
          f"tenants (bank version {live.version}, {live.swaps} hot swaps)")

    # serve a stream through the live bank, with round r+1's FIRST tenant
    # landing mid-serve through the swap window: the chunk already
    # dispatched gathers the old slot, the next chunk gathers the new one
    done_live = serve_scheduled(model, tr.base, request_stream(rnd),
                                bank=live, max_batch=2, chunk=4, wait=False)

    # train→serve parity: a static bank stacked from the SAME round's
    # adapters must produce bit-identical tokens, even though the live bank
    # overflowed, promoted, demoted, and hot-swapped its way through
    static = AdapterBank.from_adapter_set(tr.adapters)
    done_static = serve_scheduled(model, tr.base, request_stream(rnd),
                                  bank=static, max_batch=2, chunk=4,
                                  wait=False)
    for a, b in zip(done_live, done_static):
        assert a.tokens == b.tokens, (
            f"rid {a.rid}: live {a.tokens} != static {b.tokens}")
    print(f"  parity OK: {len(done_live)} requests token-identical "
          f"live-vs-static ({live.promotions} promotions, "
          f"{live.demotions} demotions so far)")

# a publish landing MID-SERVE: swap tenant 0 at boundary 2 through the
# on_boundary window, with zero recompiles of the paged engine
admit_c = model._serve_jit_cache["paged_admit"]._cache_size()
chunk_c = model._serve_jit_cache["paged_chunk"]._cache_size()
swapped = []


def on_boundary(i):
    if i == 2 and not swapped:
        tr.publish_adapters(live, clients=[0])
        swapped.append(live.version)


serve_scheduled(model, tr.base, request_stream(99), bank=live,
                max_batch=2, chunk=4, wait=False, on_boundary=on_boundary)
assert swapped, "swap window never fired"
assert model._serve_jit_cache["paged_admit"]._cache_size() == admit_c
assert model._serve_jit_cache["paged_chunk"]._cache_size() == chunk_c
print(f"mid-serve hot swap at bank version {swapped[0]}: zero recompiles "
      f"(admit cache {admit_c}, chunk cache {chunk_c})")
print("lifecycle example OK")

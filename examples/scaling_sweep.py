"""Scaling-factor sweep (mini paper Fig. 3): gradient norms across ranks for
the three scaling schemes — the paper's core claim in one screen of output.

  PYTHONPATH=src python examples/scaling_sweep.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.federated import FederatedTrainer
from repro.core.scaling import scaling_factor
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

N = 4
RANKS = (4, 64, 512)
cfg = get_config("gemma-2b").reduced()
model = build_model(cfg)

print(f"{'scaling':<10} " + "".join(f"r={r:<12}" for r in RANKS) +
      "spread(r4/r512)")
for scaling in ("lora", "rslora", "sfedlora"):
    norms = []
    for r in RANKS:
        ds = FederatedDataset(cfg.vocab_size, N, seq_len=32,
                              batch_per_client=2)
        tr = FederatedTrainer(
            model, ds, lora_cfg=LoRAConfig(rank=r, alpha=8.0,
                                           scaling=scaling),
            fed_cfg=FederatedConfig(num_clients=N, local_steps=2,
                                    aggregation="fedsa"),
            opt_cfg=OptimizerConfig(name="sgd", lr=5e-3))
        tr.run(8)
        norms.append(np.mean([h["grad_norm"] for h in tr.history]))
    spread = norms[0] / max(norms[-1], 1e-12)
    print(f"{scaling:<10} " + "".join(f"{g:<12.2e}" for g in norms) +
          f"{spread:.1f}x")
print("\nexpected: alpha/r spread >> alpha/sqrt(r) spread > sqrt(N/r) "
      "spread ~ 1 (rank-invariant gradients = paper Theorem 4.2)")
for r in (4, 512):
    gs = [scaling_factor(s, 8.0, r, N) for s in ("lora", "rslora",
                                                 "sfedlora")]
    print(f"gamma at r={r}: lora={gs[0]:.4f} rslora={gs[1]:.4f} "
          f"sfedlora={gs[2]:.4f}")

"""Heterogeneous federated clients end to end: per-client LoRA ranks (padded
representation + rank mask), per-client scaling factors gamma_i =
alpha*sqrt(N/r_i) (the paper's Theorem 4.2 applied per client), Dirichlet
non-IID topic mixtures AND client example counts, and size-weighted
aggregation.

  PYTHONPATH=src python examples/heterogeneous_clients.py [--rounds 20]

Equivalent CLI:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --clients 4 --ranks 4,8,16,16 --partition dirichlet --weight-by-size
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.aggregation import get_strategy
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=20)
ap.add_argument("--ranks", default="4,8,16,16",
                help="comma-separated per-client ranks")
ap.add_argument("--strategy", default="fedsa")
args = ap.parse_args()
ranks = tuple(int(r) for r in args.ranks.split(","))
n = len(ranks)

cfg = get_config("gemma-2b").reduced()
model = build_model(cfg)
ds = FederatedDataset(cfg.vocab_size, n, seq_len=64, batch_per_client=4,
                      partition="dirichlet", dirichlet_alpha=0.3)
tr = FederatedTrainer(
    model, ds,
    lora_cfg=LoRAConfig(ranks=ranks, alpha=8.0, scaling="sfedlora"),
    fed_cfg=FederatedConfig(num_clients=n, local_steps=2,
                            aggregation=args.strategy,
                            partition="dirichlet", weight_by_size=True),
    opt_cfg=OptimizerConfig(name="sgd", lr=0.1),
    chunk_rounds=max(1, args.rounds // 4))

print("client  rank  gamma_i = 8*sqrt(N/r_i)  examples  agg_weight")
for i, r in enumerate(ranks):
    print(f"{i:6d}  {r:4d}  {tr.gammas[i]:23.4f}  {ds.sizes[i]:8d}  "
          f"{ds.size_weights[i]:10.3f}")

per_client = get_strategy(args.strategy).upload_bytes_per_client(
    tr.lora, 0, ranks=ranks)
print("per-client active-rank upload bytes:",
      ", ".join(f"{b/1e3:.1f}kB" for b in per_client))

tr.run(args.rounds, log_every=max(1, args.rounds // 5))

# the padded representation's invariant: client i's rank rows beyond r_i
# stay exactly zero through training and aggregation
q = tr.lora["stack"]["repeat"]["p0"]["attn"]["q"]
for i, r in enumerate(ranks):
    a_i, b_i = np.asarray(q["a"][i]), np.asarray(q["b"][i])
    assert np.all(a_i[..., r:, :] == 0) and np.all(b_i[..., :, r:] == 0)
print("masked rank rows/cols exactly zero for every client")

for c in range(n):
    print(f"client {c} (r={ranks[c]}, gamma={tr.client_gamma(c):.3f}) "
          f"held-out ppl: {tr.eval_perplexity(client=c):.3f}")

"""Quickstart: federated LoRA fine-tuning with the SFed-LoRA scaling factor.

Runs a reduced gemma-2b across 4 simulated clients for 15 rounds, comparing
the paper's gamma_z = alpha*sqrt(N/r) against standard LoRA scaling at high
rank, then merges adapters for zero-latency serving.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

RANK = 128           # high rank — where standard alpha/r collapses
CLIENTS = 4

cfg = get_config("gemma-2b").reduced()
model = build_model(cfg)
print(f"model: {cfg.name} (reduced) — {cfg.num_layers}L d={cfg.d_model}")

for scaling in ("lora", "sfedlora"):
    ds = FederatedDataset(cfg.vocab_size, CLIENTS, seq_len=64,
                          batch_per_client=4)
    tr = FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=RANK, alpha=8.0, scaling=scaling),
        fed_cfg=FederatedConfig(num_clients=CLIENTS, local_steps=2,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=5e-3))
    print(f"\n--- scaling={scaling}  gamma={tr.adapters.gamma:.4f} ---")
    tr.run(15, log_every=5)
    g = np.mean([h["grad_norm"] for h in tr.history])
    print(f"mean grad norm: {g:.2e}   "
          f"(alpha/r freezes high-rank adapters; sqrt(N/r) keeps them live)")

# zero-latency deployment: client 0's AdapterSet merges into the base weights
merged = tr.client_adapters(0).merge(tr.base)
print("\nmerged client-0 AdapterSet into base weights — serving needs no "
      "adapter math (paper §4, 'no additional inference latency').")

"""End-to-end driver: pretrain a ~small base LM, then federated LoRA
fine-tuning on heterogeneous (Dirichlet non-IID) clients for a few hundred
rounds, with evaluation and checkpointing.  This is the training-kind
end-to-end example (system-prompt deliverable b).

  PYTHONPATH=src python examples/federated_finetune.py [--rounds 200]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.common import pretrained_base
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=200)
ap.add_argument("--rank", type=int, default=64)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--chunk-rounds", type=int, default=10,
                help="rounds per compiled scan chunk")
args = ap.parse_args()

print("=== stage 1: pretrain base (cached) ===")
model, base = pretrained_base()

print("=== stage 2: federated LoRA fine-tune (non-IID Dir(0.5)) ===")
ds = FederatedDataset(model.cfg.vocab_size, args.clients, seq_len=64,
                      batch_per_client=4, partition="dirichlet",
                      dirichlet_alpha=0.5)
tr = FederatedTrainer(
    model, ds,
    lora_cfg=LoRAConfig(rank=args.rank, alpha=8.0, scaling="sfedlora"),
    fed_cfg=FederatedConfig(num_clients=args.clients, local_steps=5,
                            aggregation="fedsa", partition="dirichlet"),
    opt_cfg=OptimizerConfig(name="sgd", lr=1.0),  # tiny-model-scale lr
    chunk_rounds=args.chunk_rounds)  # each chunk is one compiled lax.scan
print(f"gamma_z = 8*sqrt({args.clients}/{args.rank}) = {tr.adapters.gamma:.4f}")
tr.run(args.rounds, log_every=max(1, args.rounds // 20))

print("=== stage 3: evaluate + checkpoint ===")
for c in range(args.clients):
    print(f"client {c} held-out ppl: {tr.eval_perplexity(client=c):.3f}")
tr.save("/tmp/sfedlora_ckpt.npz")   # carries PRNG key + round for bit-exact resume
print("checkpoint -> /tmp/sfedlora_ckpt.npz")
start = np.exp(tr.history[0]["loss"])
end = np.exp(np.mean([h["loss"] for h in tr.history[-10:]]))
print(f"train ppl {start:.2f} -> {end:.2f} over {args.rounds} rounds")
assert end < start, "training should reduce perplexity"

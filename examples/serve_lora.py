"""Serving example: load a federated checkpoint into an AdapterBank and run
MULTI-TENANT batched greedy decoding — every client's personalized adapters
served concurrently by the device-resident generation engine: one batched
prefill over the prompt, then a lax.scan decode loop on device, the
per-request adapter rows gathered lazily from the stacked bank (in-kernel on
the fused BGMV tier).  A whole generation is ONE host dispatch.

Also shows the classic single-tenant deployment (merge one client's
AdapterSet into the base weights: zero serving overhead).

  PYTHONPATH=src python examples/serve_lora.py

Set REPRO_KERNEL_INTERPRET=1 to run the fused-kernel interpret tier (the CI
serve smoke job does this).
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_adapter_state
from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.lora import AdapterBank
from repro.launch import serve
from repro.launch.serve import generate, generate_banked
from repro.models.api import build_model

CKPT = os.environ.get("SERVE_CKPT", "/tmp/sfedlora_ckpt.npz")
STEPS = int(os.environ.get("SERVE_STEPS", "12"))
interpret = os.environ.get("REPRO_KERNEL_INTERPRET", "") not in ("", "0")

if os.path.exists(CKPT):
    # an existing checkpoint came from examples/federated_finetune.py,
    # which trains the shared bench-4l model — serve the SAME architecture
    from benchmarks.common import bench_config
    cfg = bench_config(use_pallas=interpret)
else:
    cfg = get_config("gemma-2b").reduced()
    if interpret:
        # route every LoRA projection through the Pallas kernels under the
        # interpreter — the CI smoke proof serving survives the fused tier
        cfg = dataclasses.replace(cfg, use_pallas=True)
model = build_model(cfg)

if not os.path.exists(CKPT):
    # build a fresh tiny state if examples/federated_finetune.py wasn't run.
    # Save to a demo-specific path, NOT the shared CKPT: the shared path is
    # federated_finetune.py's bench-4l checkpoint, and a gemma-reduced state
    # written there would make the next run load mismatched shapes.
    print("(no checkpoint found — training 5 quick rounds first)")
    from repro.core.federated import FederatedTrainer
    from repro.data.synthetic import FederatedDataset
    ds = FederatedDataset(cfg.vocab_size, 2, seq_len=32, batch_per_client=2)
    tr = FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=8),
                          fed_cfg=FederatedConfig(num_clients=2,
                                                  local_steps=1),
                          opt_cfg=OptimizerConfig())
    tr.run(5)
    CKPT = "/tmp/serve_lora_demo_ckpt.npz"
    tr.save(CKPT)

# the WHOLE AdapterSet restores: A/B, per-client gammas, rank mask, metadata
base, aset = load_adapter_state(CKPT)
bank = AdapterBank.from_adapter_set(aset)
print(f"bank: {bank.size} tenants, ranks {bank.ranks}, "
      f"{aset.num_params():,} adapter params total")

# ---- multi-tenant: 4 requests, round-robin over the checkpointed clients
prompt = jnp.asarray([[5, 17, 42, 7]] * 4, jnp.int32)
ids = jnp.arange(4) % bank.size
serve.reset_dispatch_meter()
seq = generate_banked(model, base, bank, ids, prompt, steps=STEPS,
                      max_len=4 + STEPS)
print(f"banked decode (adapter ids {list(map(int, ids))}, "
      f"{serve.host_dispatches} host dispatch for {STEPS} tokens):")
print(seq)

# personalization check: rows served by different tenants may diverge even
# from identical prompts (B is client-personalized under FedSA aggregation)
same = bool(jnp.all(seq[0] == seq[1]))
print(f"tenant-{int(ids[1])} generation identical to tenant-0: {same}")

# ---- classic single-tenant path: merge tenant 0 into the base weights
merged = bank.adapter(0).merge(base)
seq_m = generate(model, merged, prompt[:1], steps=STEPS, max_len=4 + STEPS)
print("merged tenant-0 decode matches its banked row:",
      bool(jnp.all(seq_m[0] == seq[0])) or "close (fp reassociation)")

"""Serving example: load a federated checkpoint, merge a client's adapters,
and run batched greedy decoding with a KV cache (prefill + decode loop).

  PYTHONPATH=src python examples/serve_lora.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_federated_state
from repro.configs import get_config
from repro.configs.base import FederatedConfig, LoRAConfig, OptimizerConfig
from repro.core.lora import merge_lora, num_lora_params
from repro.launch.serve import generate
from repro.models.api import build_model

CKPT = "/tmp/sfedlora_ckpt.npz"

if not os.path.exists(CKPT):
    # build a fresh tiny state if examples/federated_finetune.py wasn't run
    print("(no checkpoint found — training 5 quick rounds first)")
    from repro.core.federated import FederatedTrainer
    from repro.data.synthetic import FederatedDataset
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    ds = FederatedDataset(cfg.vocab_size, 2, seq_len=32, batch_per_client=2)
    tr = FederatedTrainer(model, ds, lora_cfg=LoRAConfig(rank=8),
                          fed_cfg=FederatedConfig(num_clients=2,
                                                  local_steps=1),
                          opt_cfg=OptimizerConfig())
    tr.run(5)
    base, lora, gamma = tr.base, tr.lora, tr.gamma
else:
    from benchmarks.common import bench_config
    cfg = bench_config()
    model = build_model(cfg)
    base, lora, _, _ = load_federated_state(CKPT)
    gamma = 8.0 * (4 / 64) ** 0.5

client = 0
lora_c = jax.tree.map(lambda x: x[client], lora)
print(f"client {client} adapter params: {num_lora_params(lora_c):,}")
merged = merge_lora(base, lora_c, gamma)

prompt = jnp.asarray([[5, 17, 42, 7]] * 3, jnp.int32)   # batch of 3 requests
seq = generate(model, merged, prompt, steps=12, max_len=16)
print("generated token ids (merged adapters, zero serving overhead):")
print(seq)

# personalization check: client 1's B differs -> different merged model
lora_c1 = jax.tree.map(lambda x: x[min(1, x.shape[0] - 1)], lora)
merged1 = merge_lora(base, lora_c1, gamma)
seq1 = generate(model, merged1, prompt, steps=12, max_len=16)
same = bool(jnp.all(seq == seq1))
print(f"client-1 generations identical to client-0: {same} "
      f"(B is client-personalized under FedSA split aggregation)")

"""Multi-tenant serving throughput: base vs 1 adapter vs K=8 banked adapters,
compiled engine vs host loop.

Measures greedy KV-cache generation on the shared 4-layer benchmark model for
three serving shapes:

  base       no adapters — the floor (one GEMM per projection)
  adapter1   one AdapterSet for the whole batch (classic LoRA serving)
  bank8      a K=8 mixed-rank AdapterBank, one adapter per request (the
             multi-tenant path — lazy ``requests()`` gather on the compiled
             engine, materialized per-step gather on the host loop)

and two engines:

  compiled   ONE host dispatch per generation: batched prefill fills the KV
             cache over the whole prompt, then a lax.scan decode loop runs
             entirely on device (``launch/serve.generate``)
  hostloop   the pre-engine oracle: one jitted dispatch per token, prompt
             fed through single-token decode steps

Reported per (engine, variant): end-to-end tokens/sec, prefill and decode
tokens/sec separately, and the host-dispatch count per generation call.
Prefill/decode are split by timing a prefill-only call and attributing the
remainder to decode.  The headline ratios:

  bank8_vs_adapter1     compiled bank8 / compiled adapter1 tokens/sec — the
                        cost of multi-tenancy (1.0 = free)
  compiled_vs_hostloop  per-variant speedup of the device-resident engine

Timing excludes compilation (every callable is warmed first), interleaves
the variants round-robin, and spans several fresh compiles of every
executable (XLA CPU compile luck is a ~±15% band — larger than the effects
measured here), taking the per-variant minimum, so neither machine noise nor
one compile's draw can skew the cross-variant ratios; results land in
EXPERIMENTS/bench_serve.json AND the repo-root BENCH_serve.json (committed,
so the serving-perf trajectory is reviewable across PRs).

``--ci`` asserts the pinned regression floors (used by the serve-perf CI
smoke): bank8_vs_adapter1 and compiled-vs-hostloop on the bank path.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config
from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, init_adapter_set
from repro.launch import serve
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")
ROOT = os.path.join(os.path.dirname(__file__), "..")

BATCH = 8
PROMPT = 32
STEPS = 32
RANKS = (4, 8, 16, 8, 4, 16, 8, 8)

# CI regression floors (see --ci): deliberately below the locally measured
# numbers to absorb runner jitter, far above the pre-engine baseline
# (bank8_vs_adapter1 was 0.709 before the compiled engine + lazy gather).
CI_FLOOR_BANK_VS_ADAPTER = 0.75
CI_FLOOR_COMPILED_VS_HOSTLOOP = 1.3


REPEATS = 7
# XLA CPU compilation is nondeterministic enough to matter: the SAME program
# recompiled lands within a ~±15% speed band (layout/fusion luck), which is
# larger than the cross-variant effects this bench reports.  So the timing
# runs over several fresh compiles of every executable and keeps the
# per-variant minimum — the program's achievable speed, not one compile's
# draw.
COMPILE_TRIALS = 3


def _time_all(timers, *, model, repeats=REPEATS, trials=COMPILE_TRIALS):
    """min seconds per callable across ``trials`` fresh compiles, each timed
    ``repeats`` times INTERLEAVED round-robin so a slow phase of the machine
    penalizes every variant equally instead of whichever happened to be on
    the clock (compile/warm-up always excluded)."""
    best = {k: float("inf") for k in timers}
    for trial in range(trials):
        if trial:
            jax.clear_caches()
            model.__dict__.pop("_serve_jit_cache", None)
        for fn in timers.values():
            jax.block_until_ready(fn())
        for _ in range(repeats):
            for k, fn in timers.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[k] = min(best[k], time.perf_counter() - t0)
    return best


def _rows(best, name, prompt_len, steps, batch, dispatches):
    """tokens/sec rows (end-to-end, prefill, decode) for one variant."""
    out = {}
    for engine in ("compiled", "hostloop"):
        t_full = best[(name, engine)]
        t_pre = best[(name, engine + "_prefill")]
        out[engine] = {
            "tokens_per_sec": batch * (prompt_len + steps) / t_full,
            "prefill_tokens_per_sec": batch * prompt_len / t_pre,
            "decode_tokens_per_sec": (batch * (steps - 1)
                                      / max(t_full - t_pre, 1e-9)),
            "host_dispatches": dispatches[engine],
        }
    return out


def main(steps: int = STEPS, ci: bool = False):
    cfg = bench_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (BATCH, PROMPT), 0,
                                cfg.vocab_size)
    max_len = PROMPT + steps

    sets = [init_adapter_set(params, jax.random.fold_in(jax.random.key(2), i),
                             LoRAConfig(rank=r), n_clients=len(RANKS))
            for i, r in enumerate(RANKS)]
    bank = AdapterBank.from_sets(sets)
    one = sets[1]
    ids = jnp.arange(BATCH) % bank.size

    # prefill-only calls (jitted standalone so the split is measurable;
    # last_only matches the program the compiled engine actually runs)
    prefill = jax.jit(lambda a: model.prefill(
        params, model.init_cache(BATCH, max_len), prompt, a,
        last_only=True)[0])

    variants = {
        "base": {
            "compiled": lambda: serve.generate(model, params, prompt, steps,
                                               max_len),
            "hostloop": lambda s=steps: serve.generate_hostloop(
                model, params, prompt, s, max_len),
            "prefill": lambda: prefill(None),
        },
        "adapter1": {
            "compiled": lambda: serve.generate(model, params, prompt, steps,
                                               max_len, one),
            "hostloop": lambda s=steps: serve.generate_hostloop(
                model, params, prompt, s, max_len, one),
            "prefill": lambda: prefill(one),
        },
        "bank8": {
            "compiled": lambda: serve.generate_banked(model, params, bank,
                                                      ids, prompt, steps,
                                                      max_len),
            "hostloop": lambda s=steps: serve.generate_banked_hostloop(
                model, params, bank, ids, prompt, s, max_len),
            "prefill": lambda: prefill(bank.requests(ids)),
        },
    }

    timers = {}
    for name, fns in variants.items():
        timers[(name, "compiled")] = fns["compiled"]
        timers[(name, "compiled_prefill")] = fns["prefill"]
        timers[(name, "hostloop")] = fns["hostloop"]
        # host-loop prefill phase ~= a steps=1 run (prompt fed token by token)
        timers[(name, "hostloop_prefill")] = lambda fns=fns: fns["hostloop"](1)
    best = _time_all(timers, model=model)

    results = {"batch": BATCH, "prompt": PROMPT, "steps": steps,
               "ranks": list(RANKS),
               "engines": {"compiled": {}, "hostloop": {}}}
    print("bench,engine,variant,tokens_per_sec,prefill_tps,decode_tps,"
          "host_dispatches")
    for name, fns in variants.items():
        dispatches = {}
        for engine in ("compiled", "hostloop"):
            serve.reset_dispatch_meter()
            fns[engine]()
            dispatches[engine] = serve.host_dispatches
        rows = _rows(best, name, PROMPT, steps, BATCH, dispatches)
        for engine, row in rows.items():
            results["engines"][engine][name] = row
            print(f"serve,{engine},{name},{row['tokens_per_sec']:.1f},"
                  f"{row['prefill_tokens_per_sec']:.1f},"
                  f"{row['decode_tokens_per_sec']:.1f},"
                  f"{row['host_dispatches']}")

    comp = results["engines"]["compiled"]
    host = results["engines"]["hostloop"]
    results["bank8_vs_adapter1"] = (comp["bank8"]["tokens_per_sec"]
                                    / comp["adapter1"]["tokens_per_sec"])
    results["compiled_vs_hostloop"] = {
        k: comp[k]["tokens_per_sec"] / host[k]["tokens_per_sec"]
        for k in comp}
    print(f"serve,ratio,bank8_vs_adapter1,"
          f"{results['bank8_vs_adapter1']:.3f}")
    for k, v in results["compiled_vs_hostloop"].items():
        print(f"serve,ratio,compiled_vs_hostloop_{k},{v:.2f}")

    os.makedirs(OUT, exist_ok=True)
    for path in (os.path.join(OUT, "bench_serve.json"),
                 os.path.join(ROOT, "BENCH_serve.json")):
        with open(path, "w") as f:
            json.dump(results, f, indent=2)
    print("# wrote EXPERIMENTS/bench_serve.json + BENCH_serve.json")

    if ci:
        rel = results["bank8_vs_adapter1"]
        spd = results["compiled_vs_hostloop"]["bank8"]
        assert rel >= CI_FLOOR_BANK_VS_ADAPTER, (
            f"bank8_vs_adapter1 regressed: {rel:.3f} < "
            f"{CI_FLOOR_BANK_VS_ADAPTER}")
        assert spd >= CI_FLOOR_COMPILED_VS_HOSTLOOP, (
            f"compiled engine speedup regressed: {spd:.2f}x < "
            f"{CI_FLOOR_COMPILED_VS_HOSTLOOP}x")
        print(f"# CI floors hold: bank8_vs_adapter1={rel:.3f} "
              f">= {CI_FLOOR_BANK_VS_ADAPTER}, compiled_vs_hostloop(bank8)="
              f"{spd:.2f}x >= {CI_FLOOR_COMPILED_VS_HOSTLOOP}x")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--ci", action="store_true",
                    help="assert the pinned perf floors (CI serve-perf job)")
    a = ap.parse_args()
    main(steps=a.steps, ci=a.ci)

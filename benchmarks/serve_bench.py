"""Multi-tenant serving throughput: base vs 1 adapter vs K=8 banked adapters,
compiled engine vs host loop.

Measures greedy KV-cache generation on the shared 4-layer benchmark model for
three serving shapes:

  base       no adapters — the floor (one GEMM per projection)
  adapter1   one AdapterSet for the whole batch (classic LoRA serving)
  bank8      a K=8 mixed-rank AdapterBank, one adapter per request (the
             multi-tenant path — lazy ``requests()`` gather on the compiled
             engine, materialized per-step gather on the host loop)

and two engines:

  compiled   ONE host dispatch per generation: batched prefill fills the KV
             cache over the whole prompt, then a lax.scan decode loop runs
             entirely on device (``launch/serve.generate``)
  hostloop   the pre-engine oracle: one jitted dispatch per token, prompt
             fed through single-token decode steps

Reported per (engine, variant): end-to-end tokens/sec, prefill and decode
tokens/sec separately, and the host-dispatch count per generation call.
Prefill/decode are split by timing a prefill-only call and attributing the
remainder to decode.  The headline ratios:

  bank8_vs_adapter1     compiled bank8 / compiled adapter1 tokens/sec — the
                        cost of multi-tenancy (1.0 = free)
  compiled_vs_hostloop  per-variant speedup of the device-resident engine

Timing excludes compilation (every callable is warmed first), interleaves
the variants round-robin, and spans several fresh compiles of every
executable (XLA CPU compile luck is a ~±15% band — larger than the effects
measured here), taking the per-variant minimum, so neither machine noise nor
one compile's draw can skew the cross-variant ratios; results land in
EXPERIMENTS/bench_serve.json AND the repo-root BENCH_serve.json (committed,
so the serving-perf trajectory is reviewable across PRs).

The Poisson scenario measures the CONTINUOUS-BATCHING scheduler against
static batching on a stream: seeded Poisson arrivals (rate calibrated to a
fixed offered load against this machine's measured batch service time),
mixed short/long generations, same requests through both disciplines —

  scheduled   paged KV pool + chunked decode; newcomers admitted and
              finished requests evicted at chunk boundaries
              (``launch/serve.serve_scheduled``)
  static      batches of ``BATCH`` formed in arrival order, each batch
              waits for its last member and runs to its LONGEST request

reporting per-request p50/p99 latency and goodput (requested tokens / wall
clock).  Static batching pays twice at the tail — batch formation delay and
short requests riding long neighbors — which is exactly what the paged
scheduler removes; ``p99_static_over_scheduled`` is the headline.

The lifecycle scenario measures adapter HOT-SWAP UNDER LOAD: the same
saturated request stream through the scheduler three ways —

  static      a static AdapterBank (no publishes; the throughput ceiling)
  hotswap     a LiveAdapterBank with every tenant resident, a new adapter
              version published into a rotating slot every 4 scheduler
              boundaries through the ``on_boundary`` swap window (zero
              recompiles by construction — the swap donates one padded
              bank slot between decode chunks)
  overflow    a LiveAdapterBank with only half the tenants resident, so
              the stream drives LRU promotion/demotion through the
              host-RAM store (reported for information)

``hotswap_vs_static`` (scheduled tokens/sec ratio) is the headline: it
prices continuous publishing, and the CI floor pins it at >= 0.9x.

The quant scenario serves the same model from a QUANTIZED frozen base
(core/quant.py: int8 per-channel / int4 grouped, adapters fp) on the
compiled adapter1 path, reporting per mode the eligible-base footprint
reduction (packed bytes vs fp — the decode bandwidth story) and decode
tokens/sec vs fp.  Results land in the ``quant`` section of
BENCH_serve.json.

``--ci`` asserts the pinned regression floors (used by the serve-perf CI
smoke): bank8_vs_adapter1, compiled-vs-hostloop on the bank path, the
scheduler's p99 advantage over static batching, and int8 decode >= 0.9x fp.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config
from repro.analysis.sanitizers import RecompileGuard
from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, LiveAdapterBank, init_adapter_set
from repro.launch import serve
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")
ROOT = os.path.join(os.path.dirname(__file__), "..")

BATCH = 8
PROMPT = 32
STEPS = 32
RANKS = (4, 8, 16, 8, 4, 16, 8, 8)

# CI regression floors (see --ci): deliberately below the locally measured
# numbers to absorb runner jitter, far above the pre-engine baseline
# (bank8_vs_adapter1 was 0.709 before the compiled engine + lazy gather).
CI_FLOOR_BANK_VS_ADAPTER = 0.75
CI_FLOOR_COMPILED_VS_HOSTLOOP = 1.3
# and the scheduler: static batching's p99 must stay >= this multiple of the
# scheduled p99 at the same offered load (locally ~2-4x; 1.1 absorbs jitter)
CI_FLOOR_STATIC_P99_OVER_SCHED = 1.1
# adapter lifecycle: the scheduler serving through a live bank that takes a
# publish every 4 boundaries must hold >= this fraction of the static-bank
# throughput (the swap is one donated slot write between chunks — cheap —
# and recompiles are zero by construction, so 0.9 is mostly runner jitter)
CI_FLOOR_HOTSWAP_VS_STATIC = 0.9
# quantized serving: int8 base decode must hold >= this fraction of fp
# decode tokens/sec.  On this CPU container the reference tier dequantizes
# ONCE per compiled call (launch/serve._prepare_base), so quant costs one
# scan-invariant dequant, not a per-step one — 0.9 absorbs jitter on top.
CI_FLOOR_INT8_DECODE_VS_FP = 0.9

# Poisson scenario shape: a skewed short/long mix at an offered load that
# saturates static batching.  Every static batch runs to its longest
# member, so most slot-steps are wasted on finished short requests — its
# request capacity is BATCH / t(64-step batch), which is exactly what the
# load calibrates against.  At 1.0x that, static rides its saturation
# point (batch-formation delay + short requests pinned for their batch's
# full 64 steps + a queue that random-walks upward), while the scheduler —
# which reclaims a short request's slot and blocks the moment it finishes
# — runs at ~75% utilization and stays flat.  The tail-latency gap is
# structural, not machine luck.
SCHED_N = 96
SCHED_PROMPT = 8
SCHED_STEPS = (8, 64)
SCHED_MIX = (0.75, 0.25)      # mostly short, some long — serving reality
SCHED_LOAD = 1.0
SCHED_BLOCK = 8
SCHED_CHUNK = 8
SCHED_TRIALS = 2


REPEATS = 7
# XLA CPU compilation is nondeterministic enough to matter: the SAME program
# recompiled lands within a ~±15% speed band (layout/fusion luck), which is
# larger than the cross-variant effects this bench reports.  So the timing
# runs over several fresh compiles of every executable and keeps the
# per-variant minimum — the program's achievable speed, not one compile's
# draw.
COMPILE_TRIALS = 3


def _time_all(timers, *, model, repeats=REPEATS, trials=COMPILE_TRIALS):
    """min seconds per callable across ``trials`` fresh compiles, each timed
    ``repeats`` times INTERLEAVED round-robin so a slow phase of the machine
    penalizes every variant equally instead of whichever happened to be on
    the clock (compile/warm-up always excluded).

    After each trial's warm pass a RecompileGuard watches every engine the
    warmup cached on the model: any executable-cache growth during the
    timed section means an unwarmed shape was compiling inside the
    measurement (the PR-6/7 bench bug class) — hard error, not a silently
    slow number."""
    best = {k: float("inf") for k in timers}
    for trial in range(trials):
        if trial:
            jax.clear_caches()
            model.__dict__.pop("_serve_jit_cache", None)
        for fn in timers.values():
            jax.block_until_ready(fn())
        guard = RecompileGuard()
        guard.watch_model(model)
        for _ in range(repeats):
            for k, fn in timers.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[k] = min(best[k], time.perf_counter() - t0)
        guard.check()
    return best


def _rows(best, name, prompt_len, steps, batch, dispatches):
    """tokens/sec rows (end-to-end, prefill, decode) for one variant."""
    out = {}
    for engine in ("compiled", "hostloop"):
        t_full = best[(name, engine)]
        t_pre = best[(name, engine + "_prefill")]
        out[engine] = {
            "tokens_per_sec": batch * (prompt_len + steps) / t_full,
            "prefill_tokens_per_sec": batch * prompt_len / t_pre,
            "decode_tokens_per_sec": (batch * (steps - 1)
                                      / max(t_full - t_pre, 1e-9)),
            "host_dispatches": dispatches[engine],
        }
    return out


def _pct(sorted_vals, q):
    return sorted_vals[min(len(sorted_vals) - 1,
                           int(len(sorted_vals) * q))]


def _run_static_stream(model, params, bank, reqs, max_len):
    """Static-batching baseline on the same arrival stream: batches of
    ``BATCH`` in arrival order; each batch launches once its last member
    has arrived and runs to its longest request.  Returns per-request
    latencies (seconds from arrival to batch completion)."""
    lat = []
    t0 = time.monotonic()
    for i in range(0, len(reqs), BATCH):
        batch = reqs[i:i + BATCH]
        gap = batch[-1].arrival - (time.monotonic() - t0)
        if gap > 0:
            time.sleep(gap)
        s = max(r.steps for r in batch)
        ids = jnp.asarray([r.adapter_id for r in batch], jnp.int32)
        pr = jnp.asarray(np.stack([r.prompt for r in batch]))
        jax.block_until_ready(serve.generate_banked(
            model, params, bank, ids, pr, s, max_len))
        done = time.monotonic() - t0
        lat.extend(done - r.arrival for r in batch)
    return lat


def poisson_scenario(model, params, bank, *, load=SCHED_LOAD, n=SCHED_N,
                     seed=0):
    """Continuous batching vs static batching on one Poisson stream.

    The arrival rate is calibrated against THIS machine: one warm timed
    static batch gives the batch service time, and the rate is set to
    ``load`` of the resulting capacity — so the scenario stresses queueing
    identically on fast and slow runners."""
    rng = np.random.default_rng(seed)
    steps_list = rng.choice(SCHED_STEPS, n, p=SCHED_MIX)
    prompts = rng.integers(0, model.cfg.vocab_size,
                           (n, SCHED_PROMPT)).astype(np.int32)
    ids = (np.arange(n) % bank.size).astype(np.int32)
    max_len = SCHED_PROMPT + max(SCHED_STEPS)

    def mk_requests(arrivals):
        return [serve.Request(rid=i, prompt=prompts[i],
                              steps=int(steps_list[i]),
                              adapter_id=int(ids[i]),
                              arrival=float(arrivals[i]))
                for i in range(n)]

    # ---- warm every shape both disciplines can hit: static batches at
    # each distinct step count (full and trailing partial batch), scheduled
    # admission groups of 1..BATCH
    sizes = {BATCH} | ({n % BATCH} if n % BATCH else set())
    for s in sorted(set(SCHED_STEPS)):
        for b in sorted(sizes):
            jax.block_until_ready(serve.generate_banked(
                model, params, bank, jnp.asarray(ids[:b]),
                jnp.asarray(prompts[:b]), int(s), max_len))
    for g in range(1, BATCH + 1):
        serve.serve_scheduled(
            model, params, mk_requests(np.zeros(n))[:g], bank=bank,
            max_batch=BATCH, block_size=SCHED_BLOCK, chunk=SCHED_CHUNK,
            max_len=max_len, wait=False)

    # ---- calibrate: best measured batch service time -> arrival rate
    # (a single timing can land 50%+ off on a noisy runner, which would
    # halve or double the offered load; the best of three is stable)
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.monotonic()
        jax.block_until_ready(serve.generate_banked(
            model, params, bank, jnp.asarray(ids[:BATCH]),
            jnp.asarray(prompts[:BATCH]), max(SCHED_STEPS), max_len))
        t_batch = min(t_batch, time.monotonic() - t0)
    rate = load * BATCH / t_batch                      # requests / second
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))

    # ---- timed runs, the same stream through both disciplines; several
    # trials, keeping each discipline's best (min-across-trials, like the
    # throughput section: the achievable number, not one trial's draw)
    toks = int(steps_list.sum())
    best = {"scheduled": None, "static": None}
    for _ in range(SCHED_TRIALS):
        t0 = time.monotonic()
        done = serve.serve_scheduled(model, params, mk_requests(arrivals),
                                     bank=bank, max_batch=BATCH,
                                     block_size=SCHED_BLOCK,
                                     chunk=SCHED_CHUNK, max_len=max_len,
                                     wait=True)
        wall = time.monotonic() - t0
        lats = sorted(r.t_done - r.arrival for r in done)
        t0 = time.monotonic()
        lat_static = sorted(_run_static_stream(
            model, params, bank, mk_requests(arrivals), max_len))
        wall_static = time.monotonic() - t0
        for name, ls, w in (("scheduled", lats, wall),
                            ("static", lat_static, wall_static)):
            row = {"p50_latency_ms": 1000 * _pct(ls, 0.50),
                   "p99_latency_ms": 1000 * _pct(ls, 0.99),
                   "goodput_tokens_per_sec": toks / w}
            if (best[name] is None
                    or row["p99_latency_ms"] < best[name]["p99_latency_ms"]):
                best[name] = row

    out = {"n": n, "load": load, "arrival_rate_per_s": rate,
           "prompt": SCHED_PROMPT, "steps_mix": sorted(set(SCHED_STEPS)),
           "steps_mix_p": list(SCHED_MIX), "max_batch": BATCH,
           "block_size": SCHED_BLOCK, "chunk": SCHED_CHUNK}
    for name in ("scheduled", "static"):
        out[name] = best[name]
        print(f"serve,{name},poisson,"
              f"{out[name]['goodput_tokens_per_sec']:.1f},"
              f"{out[name]['p50_latency_ms']:.0f},"
              f"{out[name]['p99_latency_ms']:.0f},-")
    out["p99_static_over_scheduled"] = (out["static"]["p99_latency_ms"]
                                        / out["scheduled"]["p99_latency_ms"])
    print(f"serve,ratio,p99_static_over_scheduled,"
          f"{out['p99_static_over_scheduled']:.2f}")
    return out


# lifecycle scenario shape: a saturated stream (everything already arrived
# — wait=False, pure scheduler throughput), uniform steps so the static and
# live runs retire identical token counts, one publish every SWAP_EVERY
# scheduler boundaries into a rotating tenant slot
LIFE_N = 48
LIFE_PROMPT = 8
LIFE_STEPS = 16
LIFE_SWAP_EVERY = 4
LIFE_TRIALS = 3


def lifecycle_scenario(model, params, bank, sets):
    """Hot-swap under load: scheduled throughput while publishing adapters.

    The same saturated stream runs through (a) the static bank, (b) a live
    bank taking a publish every ``LIFE_SWAP_EVERY`` boundaries (every
    tenant resident — isolates publish cost), and (c) a live bank with
    half the slots (adds LRU promotion/demotion churn; informational).
    Best-of-``LIFE_TRIALS`` wall time per discipline, tokens/sec and the
    ``hotswap_vs_static`` ratio reported."""
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, model.cfg.vocab_size,
                           (LIFE_N, LIFE_PROMPT)).astype(np.int32)
    max_len = LIFE_PROMPT + LIFE_STEPS
    toks = LIFE_N * LIFE_STEPS

    def mk_requests():
        return [serve.Request(rid=i, prompt=prompts[i], steps=LIFE_STEPS,
                              adapter_id=int(i % bank.size), arrival=0.0)
                for i in range(LIFE_N)]

    def run(mk_bank, on_boundary_of=None):
        best = float("inf")
        meta = {}
        for _ in range(LIFE_TRIALS):
            b = mk_bank()
            hook = on_boundary_of(b) if on_boundary_of else None
            serve.serve_scheduled(model, params, mk_requests(), bank=b,
                                  max_batch=BATCH, block_size=SCHED_BLOCK,
                                  chunk=SCHED_CHUNK, max_len=max_len,
                                  wait=False, on_boundary=hook)   # warm
            b = mk_bank()
            hook = on_boundary_of(b) if on_boundary_of else None
            t0 = time.monotonic()
            serve.serve_scheduled(model, params, mk_requests(), bank=b,
                                  max_batch=BATCH, block_size=SCHED_BLOCK,
                                  chunk=SCHED_CHUNK, max_len=max_len,
                                  wait=False, on_boundary=hook)
            best = min(best, time.monotonic() - t0)
            if isinstance(b, LiveAdapterBank):
                meta = {"publishes": b.version, "hot_swaps": b.swaps,
                        "promotions": b.promotions, "demotions": b.demotions}
        return {"tokens_per_sec": toks / best, **meta}

    def swapping(live):
        def hook(i):
            if i and i % LIFE_SWAP_EVERY == 0:
                slot = (i // LIFE_SWAP_EVERY - 1) % len(sets)
                live.publish(slot, sets[(slot + 1) % len(sets)])
        return hook

    out = {"n": LIFE_N, "prompt": LIFE_PROMPT, "steps": LIFE_STEPS,
           "swap_every_boundaries": LIFE_SWAP_EVERY, "max_batch": BATCH,
           "static": run(lambda: bank),
           "hotswap": run(lambda: LiveAdapterBank.from_bank(
               bank, hot_slots=bank.size), swapping),
           "overflow": run(lambda: LiveAdapterBank.from_bank(
               bank, hot_slots=bank.size // 2), swapping)}
    out["hotswap_vs_static"] = (out["hotswap"]["tokens_per_sec"]
                                / out["static"]["tokens_per_sec"])
    out["overflow_vs_static"] = (out["overflow"]["tokens_per_sec"]
                                 / out["static"]["tokens_per_sec"])
    print("bench,lifecycle,variant,tokens_per_sec,publishes,hot_swaps,"
          "promotions")
    for name in ("static", "hotswap", "overflow"):
        r = out[name]
        print(f"serve,lifecycle,{name},{r['tokens_per_sec']:.1f},"
              f"{r.get('publishes', 0)},{r.get('hot_swaps', 0)},"
              f"{r.get('promotions', 0)}")
    print(f"serve,ratio,hotswap_vs_static,{out['hotswap_vs_static']:.3f}")
    print(f"serve,ratio,overflow_vs_static,{out['overflow_vs_static']:.3f}")
    return out


def quant_scenario(model, params, one, prompt, *, steps, max_len):
    """fp vs int8 vs int4 frozen base on the compiled adapter1 path.

    Per mode: eligible-base footprint (packed bytes vs the fp bytes the same
    leaves would occupy — ``quant_footprint``), compiled end-to-end and
    decode tokens/sec, and the decode ratio vs fp.  The footprint columns
    are the bandwidth story (the eligible GEMM weights are what decode
    streams every step); the CPU decode ratio only proves the engine-level
    dequant hoist keeps quantization ~free on the reference tier."""
    from repro.core.quant import quant_footprint, quantize_tree

    bases = {"fp": params,
             "int8": quantize_tree(params, "int8"),
             "int4": quantize_tree(params, "int4")}
    # one jitted prefill taking the base as a pytree argument: fp/int8/int4
    # land as three cache entries of a single wrapper instead of three
    # fresh jit objects built inside the loop (each with a cold cache)
    prefill = jax.jit(lambda b, a: model.prefill(
        b, model.init_cache(BATCH, max_len), prompt, a, last_only=True)[0])
    timers = {}
    for mode, base in bases.items():
        timers[(mode, "compiled")] = (
            lambda b=base: serve.generate(model, b, prompt, steps, max_len,
                                          one))
        timers[(mode, "compiled_prefill")] = lambda b=base: prefill(b, one)
    best = _time_all(timers, model=model)

    out = {}
    print("bench,quant,mode,base_mbytes,footprint_reduction,tokens_per_sec,"
          "decode_tps,decode_vs_fp")
    for mode, base in bases.items():
        foot = quant_footprint(base)
        t_full = best[(mode, "compiled")]
        t_pre = best[(mode, "compiled_prefill")]
        out[mode] = {
            "base_mbytes": foot["base_bytes"] / 1e6,
            "footprint_reduction": (foot["base_fp_bytes"]
                                    / foot["base_bytes"]),
            "tokens_per_sec": BATCH * (PROMPT + steps) / t_full,
            "decode_tokens_per_sec": (BATCH * (steps - 1)
                                      / max(t_full - t_pre, 1e-9)),
        }
    for mode in bases:
        out[mode]["decode_vs_fp"] = (out[mode]["decode_tokens_per_sec"]
                                     / out["fp"]["decode_tokens_per_sec"])
        r = out[mode]
        print(f"serve,quant,{mode},{r['base_mbytes']:.2f},"
              f"{r['footprint_reduction']:.2f},{r['tokens_per_sec']:.1f},"
              f"{r['decode_tokens_per_sec']:.1f},{r['decode_vs_fp']:.2f}")
    return out


def main(steps: int = STEPS, ci: bool = False):
    cfg = bench_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (BATCH, PROMPT), 0,
                                cfg.vocab_size)
    max_len = PROMPT + steps

    sets = [init_adapter_set(params, jax.random.fold_in(jax.random.key(2), i),
                             LoRAConfig(rank=r), n_clients=len(RANKS))
            for i, r in enumerate(RANKS)]
    bank = AdapterBank.from_sets(sets)
    one = sets[1]
    ids = jnp.arange(BATCH) % bank.size

    # prefill-only calls (jitted standalone so the split is measurable;
    # last_only matches the program the compiled engine actually runs)
    prefill = jax.jit(lambda a: model.prefill(
        params, model.init_cache(BATCH, max_len), prompt, a,
        last_only=True)[0])

    variants = {
        "base": {
            "compiled": lambda: serve.generate(model, params, prompt, steps,
                                               max_len),
            "hostloop": lambda s=steps: serve.generate_hostloop(
                model, params, prompt, s, max_len),
            "prefill": lambda: prefill(None),
        },
        "adapter1": {
            "compiled": lambda: serve.generate(model, params, prompt, steps,
                                               max_len, one),
            "hostloop": lambda s=steps: serve.generate_hostloop(
                model, params, prompt, s, max_len, one),
            "prefill": lambda: prefill(one),
        },
        "bank8": {
            "compiled": lambda: serve.generate_banked(model, params, bank,
                                                      ids, prompt, steps,
                                                      max_len),
            "hostloop": lambda s=steps: serve.generate_banked_hostloop(
                model, params, bank, ids, prompt, s, max_len),
            "prefill": lambda: prefill(bank.requests(ids)),
        },
    }

    timers = {}
    for name, fns in variants.items():
        timers[(name, "compiled")] = fns["compiled"]
        timers[(name, "compiled_prefill")] = fns["prefill"]
        timers[(name, "hostloop")] = fns["hostloop"]
        # host-loop prefill phase ~= a steps=1 run (prompt fed token by token)
        timers[(name, "hostloop_prefill")] = lambda fns=fns: fns["hostloop"](1)
    best = _time_all(timers, model=model)

    results = {"batch": BATCH, "prompt": PROMPT, "steps": steps,
               "ranks": list(RANKS),
               "engines": {"compiled": {}, "hostloop": {}}}
    print("bench,engine,variant,tokens_per_sec,prefill_tps,decode_tps,"
          "host_dispatches")
    for name, fns in variants.items():
        dispatches = {}
        for engine in ("compiled", "hostloop"):
            serve.reset_dispatch_meter()
            fns[engine]()
            dispatches[engine] = serve.host_dispatches
        rows = _rows(best, name, PROMPT, steps, BATCH, dispatches)
        for engine, row in rows.items():
            results["engines"][engine][name] = row
            print(f"serve,{engine},{name},{row['tokens_per_sec']:.1f},"
                  f"{row['prefill_tokens_per_sec']:.1f},"
                  f"{row['decode_tokens_per_sec']:.1f},"
                  f"{row['host_dispatches']}")

    comp = results["engines"]["compiled"]
    host = results["engines"]["hostloop"]
    results["bank8_vs_adapter1"] = (comp["bank8"]["tokens_per_sec"]
                                    / comp["adapter1"]["tokens_per_sec"])
    results["compiled_vs_hostloop"] = {
        k: comp[k]["tokens_per_sec"] / host[k]["tokens_per_sec"]
        for k in comp}
    print(f"serve,ratio,bank8_vs_adapter1,"
          f"{results['bank8_vs_adapter1']:.3f}")
    for k, v in results["compiled_vs_hostloop"].items():
        print(f"serve,ratio,compiled_vs_hostloop_{k},{v:.2f}")

    results["quant"] = quant_scenario(model, params, one, prompt,
                                      steps=steps, max_len=max_len)
    results["scheduled_poisson"] = poisson_scenario(model, params, bank)
    results["lifecycle"] = lifecycle_scenario(model, params, bank, sets)

    os.makedirs(OUT, exist_ok=True)
    for path in (os.path.join(OUT, "bench_serve.json"),
                 os.path.join(ROOT, "BENCH_serve.json")):
        with open(path, "w") as f:
            json.dump(results, f, indent=2)
    print("# wrote EXPERIMENTS/bench_serve.json + BENCH_serve.json")

    if ci:
        rel = results["bank8_vs_adapter1"]
        spd = results["compiled_vs_hostloop"]["bank8"]
        assert rel >= CI_FLOOR_BANK_VS_ADAPTER, (
            f"bank8_vs_adapter1 regressed: {rel:.3f} < "
            f"{CI_FLOOR_BANK_VS_ADAPTER}")
        assert spd >= CI_FLOOR_COMPILED_VS_HOSTLOOP, (
            f"compiled engine speedup regressed: {spd:.2f}x < "
            f"{CI_FLOOR_COMPILED_VS_HOSTLOOP}x")
        tail = results["scheduled_poisson"]["p99_static_over_scheduled"]
        assert tail >= CI_FLOOR_STATIC_P99_OVER_SCHED, (
            f"scheduler p99 advantage regressed: static/scheduled "
            f"{tail:.2f}x < {CI_FLOOR_STATIC_P99_OVER_SCHED}x")
        q8 = results["quant"]["int8"]["decode_vs_fp"]
        assert q8 >= CI_FLOOR_INT8_DECODE_VS_FP, (
            f"int8 decode regressed vs fp: {q8:.2f}x < "
            f"{CI_FLOOR_INT8_DECODE_VS_FP}x (is the reference-tier dequant "
            "still hoisted out of the decode scan?)")
        hs = results["lifecycle"]["hotswap_vs_static"]
        assert hs >= CI_FLOOR_HOTSWAP_VS_STATIC, (
            f"hot-swap-under-load regressed: {hs:.3f}x < "
            f"{CI_FLOOR_HOTSWAP_VS_STATIC}x of static-bank throughput "
            "(is the slot swap still recompile-free?)")
        print(f"# CI floors hold: bank8_vs_adapter1={rel:.3f} "
              f">= {CI_FLOOR_BANK_VS_ADAPTER}, compiled_vs_hostloop(bank8)="
              f"{spd:.2f}x >= {CI_FLOOR_COMPILED_VS_HOSTLOOP}x, "
              f"p99 static/scheduled={tail:.2f}x >= "
              f"{CI_FLOOR_STATIC_P99_OVER_SCHED}x, int8 decode {q8:.2f}x "
              f">= {CI_FLOOR_INT8_DECODE_VS_FP}x fp, hotswap {hs:.3f}x "
              f">= {CI_FLOOR_HOTSWAP_VS_STATIC}x static")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--ci", action="store_true",
                    help="assert the pinned perf floors (CI serve-perf job)")
    a = ap.parse_args()
    main(steps=a.steps, ci=a.ci)

"""Multi-tenant serving throughput: base vs 1 adapter vs K=8 banked adapters.

Measures greedy KV-cache decode tokens/sec on the shared 4-layer benchmark
model for three serving shapes:

  base       no adapters — the floor (one GEMM per projection)
  adapter1   one AdapterSet for the whole batch (classic LoRA serving)
  bank8      a K=8 mixed-rank AdapterBank, one adapter per request gathered
             inside the compiled step (the multi-tenant path)

The interesting number is bank8/adapter1: the batched gather + per-request
rank-r delta costs a pair of batched GEMVs per projection, so banked serving
of 8 heterogeneous tenants should stay within a small factor of single-
adapter serving rather than 8x (which is what one-merge-per-tenant would
cost in executables or weight copies).

Timing excludes compilation (one warm-up decode per variant); results land
in EXPERIMENTS/bench_serve.json.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config
from repro.configs.base import LoRAConfig
from repro.core.lora import AdapterBank, init_adapter_set
from repro.launch.serve import generate, generate_banked
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")

BATCH = 8
STEPS = 32
RANKS = (4, 8, 16, 8, 4, 16, 8, 8)


def _decode_tps(fn, batch, steps, repeats=3):
    fn()                                    # compile + warm caches
    times = []
    for _ in range(repeats):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    dt = min(times)
    return batch * steps / dt


def main(steps: int = STEPS):
    cfg = bench_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (BATCH, 4), 0,
                                cfg.vocab_size)
    max_len = 4 + steps

    sets = [init_adapter_set(params, jax.random.fold_in(jax.random.key(2), i),
                             LoRAConfig(rank=r), n_clients=len(RANKS))
            for i, r in enumerate(RANKS)]
    bank = AdapterBank.from_sets(sets)
    one = sets[1]
    ids = jnp.arange(BATCH) % bank.size

    variants = {
        "base": lambda: generate(model, params, prompt, steps, max_len),
        "adapter1": lambda: generate(model, params, prompt, steps, max_len,
                                     adapters=one),
        "bank8": lambda: generate_banked(model, params, bank, ids, prompt,
                                         steps, max_len),
    }
    results = {"batch": BATCH, "steps": steps, "ranks": list(RANKS)}
    print("bench,variant,tokens_per_sec")
    for name, fn in variants.items():
        tps = _decode_tps(fn, BATCH, steps)
        results[name] = {"tokens_per_sec": tps}
        print(f"serve,{name},{tps:.1f}")
    if results.get("adapter1") and results.get("bank8"):
        rel = (results["bank8"]["tokens_per_sec"]
               / results["adapter1"]["tokens_per_sec"])
        results["bank8_vs_adapter1"] = rel
        print(f"serve,bank8_vs_adapter1,{rel:.3f}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_serve.json"), "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote EXPERIMENTS/bench_serve.json")
    return results


if __name__ == "__main__":
    main()

"""Paper Fig. 4 / §5.2: stability across client counts at fixed high rank.

Claim: at r=512, alpha/r baselines degrade as N grows (ppl 7 -> 15 in the
paper); SFed-LoRA is invariant to N (sqrt(N) compensates aggregation).
Reduced scale: rank 256, N in {2, 4, 8}.
"""
import numpy as np

from benchmarks.common import pretrained_base, run_method

CLIENTS = (2, 4, 8)
MAIN = ("RoLoRA", "FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA")
RANK = 256


def main(rounds: int = 25, emit=print):
    model, base = pretrained_base()
    emit("bench,method,clients,final_loss,final_ppl")
    results = {}
    for method in MAIN:
        for n in CLIENTS:
            tr = run_method(method, rank=RANK, clients=n, rounds=rounds,
                            model=model, base=base)
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            results[(method, n)] = final
            emit(f"fig4,{method},{n},{final:.4f},{np.exp(final):.3f}")
    return results


if __name__ == "__main__":
    main()

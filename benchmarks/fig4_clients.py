"""Paper Fig. 4 / §5.2: stability across client counts at fixed high rank.

Claim: at r=512, alpha/r baselines degrade as N grows (ppl 7 -> 15 in the
paper); SFed-LoRA is invariant to N (sqrt(N) compensates aggregation).
Reduced scale: rank 256, N in {2, 4, 8}.  Each run executes as one compiled
scan chunk; the rounds/sec column tracks the engine's steady-state
throughput as N grows (timed on a second, jit-cached chunk of the same
length — the accuracy columns come from the first chunk only).

The heterogeneous sweep repeats the main methods with per-client ranks
mixed across {r/4, r/2, r} (the regime FLoRA/ILoRA show breaks naive
factor-averaging): padded-rank engine, per-client gamma_i, Dirichlet client
sizes with size-weighted aggregation.
"""
import time

import numpy as np

from benchmarks.common import pretrained_base, run_method

CLIENTS = (2, 4, 8)
MAIN = ("RoLoRA", "FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA")
HET = ("SFed-LoRA", "FLoRA")
RANK = 256


def het_ranks(n: int, r_max: int = RANK):
    """Mixed per-client ranks cycling r/4, r/2, r (always includes r_max)."""
    return tuple(r_max // (4, 2, 1)[i % 3] for i in range(n - 1)) + (r_max,)


def main(rounds: int = 25, emit=print):
    model, base = pretrained_base()
    emit("bench,method,clients,final_loss,final_ppl,rounds_per_sec")
    results = {}
    for method in MAIN:
        for n in CLIENTS:
            tr = run_method(method, rank=RANK, clients=n, rounds=rounds,
                            model=model, base=base, chunk_rounds=rounds)
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            t0 = time.perf_counter()
            tr.run(rounds)          # same chunk length -> compile-free
            rps = rounds / (time.perf_counter() - t0)
            results[(method, n)] = final
            emit(f"fig4,{method},{n},{final:.4f},{np.exp(final):.3f},"
                 f"{rps:.2f}")
    emit("bench,method,clients,ranks,final_loss,final_ppl,rounds_per_sec")
    for method in HET:
        for n in CLIENTS:
            ranks = het_ranks(n)
            tr = run_method(method, rank=RANK, ranks=ranks, clients=n,
                            rounds=rounds, partition="dirichlet",
                            weight_by_size=True, model=model, base=base,
                            chunk_rounds=rounds)
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            t0 = time.perf_counter()
            tr.run(rounds)
            rps = rounds / (time.perf_counter() - t0)
            results[(method, n, ranks)] = final
            emit(f"fig4het,{method},{n},{'|'.join(map(str, ranks))},"
                 f"{final:.4f},{np.exp(final):.3f},{rps:.2f}")
    return results


if __name__ == "__main__":
    main()

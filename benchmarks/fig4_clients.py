"""Paper Fig. 4 / §5.2: stability across client counts at fixed high rank.

Claim: at r=512, alpha/r baselines degrade as N grows (ppl 7 -> 15 in the
paper); SFed-LoRA is invariant to N (sqrt(N) compensates aggregation).
Reduced scale: rank 256, N in {2, 4, 8}.  Each run executes as one compiled
scan chunk; the rounds/sec column tracks the engine's steady-state
throughput as N grows (timed on a second, jit-cached chunk of the same
length — the accuracy columns come from the first chunk only).
"""
import time

import numpy as np

from benchmarks.common import pretrained_base, run_method

CLIENTS = (2, 4, 8)
MAIN = ("RoLoRA", "FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA")
RANK = 256


def main(rounds: int = 25, emit=print):
    model, base = pretrained_base()
    emit("bench,method,clients,final_loss,final_ppl,rounds_per_sec")
    results = {}
    for method in MAIN:
        for n in CLIENTS:
            tr = run_method(method, rank=RANK, clients=n, rounds=rounds,
                            model=model, base=base, chunk_rounds=rounds)
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            t0 = time.perf_counter()
            tr.run(rounds)          # same chunk length -> compile-free
            rps = rounds / (time.perf_counter() - t0)
            results[(method, n)] = final
            emit(f"fig4,{method},{n},{final:.4f},{np.exp(final):.3f},"
                 f"{rps:.2f}")
    return results


if __name__ == "__main__":
    main()

"""Paper Fig. 9 / App. B.4: mean/variance of post-adapter activations across
ranks, plus the Definition 4.1 moment sweep (App. A eq. 23): the analytic
one-step aggregated adapter moment gamma^2 r/N per scaling.

Claim: sfedlora's adapter output moment is ~constant in (N, r); lora's decays
as 1/(r N); rslora's as 1/N.
"""
import jax
import numpy as np

from benchmarks.common import pretrained_base, run_method
from repro.core.scaling import predicted_moment_scale, scaling_factor
from repro.core.stability import activation_moments, aggregated_moment_sweep


def main(rounds: int = 10, emit=print):
    # --- analytic Definition-4.1 sweep
    emit("bench,scaling,clients,rank,measured_moment,predicted_scale")
    sweep = aggregated_moment_sweep(jax.random.key(0), ranks=(4, 32, 128, 512),
                                    clients=(1, 4, 16))
    for name, res in sweep.items():
        for (n, r), v in res.items():
            pred = predicted_moment_scale(
                scaling_factor(name, 8.0, r, n), r, n)
            emit(f"fig9_moment,{name},{n},{r},{v:.4e},{pred:.4e}")

    # --- empirical activation stats during training
    model, base = pretrained_base()
    emit("bench,method,rank,act_mean,act_var")
    out = {}
    for method in ("FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA"):
        for rank in (32, 512):
            tr = run_method(method, rank=rank, rounds=rounds, model=model,
                            base=base)
            toks = np.asarray(tr.dataset.eval_batch(8))
            st = activation_moments(model, tr.base, {"tokens": toks},
                                    tr.client_adapters(0))
            out[(method, rank)] = st
            emit(f"fig9,{method},{rank},{st['mean']:.4e},{st['var']:.4e}")
    return sweep, out


if __name__ == "__main__":
    main()

"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = collective_bytes(per-device) / ICI_bw
plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode),
the useful-compute ratio, the dominant bottleneck, and a what-would-move-it
note.  Hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The XLA cost/memory analyses of an SPMD module are for the per-device
partitioned program, so no extra division by chip count is needed; chips
enter through the sharded shapes themselves.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, config_for_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS",
                          "dryrun")


def count_params(cfg):
    """Exact param count (+ active count for MoE) via eval_shape."""
    import jax
    from repro.models.api import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    total = active = 0
    def walk(node, in_moe):
        nonlocal total, active
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, in_moe or k == "moe")
            return
        n = 1
        for d in node.shape:
            n *= d
        total += n
        if in_moe and len(node.shape) >= 3 and cfg.moe:
            active += int(n * cfg.moe.top_k / max(cfg.moe.num_experts, 1))
        else:
            active += n
    walk(shapes, False)
    return total, active


def model_flops(arch, shape_name, cfg=None):
    """Architectural useful FLOPs for the whole step (global)."""
    cfg = cfg or config_for_shape(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    total, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * active * tokens
    return 2 * active * shape.global_batch          # decode: 1 token/seq


def analyze(rec, devices=None):
    if rec.get("skipped") or rec.get("error"):
        return None
    devices = devices or rec["devices"]
    src = rec.get("corrected", rec)   # unit-calibrated loop-exact stats
    ct = (src["flops"] or 0) / PEAK_FLOPS_BF16
    mt = (src["bytes_accessed"] or 0) / HBM_BW
    cb = sum(src["collective_bytes"].values())
    lt = cb / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = (src["flops"] or 0) * devices
    ratio = mf / hlo_global if hlo_global else 0.0
    return {**rec, "compute_s": ct, "memory_s": mt, "collective_s": lt,
            "dominant": dom, "model_flops": mf,
            "useful_ratio": ratio, "collective_total_bytes": cb}


_SUGGEST = {
    "compute": "reduce recompute (remat policy) / raise useful-ratio toward 1",
    "memory": "fuse adapter GEMMs (Pallas lora_matmul), shard activations "
              "(sequence parallel), bf16 logits CE",
    "collective": "reshard to cut all-gathers (kv-head replication, "
                  "seq-parallel norm), overlap A-aggregation with compute",
}


def table(records, emit=print):
    emit("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
         "model_flops,useful_ratio,note")
    rows = []
    for rec in records:
        if rec.get("skipped"):
            emit(f"{rec['arch']},{rec['shape']},{rec['mesh']},-,-,-,"
                 f"SKIP,-,-,{rec['skipped'][:40]}")
            continue
        if rec.get("error"):
            emit(f"{rec['arch']},{rec['shape']},{rec['mesh']},-,-,-,ERROR,-,-,"
                 f"{rec['error'][:60]}")
            continue
        a = analyze(rec)
        rows.append(a)
        emit(f"{a['arch']},{a['shape']},{a['mesh']},{a['compute_s']:.4f},"
             f"{a['memory_s']:.4f},{a['collective_s']:.4f},{a['dominant']},"
             f"{a['model_flops']:.3e},{a['useful_ratio']:.3f},"
             f"{_SUGGEST[a['dominant']][:50]}")
    return rows


def load_records(dirname=DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main(emit=print):
    recs = load_records()
    if not recs:
        emit("roofline,no_dryrun_records_found,run launch/dryrun.py first")
        return []
    return table(recs, emit)


if __name__ == "__main__":
    main()

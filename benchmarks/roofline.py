"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = collective_bytes(per-device) / ICI_bw
plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode),
the useful-compute ratio, the dominant bottleneck, and a what-would-move-it
note.  Hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The XLA cost/memory analyses of an SPMD module are for the per-device
partitioned program, so no extra division by chip count is needed; chips
enter through the sharded shapes themselves.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, config_for_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS",
                          "dryrun")


def count_params(cfg):
    """Exact param count (+ active count for MoE) via eval_shape."""
    import jax
    from repro.models.api import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    total = active = 0
    def walk(node, in_moe):
        nonlocal total, active
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, in_moe or k == "moe")
            return
        n = 1
        for d in node.shape:
            n *= d
        total += n
        if in_moe and len(node.shape) >= 3 and cfg.moe:
            active += int(n * cfg.moe.top_k / max(cfg.moe.num_experts, 1))
        else:
            active += n
    walk(shapes, False)
    return total, active


def model_flops(arch, shape_name, cfg=None):
    """Architectural useful FLOPs for the whole step (global)."""
    cfg = cfg or config_for_shape(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    total, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * active * tokens
    return 2 * active * shape.global_batch          # decode: 1 token/seq


def analyze(rec, devices=None):
    if rec.get("skipped") or rec.get("error"):
        return None
    devices = devices or rec["devices"]
    src = rec.get("corrected", rec)   # unit-calibrated loop-exact stats
    ct = (src["flops"] or 0) / PEAK_FLOPS_BF16
    mt = (src["bytes_accessed"] or 0) / HBM_BW
    cb = sum(src["collective_bytes"].values())
    lt = cb / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = (src["flops"] or 0) * devices
    ratio = mf / hlo_global if hlo_global else 0.0
    return {**rec, "compute_s": ct, "memory_s": mt, "collective_s": lt,
            "dominant": dom, "model_flops": mf,
            "useful_ratio": ratio, "collective_total_bytes": cb}


_SUGGEST = {
    "compute": "reduce recompute (remat policy) / raise useful-ratio toward 1",
    "memory": "fuse adapter GEMMs (Pallas lora_matmul), shard activations "
              "(sequence parallel), bf16 logits CE",
    "collective": "reshard to cut all-gathers (kv-head replication, "
                  "seq-parallel norm), overlap A-aggregation with compute",
}


def table(records, emit=print):
    emit("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
         "model_flops,useful_ratio,note")
    rows = []
    for rec in records:
        if rec.get("skipped"):
            emit(f"{rec['arch']},{rec['shape']},{rec['mesh']},-,-,-,"
                 f"SKIP,-,-,{rec['skipped'][:40]}")
            continue
        if rec.get("error"):
            emit(f"{rec['arch']},{rec['shape']},{rec['mesh']},-,-,-,ERROR,-,-,"
                 f"{rec['error'][:60]}")
            continue
        a = analyze(rec)
        rows.append(a)
        emit(f"{a['arch']},{a['shape']},{a['mesh']},{a['compute_s']:.4f},"
             f"{a['memory_s']:.4f},{a['collective_s']:.4f},{a['dominant']},"
             f"{a['model_flops']:.3e},{a['useful_ratio']:.3f},"
             f"{_SUGGEST[a['dominant']][:50]}")
    return rows


def load_records(dirname=DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def quant_decode_table(emit=print):
    """Decode arithmetic-intensity accounting under quantized base weights.

    Bytes-moved uses the ACTUAL storage dtypes: fp leaves at their itemsize,
    packed leaves at their int8/int4-packed + scales bytes (the
    ``QuantizedLinear.nbytes`` accounting, on eval_shape trees — no real
    buffers).  Decode at small batch is bandwidth-bound: every step streams
    the whole parameter set once, so predicted per-token intensity is
    2*P*B FLOPs over the tree's stored bytes, and the predicted decode
    speedup from quantization is simply the byte ratio.  When
    BENCH_serve.json carries a ``quant`` section the MEASURED decode ratio
    prints beside the prediction (CPU container: XLA re-dequantizes on the
    reference tier, so measured ~1.0x is expected there; the predicted
    column is the TPU story the packed DMA path exists for)."""
    import jax
    import numpy as np

    from benchmarks.common import bench_config
    from repro.core.quant import quant_footprint, quantize_tree
    from repro.models.api import build_model

    cfg = bench_config()
    model = build_model(cfg)
    batch = 8
    trees = {"fp": jax.eval_shape(lambda: model.init(jax.random.key(0)))}
    for mode in ("int8", "int4"):
        trees[mode] = jax.eval_shape(
            lambda m=mode: quantize_tree(model.init(jax.random.key(0)), m))
    foot = {m: quant_footprint(t) for m, t in trees.items()}
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(trees["fp"]))
    flops = 2 * n_params * batch

    measured = {}
    bench = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    try:
        with open(bench) as f:
            measured = {k: v.get("decode_vs_fp")
                        for k, v in json.load(f).get("quant", {}).items()}
    except (OSError, ValueError):
        pass

    emit("roofline,quant,mode,base_mbytes,total_mbytes,intensity_flops_per_"
         "byte,pred_decode_speedup,measured_decode_vs_fp")
    fp_bytes = foot["fp"]["total_bytes"]
    rows = []
    for mode in ("fp", "int8", "int4"):
        fo = foot[mode]
        row = {"mode": mode,
               "base_mbytes": fo["base_bytes"] / 1e6,
               "total_mbytes": fo["total_bytes"] / 1e6,
               "intensity": flops / fo["total_bytes"],
               "pred_decode_speedup": fp_bytes / fo["total_bytes"],
               "measured_decode_vs_fp": measured.get(mode)}
        rows.append(row)
        meas = (f"{row['measured_decode_vs_fp']:.2f}"
                if row["measured_decode_vs_fp"] else "-")
        emit(f"roofline,quant,{mode},{row['base_mbytes']:.2f},"
             f"{row['total_mbytes']:.2f},{row['intensity']:.1f},"
             f"{row['pred_decode_speedup']:.2f},{meas}")
    return rows


def main(emit=print):
    recs = load_records()
    if not recs:
        emit("roofline,no_dryrun_records_found,run launch/dryrun.py first")
        quant_decode_table(emit)
        return []
    rows = table(recs, emit)
    quant_decode_table(emit)
    return rows


if __name__ == "__main__":
    main()

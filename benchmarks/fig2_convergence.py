"""Paper Fig. 2: perplexity convergence across ranks for the four methods.

Claim: FedSA-LoRA (alpha/r) stagnates at high rank; FedSA-rsLoRA converges but
lags; SFed-LoRA converges fastest and lowest at every rank.
Reduced scale: 4L/128d base, 3 clients IID, ranks {4, 64, 256}.
"""
import time

import numpy as np

from benchmarks.common import METHODS, pretrained_base, run_method

RANKS = (4, 64, 256)
MAIN = ("RoLoRA", "FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA")


def main(rounds: int = 30, emit=print):
    model, base = pretrained_base()
    emit("bench,method,rank,round,loss,ppl")
    results = {}
    for method in MAIN:
        for rank in RANKS:
            t0 = time.monotonic()
            tr = run_method(method, rank=rank, rounds=rounds, model=model,
                            base=base)
            for h in tr.history[:: max(1, rounds // 10)]:
                emit(f"fig2,{method},{rank},{h['round']},{h['loss']:.4f},"
                     f"{np.exp(h['loss']):.3f}")
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            results[(method, rank)] = final
            emit(f"fig2_final,{method},{rank},{rounds},{final:.4f},"
                 f"{np.exp(final):.3f}")
    return results


if __name__ == "__main__":
    main()

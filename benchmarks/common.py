"""Shared benchmark substrate: a small pretrained base model + federated
fine-tuning runs mirroring the paper's experimental axes at CPU scale.

The paper fine-tunes a *pretrained* LLaMA2-7B; at CPU scale we pretrain a
4-layer GQA decoder on the uniform-topic synthetic LM once (cached), then run
each federated LoRA method on topic-specialized clients — same protocol,
reduced scale (DESIGN.md §7).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset, SyntheticLM
from repro.models.api import build_model
from repro.optim.optimizers import apply_updates, make_optimizer

VOCAB = 256
SEQ = 64
CACHE = os.path.join(os.path.dirname(__file__), "_base_cache.npz")


def bench_config(**kw) -> ModelConfig:
    base = dict(name="bench-4l", family="dense", num_layers=4, d_model=128,
                num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
                vocab_size=VOCAB)
    base.update(kw)
    return ModelConfig(**base)


def pretrained_base(steps: int = 800, lr: float = 3e-3, force=False):
    """Full-parameter pretrain on uniform-topic data; cached to disk."""
    cfg = bench_config()
    model = build_model(cfg)
    if os.path.exists(CACHE) and not force:
        return model, load_pytree(CACHE)
    params = model.init(jax.random.key(0))
    lm = SyntheticLM(VOCAB, num_topics=8, seed=0)
    rng = np.random.default_rng(0)
    opt_init, opt_update = make_optimizer(
        OptimizerConfig(name="adamw", lr=lr))
    state = opt_init(params)

    @jax.jit
    def step(params, state, toks):
        (loss, _), g = jax.value_and_grad(
            lambda p: model.loss(p, {"tokens": toks}), has_aux=True)(params)
        upd, state = opt_update(g, state, params)
        return apply_updates(params, upd), state, loss

    for i in range(steps):
        topic = int(rng.integers(0, 8))
        toks = jnp.asarray(lm.sample(rng, topic, 16, SEQ))
        params, state, loss = step(params, state, toks)
        if i % 100 == 0:
            print(f"# pretrain step {i} loss {float(loss):.3f}")
    save_pytree(CACHE, params)
    return model, params


METHODS = {
    # paper baselines (Fig. 2-4): aggregation strategy + scaling factor
    "RoLoRA":        ("rolora", "lora"),
    "FedSA-LoRA":    ("fedsa", "lora"),
    "FedSA-rsLoRA":  ("fedsa", "rslora"),
    "SFed-LoRA":     ("fedsa", "sfedlora"),
    # ablation candidates (Fig. 8)
    "gamma_za":      ("fedsa", "za"),
    "gamma_zb":      ("fedsa", "zb"),
    # extra baselines implemented for completeness
    "FedIT":         ("fedit", "lora"),
    "FFA-LoRA":      ("ffa", "lora"),
    "FLoRA":         ("flora", "lora"),     # stacking aggregation (2409.05976)
}


def run_method(method: str, *, rank: int, clients: int = 3, rounds: int = 30,
               local_steps: int = 5, lr: float = 1.0, alpha: float = 8.0,
               partition: str = "iid", optimizer: str = "sgd", seed: int = 0,
               model=None, base=None, targets=("q", "v"),
               chunk_rounds: int = 0, data_mode: str = "host",
               ranks=None, dirichlet_alpha: float = 0.5,
               weight_by_size: bool = False):
    """One federated fine-tuning run; returns the trainer (history inside).
    With the default ``chunk_rounds=0`` the whole run is one compiled scan.
    ``ranks`` (one per client) switches to the heterogeneous padded-rank
    path with per-client gamma_i; ``weight_by_size`` weights the server
    mean by the dataset's per-client example counts."""
    strategy, scaling = METHODS[method]
    if model is None:
        model, base = pretrained_base()
    # fine-tuning is a NEW task (fresh topic transition tables, seed offset)
    # — the paper fine-tunes a pretrained model on a downstream dataset.
    ds = FederatedDataset(VOCAB, clients, seq_len=SEQ, batch_per_client=4,
                          partition=partition,
                          dirichlet_alpha=dirichlet_alpha, seed=seed + 777)
    tr = FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=rank, ranks=ranks, alpha=alpha,
                            scaling=scaling, targets=targets),
        fed_cfg=FederatedConfig(num_clients=clients, local_steps=local_steps,
                                aggregation=strategy, partition=partition,
                                dirichlet_alpha=dirichlet_alpha,
                                weight_by_size=weight_by_size),
        opt_cfg=OptimizerConfig(name=optimizer, lr=lr),
        seed=seed, base_params=base, chunk_rounds=chunk_rounds,
        data_mode=data_mode)
    tr.run(rounds)
    return tr


def eval_top1(tr, batch: int = 32) -> float:
    """Next-token top-1 accuracy on held-out data (accuracy proxy for the
    paper's GSM8K/GLUE accuracy tables)."""
    toks = jnp.asarray(tr.dataset.eval_batch(batch))
    logits, _ = tr.model.forward(tr.base, {"tokens": toks},
                                 adapters=tr.client_adapters(0))
    pred = jnp.argmax(logits[:, :-1], -1)
    return float((pred == toks[:, 1:]).mean())

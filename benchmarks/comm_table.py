"""Communication-volume table: the paper's selective-upload advantage.

Per-round client->server bytes for each aggregation strategy at several
ranks on llama2-7b-shaped adapters (q,v targets).  FedSA/SFed upload only A —
half of FedIT's volume; this is also visible as all-reduce bytes in the
dry-run's train_4k collective schedule.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import LoRAConfig
from repro.core.aggregation import STRATEGIES, get_strategy
from repro.core.lora import init_lora
from repro.models.api import build_model


HET_RANKS = (8, 64, 512, 512)      # a mixed-rank federation (pad: r_max=512)


def main(emit=print):
    cfg = get_config("llama2-7b")
    model = build_model(cfg)
    emit("bench,strategy,rank,upload_MB_per_client_round")
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    for rank in (8, 64, 512):
        lora1 = init_lora(zeros, jax.random.key(1), LoRAConfig(rank=rank))
        lora_n = jax.tree.map(lambda x: x[None], lora1)
        for strat in STRATEGIES:
            # round 0 accounting (rolora alternates A/B rounds; flora
            # uploads both matrices for the stacked product)
            mb = get_strategy(strat).upload_bytes(lora_n, 0) / 1e6
            emit(f"comm,{strat},{rank},{mb:.2f}")
    # heterogeneous clients: every client allocates the padded r_max but
    # only uploads its own active rank rows/cols — low-rank clients pay a
    # fraction of the padded volume
    r_max = max(HET_RANKS)
    if rank != r_max:        # reuse the homogeneous loop's last tree
        lora1 = init_lora(zeros, jax.random.key(1), LoRAConfig(rank=r_max))
    # accounting only reads per-client shapes — a length-1 client dim
    # suffices (no need to materialize N copies of the r_max adapters)
    lora_n = jax.tree.map(lambda x: x[None], lora1)
    emit("bench,strategy,client,rank,active_upload_MB_per_round")
    for strat in STRATEGIES:
        per = get_strategy(strat).upload_bytes_per_client(
            lora_n, 0, ranks=HET_RANKS)
        for i, (r_i, bts) in enumerate(zip(HET_RANKS, per)):
            emit(f"commhet,{strat},{i},{r_i},{bts / 1e6:.2f}")


if __name__ == "__main__":
    main()

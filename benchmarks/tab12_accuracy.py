"""Paper Tables 1-2: accuracy across ranks (GSM8K LLaMA2+SGD / GLUE
RoBERTa+AdamW).  Proxy: next-token top-1 accuracy on held-out synthetic data.

Two scenarios mirroring the paper's axes:
  tab1: decoder + SGD + IID (paper's GSM8K setup)
  tab2: encoder (MLM loss) + AdamW + Dirichlet(0.5) non-IID (paper's GLUE)
Claim: SFed-LoRA >= baselines at every rank, margin largest at high rank.
"""
import jax
import numpy as np

from benchmarks.common import (bench_config, eval_top1, pretrained_base,
                               run_method)
from repro.models.api import build_model

RANKS = (4, 32, 256)
MAIN = ("RoLoRA", "FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA")


def main(rounds: int = 25, emit=print):
    results = {}
    # --- tab1: decoder + SGD + IID
    model, base = pretrained_base()
    emit("bench,method,rank,top1_acc")
    for method in MAIN:
        for rank in RANKS:
            tr = run_method(method, rank=rank, rounds=rounds, model=model,
                            base=base, optimizer="sgd", partition="iid")
            acc = eval_top1(tr)
            results[("tab1", method, rank)] = acc
            emit(f"tab1,{method},{rank},{acc:.4f}")
    # --- tab2: encoder + AdamW + non-IID  (architecture/optimizer/dist shift)
    enc_cfg = bench_config(name="bench-enc", family="encoder",
                           norm="layernorm", mlp_variant="gelu")
    enc_model = build_model(enc_cfg)
    enc_base = enc_model.init(jax.random.key(7))
    for method in MAIN:
        for rank in (4, 256):
            tr = run_method(method, rank=rank, rounds=rounds, model=enc_model,
                            base=enc_base, optimizer="adamw", lr=3e-3,
                            partition="dirichlet")
            final = np.mean([h["loss"] for h in tr.history[-5:]])
            results[("tab2", method, rank)] = final
            emit(f"tab2,{method},{rank},{final:.4f}")
    return results


if __name__ == "__main__":
    main()

"""Engine throughput: host-loop vs compiled scan-over-rounds (rounds/sec).

The refactored engine (core/federated.py) runs a whole chunk of federated
rounds as one ``lax.scan`` on device.  This bench measures what that buys at
the paper-reduced protocol (local_steps=2, rounds=20) on two model scales:

  micro     1-layer d32 — rounds are cheap, so the per-round host work
            (dispatch, data staging, metric sync) dominates: this is the
            regime the engine exists for (stress-testing large N needs
            cheap rounds) and where the >=2x speedup shows.
  bench4l   the shared 4-layer d128 benchmark model — CPU compute-bound,
            so the ratio approaches 1; included for honesty.

Variants per scale:
  host_loop          chunk_rounds=1 — one dispatch + one host sync per round
                     (the pre-refactor execution shape)
  scan               one chunk for all rounds, host-staged data
  scan_device_data   one chunk, batches synthesized inside the scan (zero
                     host data traffic)

Timing excludes compilation (one full warm-up run per variant); results land
in EXPERIMENTS/bench_engine.json for the BENCH record.
"""
import json
import os
import time

import jax

from benchmarks.common import VOCAB, bench_config
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")

SCALES = {
    "micro": dict(
        cfg=ModelConfig(name="micro", family="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                        d_ff=64, vocab_size=VOCAB),
        clients=2, seq=16, batch=1, rank=4),
    "bench4l": dict(cfg=bench_config(), clients=4, seq=64, batch=4, rank=8),
}

VARIANTS = ("host_loop", "scan", "scan_device_data")


def _make_trainer(model, base, scale, *, local_steps, chunk_rounds,
                  data_mode, seed=0):
    ds = FederatedDataset(VOCAB, scale["clients"], seq_len=scale["seq"],
                          batch_per_client=scale["batch"], seed=seed)
    return FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=scale["rank"], scaling="sfedlora"),
        fed_cfg=FederatedConfig(num_clients=scale["clients"],
                                local_steps=local_steps,
                                aggregation="fedsa"),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05),
        seed=seed, base_params=base, chunk_rounds=chunk_rounds,
        data_mode=data_mode)


def _time_variant(model, base, scale, variant, *, rounds, local_steps):
    chunk = 1 if variant == "host_loop" else rounds
    data_mode = "device" if variant == "scan_device_data" else "host"
    tr = _make_trainer(model, base, scale, local_steps=local_steps,
                       chunk_rounds=chunk, data_mode=data_mode)
    tr.run(rounds)                      # compile + warm-up
    t0 = time.perf_counter()
    tr.run(rounds)                      # same chunk length -> cached
    return rounds / (time.perf_counter() - t0)


def main(rounds: int = 20, local_steps: int = 2, emit=print):
    emit("bench,scale,engine,clients,local_steps,rounds,rounds_per_sec")
    rec = {"bench": "engine", "rounds": rounds, "local_steps": local_steps,
           "scales": {}}
    for sname, scale in SCALES.items():
        model = build_model(scale["cfg"])
        base = model.init(jax.random.key(0))
        rps = {}
        for variant in VARIANTS:
            rps[variant] = _time_variant(model, base, scale, variant,
                                         rounds=rounds,
                                         local_steps=local_steps)
            emit(f"engine,{sname},{variant},{scale['clients']},{local_steps},"
                 f"{rounds},{rps[variant]:.2f}")
        scan_speedup = rps["scan"] / rps["host_loop"]
        engine_speedup = rps["scan_device_data"] / rps["host_loop"]
        emit(f"engine,{sname},scan_vs_host_speedup,{scale['clients']},"
             f"{local_steps},{rounds},{scan_speedup:.2f}")
        emit(f"engine,{sname},scan_device_vs_host_speedup,"
             f"{scale['clients']},{local_steps},{rounds},"
             f"{engine_speedup:.2f}")
        # per-round cost above the fastest variant at this scale.  At the
        # micro scale the fastest is the fully on-device engine and the
        # excess IS host overhead; at compute-bound scales device-side data
        # generation costs device time too, so this stays a neutral
        # "vs fastest" delta rather than claiming to isolate host work.
        floor_ms = 1e3 / max(rps.values())
        excess = {k: round(1e3 / v - floor_ms, 3) for k, v in rps.items()}
        rec["scales"][sname] = {
            "clients": scale["clients"], "rounds_per_sec":
                {k: round(v, 2) for k, v in rps.items()},
            "excess_ms_per_round_vs_fastest": excess,
            "scan_vs_host_speedup": round(scan_speedup, 3),
            "scan_device_vs_host_speedup": round(engine_speedup, 3)}
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_engine.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


if __name__ == "__main__":
    main()

"""Engine throughput: host-loop vs compiled scan-over-rounds (rounds/sec).

The refactored engine (core/federated.py) runs a whole chunk of federated
rounds as one ``lax.scan`` on device.  This bench measures what that buys at
the paper-reduced protocol (local_steps=2, rounds=20) on two model scales:

  micro     1-layer d32 — rounds are cheap, so the per-round host work
            (dispatch, data staging, metric sync) dominates: this is the
            regime the engine exists for (stress-testing large N needs
            cheap rounds) and where the >=2x speedup shows.
  bench4l   the shared 4-layer d128 benchmark model — CPU compute-bound,
            so the ratio approaches 1; included for honesty.

Variants per scale:
  host_loop          chunk_rounds=1 — one dispatch + one host sync per round
                     (the pre-refactor execution shape)
  scan               one chunk for all rounds, host-staged data
  scan_device_data   one chunk, batches synthesized inside the scan (zero
                     host data traffic)

Timing excludes compilation (one full warm-up run per variant); results land
in EXPERIMENTS/bench_engine.json for the BENCH record.

``fault_scenario`` additionally measures the async buffered engine: its
rounds/sec overhead vs the synchronous engine at staleness 0 (the
bit-identical degradation point), and rounds/sec + a short loss trajectory
under deterministic fault injection (dropout sweep with stragglers).  Those
results are merged into the repo-root ``BENCH_engine.json`` so
``python -m benchmarks.run table`` tracks them across PRs.  ``--ci`` floors
the buffered-at-staleness-0 throughput at ``CI_FLOOR``x synchronous.
"""
import argparse
import json
import os
import time

import jax

from benchmarks.common import VOCAB, bench_config
from repro.configs.base import (FederatedConfig, LoRAConfig, ModelConfig,
                                OptimizerConfig)
from repro.core.faults import FaultConfig
from repro.core.federated import FederatedTrainer
from repro.data.synthetic import FederatedDataset
from repro.models.api import build_model

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# buffered engine at staleness 0 must stay within 10% of the sync engine
CI_FLOOR = 0.9

SCALES = {
    "micro": dict(
        cfg=ModelConfig(name="micro", family="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                        d_ff=64, vocab_size=VOCAB),
        clients=2, seq=16, batch=1, rank=4),
    "bench4l": dict(cfg=bench_config(), clients=4, seq=64, batch=4, rank=8),
}

VARIANTS = ("host_loop", "scan", "scan_device_data")


def _make_trainer(model, base, scale, *, local_steps, chunk_rounds,
                  data_mode, seed=0, **fed_kw):
    ds = FederatedDataset(VOCAB, scale["clients"], seq_len=scale["seq"],
                          batch_per_client=scale["batch"], seed=seed)
    return FederatedTrainer(
        model, ds,
        lora_cfg=LoRAConfig(rank=scale["rank"], scaling="sfedlora"),
        fed_cfg=FederatedConfig(num_clients=scale["clients"],
                                local_steps=local_steps,
                                aggregation="fedsa", **fed_kw),
        opt_cfg=OptimizerConfig(name="sgd", lr=0.05),
        seed=seed, base_params=base, chunk_rounds=chunk_rounds,
        data_mode=data_mode)


def _time_variant(model, base, scale, variant, *, rounds, local_steps):
    chunk = 1 if variant == "host_loop" else rounds
    data_mode = "device" if variant == "scan_device_data" else "host"
    tr = _make_trainer(model, base, scale, local_steps=local_steps,
                       chunk_rounds=chunk, data_mode=data_mode)
    tr.run(rounds)                      # compile + warm-up
    t0 = time.perf_counter()
    tr.run(rounds)                      # same chunk length -> cached
    return rounds / (time.perf_counter() - t0)


def _merge_root(update):
    """Merge *update* into the committed repo-root BENCH_engine.json.

    The trajectory table (benchmarks/run.py) walks git history of the
    repo-root snapshots, so sections written by different entry points
    (scales from main(), fault_scenario from the chaos bench) must not
    clobber each other.
    """
    path = os.path.join(ROOT, "BENCH_engine.json")
    try:
        with open(path) as f:
            full = json.load(f)
    except (OSError, ValueError):
        full = {}
    full.update(update)
    with open(path, "w") as f:
        json.dump(full, f, indent=1)


def fault_scenario(rounds: int = 4, local_steps: int = 2, emit=print,
                   ci: bool = False):
    """Async buffered engine under deterministic faults (micro scale, N=8).

    Two measurements:
      overhead   sync vs buffered-at-staleness-0 rounds/sec — the buffered
                 wrapper at zero faults degrades bit-identically to the
                 synchronous engine, so any gap here is pure bookkeeping
                 cost (staleness counters, screening masks, cumsum cap).
      sweep      dropout in {0, 0.1, 0.3} with straggler rate 0.3 —
                 rounds/sec plus the per-round loss trajectory, showing
                 convergence holds as delivered updates shrink.

    Sweep rounds/sec includes the chunk-boundary recompiles the
    staleness-corrected gamma fold causes whenever rho moves to a new
    quantized value (bounded at ~100 executables by _quantize_rho); at
    this micro scale those compiles dominate, so sweep numbers measure
    fault-mode worst case, not steady state — compare sweep points to
    each other, not to the fault-free rows.

    ``ci=True`` asserts the staleness-0 ratio >= CI_FLOOR.
    """
    scale = dict(SCALES["micro"], clients=8)
    model = build_model(scale["cfg"])
    base = model.init(jax.random.key(0))

    def measure(**fed_kw):
        tr = _make_trainer(model, base, scale, local_steps=local_steps,
                           chunk_rounds=rounds, data_mode="host", **fed_kw)
        tr.run(rounds)                  # compile + warm-up; fresh-run losses
        traj = {f"r{i + 1}": round(float(h["loss"]), 4)
                for i, h in enumerate(tr.history[:rounds])}
        best = float("inf")
        for _ in range(3):              # best-of-3: same chunk -> cached
            t0 = time.perf_counter()
            tr.run(rounds)
            best = min(best, time.perf_counter() - t0)
        return rounds / best, traj, tr

    emit("bench,scenario,variant,clients,rounds,rounds_per_sec,final_loss")
    n = scale["clients"]
    sync_rps, sync_traj, _ = measure()
    buf_rps, buf_traj, _ = measure(buffer_size=0)
    ratio = buf_rps / sync_rps
    emit(f"engine,fault_scenario,sync,{n},{rounds},{sync_rps:.2f},"
         f"{sync_traj[f'r{rounds}']}")
    emit(f"engine,fault_scenario,buffered_staleness0,{n},{rounds},"
         f"{buf_rps:.2f},{buf_traj[f'r{rounds}']}")
    emit(f"engine,fault_scenario,buffered_vs_sync,{n},{rounds},"
         f"{ratio:.3f},")
    assert buf_traj == sync_traj, (
        "buffered engine at staleness 0 diverged from sync losses")

    rec = {"clients": n, "rounds": rounds, "local_steps": local_steps,
           "sync_rounds_per_sec": round(sync_rps, 2),
           "buffered_staleness0_rounds_per_sec": round(buf_rps, 2),
           "buffered_vs_sync": round(ratio, 3), "sweep": {}}
    for p in (0.0, 0.1, 0.3):
        faults = FaultConfig(dropout=p, straggle=0.3, seed=1)
        rps, traj, tr = measure(buffer_size=0, faults=faults)
        last = tr.history[rounds - 1]
        key = f"dropout_{int(round(p * 100)):02d}"
        rec["sweep"][key] = {
            "rounds_per_sec": round(rps, 2), "loss": traj,
            "n_eff": round(float(last["n_eff"]), 3),
            "delivered": float(last["delivered"]),
            "gamma_eff": round(float(tr.gamma_eff), 4)}
        emit(f"engine,fault_scenario,{key}+straggle30,{n},{rounds},"
             f"{rps:.2f},{traj[f'r{rounds}']}")
    _merge_root({"fault_scenario": rec})
    emit("# merged fault_scenario into BENCH_engine.json")
    if ci:
        assert ratio >= CI_FLOOR, (
            f"buffered engine at staleness 0 is {ratio:.3f}x sync "
            f"(floor {CI_FLOOR}x)")
        emit(f"# CI floor ok: buffered/sync {ratio:.3f} >= {CI_FLOOR}")
    return rec


def main(rounds: int = 20, local_steps: int = 2, emit=print):
    emit("bench,scale,engine,clients,local_steps,rounds,rounds_per_sec")
    rec = {"bench": "engine", "rounds": rounds, "local_steps": local_steps,
           "scales": {}}
    for sname, scale in SCALES.items():
        model = build_model(scale["cfg"])
        base = model.init(jax.random.key(0))
        rps = {}
        for variant in VARIANTS:
            rps[variant] = _time_variant(model, base, scale, variant,
                                         rounds=rounds,
                                         local_steps=local_steps)
            emit(f"engine,{sname},{variant},{scale['clients']},{local_steps},"
                 f"{rounds},{rps[variant]:.2f}")
        scan_speedup = rps["scan"] / rps["host_loop"]
        engine_speedup = rps["scan_device_data"] / rps["host_loop"]
        emit(f"engine,{sname},scan_vs_host_speedup,{scale['clients']},"
             f"{local_steps},{rounds},{scan_speedup:.2f}")
        emit(f"engine,{sname},scan_device_vs_host_speedup,"
             f"{scale['clients']},{local_steps},{rounds},"
             f"{engine_speedup:.2f}")
        # per-round cost above the fastest variant at this scale.  At the
        # micro scale the fastest is the fully on-device engine and the
        # excess IS host overhead; at compute-bound scales device-side data
        # generation costs device time too, so this stays a neutral
        # "vs fastest" delta rather than claiming to isolate host work.
        floor_ms = 1e3 / max(rps.values())
        excess = {k: round(1e3 / v - floor_ms, 3) for k, v in rps.items()}
        rec["scales"][sname] = {
            "clients": scale["clients"], "rounds_per_sec":
                {k: round(v, 2) for k, v in rps.items()},
            "excess_ms_per_round_vs_fastest": excess,
            "scan_vs_host_speedup": round(scan_speedup, 3),
            "scan_device_vs_host_speedup": round(engine_speedup, 3)}
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "bench_engine.json"), "w") as f:
        json.dump(rec, f, indent=1)
    _merge_root({"bench": "engine", "scales": rec["scales"]})
    fault_scenario(local_steps=local_steps, emit=emit)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--ci", action="store_true",
                    help="run only fault_scenario and enforce the "
                         f"{CI_FLOOR}x buffered-vs-sync throughput floor")
    args = ap.parse_args()
    if args.ci:
        fault_scenario(ci=True)
    else:
        main(rounds=args.rounds)

"""Paper Fig. 8 / App. B.3: alternative scaling factors at extreme rank.

Candidates: gamma_za = 1/sqrt(N r)  (smaller than optimal -> slow),
gamma_zb = N^2/sqrt(r)              (larger -> explodes early),
vs FedSA-LoRA (alpha/r), FedSA-rsLoRA (alpha/sqrt r), SFed-LoRA (alpha sqrt(N/r)).

Claim: sfedlora converges fastest/lowest; zb is unstable early; za and rslora
converge slowly; alpha/r stagnates.  Reduced scale: rank 512, N=6.
"""
import numpy as np

from benchmarks.common import pretrained_base, run_method

METHODS_ABL = ("FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA", "gamma_za",
               "gamma_zb")
RANK = 512


def main(rounds: int = 25, emit=print):
    model, base = pretrained_base()
    emit("bench,method,rank,round,loss")
    results = {}
    for method in METHODS_ABL:
        tr = run_method(method, rank=RANK, clients=6, rounds=rounds,
                        model=model, base=base)
        losses = [h["loss"] for h in tr.history]
        for h in tr.history[:: max(1, rounds // 8)]:
            emit(f"fig8,{method},{RANK},{h['round']},{h['loss']:.4f}")
        results[method] = {"final": float(np.mean(losses[-5:])),
                           "peak": float(np.max(losses)),
                           "first": float(losses[0])}
        emit(f"fig8_final,{method},{RANK},final={results[method]['final']:.4f},"
             f"peak={results[method]['peak']:.4f}")
    return results


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks: us_per_call for the Pallas kernels vs their jnp
references.  NOTE: on this CPU container the kernels run in interpret mode
(Python emulation), so absolute Pallas numbers are NOT hardware-representative
— the jnp reference timing and the derived FLOP counts are the meaningful
columns; on a real TPU the same harness times the Mosaic kernels.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import flash_mha, fused_lora_matmul, rglru_scan_op


def timeit(fn, *args, iters: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(emit=print):
    emit("bench,name,us_per_call,derived")
    key = jax.random.key(0)

    # lora_matmul: (m,k,n,r) = (1024, 1024, 1024, 64)
    m, k, n, r = 1024, 1024, 1024, 64
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32)
    a = jax.random.normal(ks[2], (r, k), jnp.float32) * 0.02
    b = jax.random.normal(ks[3], (n, r), jnp.float32) * 0.02
    flops = 2 * m * k * n + 2 * m * k * r + 2 * m * r * n
    ref_fn = jax.jit(lambda *t: ref.lora_matmul_ref(*t, 2.0))
    us = timeit(ref_fn, x, w, a, b)
    emit(f"kernels,lora_matmul_ref_jnp,{us:.1f},gflops={flops/us/1e3:.2f}")
    us = timeit(lambda *t: fused_lora_matmul(*t, 2.0), x, w, a, b)
    emit(f"kernels,lora_matmul_pallas_interp,{us:.1f},flops={flops}")

    # lora_matmul backward: fused custom-VJP kernels vs jnp autodiff.
    # dx mirrors the forward's three GEMMs (2mnk + 2mnr + 2mrk); dA and dB
    # add one rank-r reduction each (2mrk and 2mnr) — dW is dead-code-
    # eliminated: LoRA training never differentiates the base weights.
    from repro.kernels.dispatch import fused_lora_apply
    bwd_flops = 2 * m * n * k + 4 * m * n * r + 4 * m * r * k
    ref_grad = jax.jit(jax.grad(
        lambda x_, a_, b_: ref.lora_matmul_ref(x_, w, a_, b_, 2.0).sum(),
        argnums=(0, 1, 2)))
    us = timeit(ref_grad, x, a, b)
    emit(f"kernels,lora_matmul_bwd_ref_jnp,{us:.1f},gflops={bwd_flops/us/1e3:.2f}")
    fused_grad = jax.jit(jax.grad(
        lambda x_, a_, b_: fused_lora_apply(x_, w, a_, b_, 2.0,
                                            interpret=True).sum(),
        argnums=(0, 1, 2)))
    us = timeit(fused_grad, x, a, b)
    emit(f"kernels,lora_matmul_bwd_pallas_interp,{us:.1f},flops={bwd_flops}")

    # batched bank kernel (BGMV): the multi-tenant serving delta — per
    # request row, the shared base GEMM fused with that row's rank-r delta
    # gathered from the stacked bank by id inside the kernel.
    from repro.kernels.bgmv import bgmv_gemv, bgmv_matmul, bgmv_reference
    B, s, K = 8, 32, 8
    ks2 = jax.random.split(jax.random.key(1), 5)
    xb = jax.random.normal(ks2[0], (B, s, k), jnp.float32)
    ab = jax.random.normal(ks2[1], (K, r, k), jnp.float32) * 0.02
    bb = jax.random.normal(ks2[2], (K, n, r), jnp.float32) * 0.02
    ids = jnp.arange(B, dtype=jnp.int32) % K
    flops = B * s * (2 * k * n + 2 * k * r + 2 * r * n)
    ref_fn = jax.jit(bgmv_reference)
    us = timeit(ref_fn, xb, w, ab, bb, ids)
    emit(f"kernels,bgmv_matmul_ref_einsum,{us:.1f},gflops={flops/us/1e3:.2f}")
    us = timeit(lambda *t: bgmv_matmul(*t, interpret=True), xb, w, ab, bb,
                ids)
    emit(f"kernels,bgmv_matmul_pallas_interp,{us:.1f},flops={flops}")
    # decode shape: one token per request (the GEMV-form kernel)
    x1 = xb[:, :1]
    flops1 = B * (2 * k * n + 2 * k * r + 2 * r * n)
    us = timeit(ref_fn, x1, w, ab, bb, ids)
    emit(f"kernels,bgmv_gemv_ref_einsum,{us:.1f},gflops={flops1/us/1e3:.2f}")
    us = timeit(lambda x_, *t: bgmv_gemv(x_[:, 0], *t, interpret=True), x1,
                w, ab, bb, ids)
    emit(f"kernels,bgmv_gemv_pallas_interp,{us:.1f},flops={flops1}")

    # flash attention: b=1, s=1024, h=4, d=64
    bq, s, h, d = 1, 1024, 4, 64
    q = jax.random.normal(ks[0], (bq, s, h, d), jnp.float32)
    kk = jax.random.normal(ks[1], (bq, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (bq, s, h, d), jnp.float32)
    flops = 4 * bq * h * s * s * d
    ref_fn = jax.jit(lambda *t: ref.flash_attention_ref(*t, causal=True))
    us = timeit(ref_fn, q, kk, v)
    emit(f"kernels,flash_attention_ref_jnp,{us:.1f},gflops={flops/us/1e3:.2f}")
    us = timeit(lambda *t: flash_mha(*t, causal=True), q, kk, v)
    emit(f"kernels,flash_attention_pallas_interp,{us:.1f},flops={flops}")

    # flash attention, GQA serving shape: 8 query heads sharing 2 KV heads
    # (the wrapper's KV expansion) — the decode-cache-heavy config
    hq, hkv = 8, 2
    qg = jax.random.normal(ks[0], (bq, s, hq, d), jnp.float32)
    kg = jax.random.normal(ks[1], (bq, s, hkv, d), jnp.float32)
    vg = jax.random.normal(ks[2], (bq, s, hkv, d), jnp.float32)
    flops = 4 * bq * hq * s * s * d
    ref_gqa = jax.jit(lambda q_, k_, v_: ref.flash_attention_ref(
        q_, jnp.repeat(k_, hq // hkv, axis=2),
        jnp.repeat(v_, hq // hkv, axis=2), causal=True))
    us = timeit(ref_gqa, qg, kg, vg)
    emit(f"kernels,flash_attention_gqa_ref_jnp,{us:.1f},"
         f"gflops={flops/us/1e3:.2f}")
    us = timeit(lambda *t: flash_mha(*t, causal=True), qg, kg, vg)
    emit(f"kernels,flash_attention_gqa_pallas_interp,{us:.1f},flops={flops}")

    # rglru scan: (bt, s, d) = (4, 2048, 256)
    bt, s, d = 4, 2048, 256
    a_ = jax.random.uniform(ks[0], (bt, s, d), jnp.float32, 0.8, 0.999)
    b_ = jax.random.normal(ks[1], (bt, s, d), jnp.float32)
    from repro.models.rglru import rglru_scan as assoc_scan
    ref_fn = jax.jit(assoc_scan)
    us = timeit(ref_fn, a_, b_)
    bytes_moved = 3 * bt * s * d * 4
    emit(f"kernels,rglru_assoc_scan_jnp,{us:.1f},gb_s={bytes_moved/us/1e3:.2f}")
    us = timeit(rglru_scan_op, a_, b_)
    emit(f"kernels,rglru_scan_pallas_interp,{us:.1f},bytes={bytes_moved}")


if __name__ == "__main__":
    main()

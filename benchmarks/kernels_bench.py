"""Kernel microbenchmarks: us_per_call for the Pallas kernels vs their jnp
references.  NOTE: on this CPU container the kernels run in interpret mode
(Python emulation), so absolute Pallas numbers are NOT hardware-representative
— the jnp reference timing and the derived FLOP counts are the meaningful
columns; on a real TPU the same harness times the Mosaic kernels.

Every row is REGISTERED first and the whole set is warmed before any timing
begins: a shape that first compiles inside a timed region poisons not just
its own row but (via allocator/compile-thread pressure) its neighbors' —
the engine-bench lesson, applied here so later-added rows can't regress the
harness.  Results land in EXPERIMENTS/bench_kernels.json AND the repo-root
BENCH_kernels.json (committed, so ``benchmarks/run.py table`` has a
cross-PR kernel trajectory).
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.analysis.sanitizers import RecompileGuard
from repro.core.quant import dequantize, quantize
from repro.kernels import ref
from repro.kernels.bgmv import (bgmv_gemv, bgmv_gemv_quant, bgmv_matmul,
                                bgmv_matmul_quant, bgmv_reference)
from repro.kernels.dispatch import fused_lora_apply
from repro.kernels.lora_matmul import lora_matmul_quant_vjp
from repro.kernels.ops import flash_mha, fused_lora_matmul, rglru_scan_op

OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def timeit(fn, *args, iters: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(emit=print):
    key = jax.random.key(0)
    rows = []

    def add(name, fn, args, derived):
        """derived: callable us -> trailing CSV field (flop counts are
        static strings; achieved-rate fields need the measured time)."""
        rows.append((name, fn, args, derived))

    # lora_matmul: (m,k,n,r) = (1024, 1024, 1024, 64)
    m, k, n, r = 1024, 1024, 1024, 64
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32)
    a = jax.random.normal(ks[2], (r, k), jnp.float32) * 0.02
    b = jax.random.normal(ks[3], (n, r), jnp.float32) * 0.02
    flops = 2 * m * k * n + 2 * m * k * r + 2 * m * r * n
    add("lora_matmul_ref_jnp",
        jax.jit(lambda *t: ref.lora_matmul_ref(*t, 2.0)), (x, w, a, b),
        lambda us, f=flops: f"gflops={f/us/1e3:.2f}")
    add("lora_matmul_pallas_interp",
        lambda *t: fused_lora_matmul(*t, 2.0), (x, w, a, b),
        lambda us, f=flops: f"flops={f}")

    # quantized base variants: the fused kernels DMA the packed int tiles +
    # scales and dequantize in VMEM; the reference tier dequantizes the
    # whole weight up front (the parity-bounds policy).  The derived field
    # records the base-weight bytes each path moves from HBM.
    # one jitted dequant-reference shared by both widths: the packed tree is
    # a pytree argument, so int8/int4 land as two cache entries of a single
    # wrapper (an inline jit per loop iteration would rebuild the cache)
    dequant_ref = jax.jit(lambda x_, a_, b_, q_: ref.lora_matmul_ref(
        x_, dequantize(q_), a_, b_, 2.0))
    for bits, mode in ((8, "int8"), (4, "int4")):
        q = quantize(w, bits=bits)
        wbytes = q.nbytes
        add(f"lora_matmul_{mode}_ref_dequant",
            lambda x_, a_, b_, q=q: dequant_ref(x_, a_, b_, q), (x, a, b),
            lambda us, f=flops: f"gflops={f/us/1e3:.2f}")
        add(f"lora_matmul_{mode}_pallas_interp",
            lambda x_, a_, b_, q=q, bits=bits: lora_matmul_quant_vjp(
                x_, q.data, q.scales, a_, b_, 2.0, bits=bits,
                interpret=True), (x, a, b),
            lambda us, wb=wbytes: f"w_bytes={wb}_vs_fp={w.nbytes}")

    # lora_matmul backward: fused custom-VJP kernels vs jnp autodiff.
    # dx mirrors the forward's three GEMMs (2mnk + 2mnr + 2mrk); dA and dB
    # add one rank-r reduction each (2mrk and 2mnr) — dW is dead-code-
    # eliminated: LoRA training never differentiates the base weights.
    bwd_flops = 2 * m * n * k + 4 * m * n * r + 4 * m * r * k
    add("lora_matmul_bwd_ref_jnp",
        jax.jit(jax.grad(
            lambda x_, a_, b_: ref.lora_matmul_ref(x_, w, a_, b_, 2.0).sum(),
            argnums=(0, 1, 2))), (x, a, b),
        lambda us, f=bwd_flops: f"gflops={f/us/1e3:.2f}")
    add("lora_matmul_bwd_pallas_interp",
        jax.jit(jax.grad(
            lambda x_, a_, b_: fused_lora_apply(x_, w, a_, b_, 2.0,
                                                interpret=True).sum(),
            argnums=(0, 1, 2))), (x, a, b),
        lambda us, f=bwd_flops: f"flops={f}")

    # batched bank kernel (BGMV): the multi-tenant serving delta — per
    # request row, the shared base GEMM fused with that row's rank-r delta
    # gathered from the stacked bank by id inside the kernel.
    B, s, K = 8, 32, 8
    ks2 = jax.random.split(jax.random.key(1), 5)
    xb = jax.random.normal(ks2[0], (B, s, k), jnp.float32)
    ab = jax.random.normal(ks2[1], (K, r, k), jnp.float32) * 0.02
    bb = jax.random.normal(ks2[2], (K, n, r), jnp.float32) * 0.02
    ids = jnp.arange(B, dtype=jnp.int32) % K
    bflops = B * s * (2 * k * n + 2 * k * r + 2 * r * n)
    bgmv_ref = jax.jit(bgmv_reference)
    add("bgmv_matmul_ref_einsum", bgmv_ref, (xb, w, ab, bb, ids),
        lambda us, f=bflops: f"gflops={f/us/1e3:.2f}")
    add("bgmv_matmul_pallas_interp",
        lambda *t: bgmv_matmul(*t, interpret=True), (xb, w, ab, bb, ids),
        lambda us, f=bflops: f"flops={f}")
    # decode shape: one token per request (the GEMV-form kernel)
    x1 = xb[:, :1]
    flops1 = B * (2 * k * n + 2 * k * r + 2 * r * n)
    add("bgmv_gemv_ref_einsum", bgmv_ref, (x1, w, ab, bb, ids),
        lambda us, f=flops1: f"gflops={f/us/1e3:.2f}")
    add("bgmv_gemv_pallas_interp",
        lambda x_, *t: bgmv_gemv(x_[:, 0], *t, interpret=True),
        (x1, w, ab, bb, ids), lambda us, f=flops1: f"flops={f}")
    # quantized-base BGMV (decode is where packed bytes pay: the base GEMM
    # is the bandwidth term at batch-1 token shapes)
    for bits, mode in ((8, "int8"), (4, "int4")):
        q = quantize(w, bits=bits)
        add(f"bgmv_matmul_{mode}_pallas_interp",
            lambda x_, a_, b_, i_, q=q, bits=bits: bgmv_matmul_quant(
                x_, q.data, q.scales, a_, b_, i_, bits=bits,
                interpret=True), (xb, ab, bb, ids),
            lambda us, wb=q.nbytes: f"w_bytes={wb}_vs_fp={w.nbytes}")
        add(f"bgmv_gemv_{mode}_pallas_interp",
            lambda x_, a_, b_, i_, q=q, bits=bits: bgmv_gemv_quant(
                x_[:, 0], q.data, q.scales, a_, b_, i_, bits=bits,
                interpret=True), (x1, ab, bb, ids),
            lambda us, wb=q.nbytes: f"w_bytes={wb}_vs_fp={w.nbytes}")

    # flash attention: b=1, s=1024, h=4, d=64
    bq, sq, h, d = 1, 1024, 4, 64
    q_ = jax.random.normal(ks[0], (bq, sq, h, d), jnp.float32)
    kk = jax.random.normal(ks[1], (bq, sq, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (bq, sq, h, d), jnp.float32)
    aflops = 4 * bq * h * sq * sq * d
    add("flash_attention_ref_jnp",
        jax.jit(lambda *t: ref.flash_attention_ref(*t, causal=True)),
        (q_, kk, v), lambda us, f=aflops: f"gflops={f/us/1e3:.2f}")
    add("flash_attention_pallas_interp",
        lambda *t: flash_mha(*t, causal=True), (q_, kk, v),
        lambda us, f=aflops: f"flops={f}")

    # flash attention, GQA serving shape: 8 query heads sharing 2 KV heads
    # (the wrapper's KV expansion) — the decode-cache-heavy config
    hq, hkv = 8, 2
    qg = jax.random.normal(ks[0], (bq, sq, hq, d), jnp.float32)
    kg = jax.random.normal(ks[1], (bq, sq, hkv, d), jnp.float32)
    vg = jax.random.normal(ks[2], (bq, sq, hkv, d), jnp.float32)
    gflops = 4 * bq * hq * sq * sq * d
    add("flash_attention_gqa_ref_jnp",
        jax.jit(lambda q2, k2, v2: ref.flash_attention_ref(
            q2, jnp.repeat(k2, hq // hkv, axis=2),
            jnp.repeat(v2, hq // hkv, axis=2), causal=True)), (qg, kg, vg),
        lambda us, f=gflops: f"gflops={f/us/1e3:.2f}")
    add("flash_attention_gqa_pallas_interp",
        lambda *t: flash_mha(*t, causal=True), (qg, kg, vg),
        lambda us, f=gflops: f"flops={f}")

    # rglru scan: (bt, s, d) = (4, 2048, 256)
    bt, sr, dr = 4, 2048, 256
    a_ = jax.random.uniform(ks[0], (bt, sr, dr), jnp.float32, 0.8, 0.999)
    b_ = jax.random.normal(ks[1], (bt, sr, dr), jnp.float32)
    from repro.models.rglru import rglru_scan as assoc_scan
    bytes_moved = 3 * bt * sr * dr * 4
    add("rglru_assoc_scan_jnp", jax.jit(assoc_scan), (a_, b_),
        lambda us, bm_=bytes_moved: f"gb_s={bm_/us/1e3:.2f}")
    add("rglru_scan_pallas_interp", rglru_scan_op, (a_, b_),
        lambda us, bm_=bytes_moved: f"bytes={bm_}")

    # ---- warm EVERY registered shape before ANY timing: compiles (and
    # interpret-mode tracing) never land inside a timed region
    for _, fn, args, _ in rows:
        jax.block_until_ready(fn(*args))

    # recompile sanitizer: each row's executable cache is snapshotted after
    # the warm pass; growth during the timed loop means a shape was
    # compiling on the clock — fail loudly instead of reporting it as slow
    guard = RecompileGuard()
    for name, fn, _, _ in rows:
        guard.watch(name, fn)

    emit("bench,name,us_per_call,derived")
    results = {}
    for name, fn, args, derived in rows:
        us = timeit(fn, *args)
        results[name] = {"us_per_call": round(us, 1)}
        emit(f"kernels,{name},{us:.1f},{derived(us)}")
    guard.check()

    os.makedirs(OUT, exist_ok=True)
    for path in (os.path.join(OUT, "bench_kernels.json"),
                 os.path.join(ROOT, "BENCH_kernels.json")):
        with open(path, "w") as f:
            json.dump(results, f, indent=2)
    emit("# wrote EXPERIMENTS/bench_kernels.json + BENCH_kernels.json")
    return results


if __name__ == "__main__":
    main()

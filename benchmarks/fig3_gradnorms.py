"""Paper Fig. 3 / App. B.1: average parameter gradient norm across ranks.

Claim: with alpha/r the gradient norm collapses exponentially in r (orders of
magnitude between r=4 and r=512); alpha/sqrt(r) narrows but does not close the
gap; SFed-LoRA's sqrt(N/r) keeps norms tightly clustered across ranks.

Metric: mean grad norm over rounds; 'spread' = norm(r_min)/norm(r_max) —
near 1.0 means rank-invariant gradients (the paper's stability claim).
"""
import numpy as np

from benchmarks.common import pretrained_base, run_method

RANKS = (4, 32, 256)
MAIN = ("FedSA-LoRA", "FedSA-rsLoRA", "SFed-LoRA", "RoLoRA")


def main(rounds: int = 20, emit=print):
    model, base = pretrained_base()
    emit("bench,method,rank,mean_grad_norm,final_loss")
    norms = {}
    for method in MAIN:
        for rank in RANKS:
            tr = run_method(method, rank=rank, rounds=rounds, model=model,
                            base=base)
            g = np.mean([h["grad_norm"] for h in tr.history])
            norms[(method, rank)] = g
            emit(f"fig3,{method},{rank},{g:.4e},"
                 f"{tr.history[-1]['loss']:.4f}")
    emit("bench,method,spread_rmin_over_rmax")
    spreads = {}
    for method in MAIN:
        spread = norms[(method, RANKS[0])] / max(norms[(method, RANKS[-1])],
                                                 1e-12)
        spreads[method] = spread
        emit(f"fig3_spread,{method},{spread:.2f}")
    return norms, spreads


if __name__ == "__main__":
    main()

"""Benchmark orchestrator — one module per paper table/figure.

``python -m benchmarks.run``           runs everything (CSV to stdout)
``python -m benchmarks.run fig2 fig8`` runs a subset
``FAST=1``                             shortens training benches
"""
import os
import sys
import time

SUITES = ("comm", "kernels", "engine", "serve", "roofline", "fig9", "fig3",
          "fig2", "fig4", "fig8", "tab12")


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")] or list(SUITES)
    fast = os.environ.get("FAST", "0") not in ("0", "")
    rounds = 10 if fast else None

    def run(name, fn, **kw):
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn(**kw)
        except Exception as e:  # keep the suite alive
            import traceback
            print(f"{name},ERROR,{e}")
            traceback.print_exc()
        print(f"# === {name} done in {time.time()-t0:.1f}s ===", flush=True)

    if "comm" in want:
        from benchmarks import comm_table
        run("comm_table", comm_table.main)
    if "kernels" in want:
        from benchmarks import kernels_bench
        run("kernels_bench", kernels_bench.main)
    if "engine" in want:
        from benchmarks import engine_bench
        run("engine_bench", engine_bench.main,
            **({"rounds": rounds} if rounds else {}))
    if "serve" in want:
        from benchmarks import serve_bench
        run("serve_bench", serve_bench.main,
            **({"steps": 8} if fast else {}))
    if "roofline" in want:
        from benchmarks import roofline
        run("roofline", roofline.main)
    if "fig9" in want:
        from benchmarks import fig9_activations
        run("fig9_activations", fig9_activations.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig3" in want:
        from benchmarks import fig3_gradnorms
        run("fig3_gradnorms", fig3_gradnorms.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig2" in want:
        from benchmarks import fig2_convergence
        run("fig2_convergence", fig2_convergence.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig4" in want:
        from benchmarks import fig4_clients
        run("fig4_clients", fig4_clients.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig8" in want:
        from benchmarks import fig8_scaling_ablation
        run("fig8_scaling_ablation", fig8_scaling_ablation.main,
            **({"rounds": rounds} if rounds else {}))
    if "tab12" in want:
        from benchmarks import tab12_accuracy
        run("tab12_accuracy", tab12_accuracy.main,
            **({"rounds": rounds} if rounds else {}))


if __name__ == "__main__":
    main()

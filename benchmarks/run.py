"""Benchmark orchestrator — one module per paper table/figure.

``python -m benchmarks.run``           runs everything (CSV to stdout)
``python -m benchmarks.run fig2 fig8`` runs a subset
``python -m benchmarks.run table``     cross-PR trajectory of BENCH_*.json
``FAST=1``                             shortens training benches
"""
import glob
import json
import os
import subprocess
import sys
import time

SUITES = ("comm", "kernels", "engine", "serve", "roofline", "fig9", "fig3",
          "fig2", "fig4", "fig8", "tab12", "table")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _flatten(obj, prefix=""):
    """Dotted-path numeric scalars of a nested benchmark dict."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix.rstrip(".")] = obj
    return out


def _git(*args):
    """Run git in the repo root; returns stdout or None on any failure."""
    try:
        proc = subprocess.run(["git", *args], cwd=ROOT, capture_output=True,
                              text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return None
    return proc.stdout if proc.returncode == 0 else None


def _warn_row(name, rev, why):
    """One ``__warning__`` CSV row; commas/newlines sanitized out of *why*."""
    why = str(why).replace(",", ";").replace("\n", " ")
    print(f"trajectory,{name},{rev},__warning__,{why}")


def trajectory() -> None:
    """Cross-PR trajectory table aggregated from repo-root ``BENCH_*.json``.

    Each benchmark run that lands in a PR rewrites its ``BENCH_<suite>.json``
    at the repo root, so git history holds one snapshot per PR.  This walks
    every committed revision of every ``BENCH_*.json`` (oldest first), adds
    the current working tree, flattens each snapshot to dotted scalar
    metrics, and prints one CSV row per metric:

        trajectory,<file>,<rev>,<metric>,<value>

    A historical revision that cannot be read (file renamed since, blob
    missing) or parsed (malformed snapshot from an old commit) emits a
    ``__warning__`` row instead of aborting the aggregation — the rest of
    the trajectory still prints.  A missing git repo degrades to the
    working-tree snapshot alone.
    """
    print("trajectory,file,rev,metric,value")
    for path in sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json"))):
        name = os.path.basename(path)
        snapshots = []
        revs = (_git("log", "--reverse", "--format=%h", "--", name) or "").split()
        for rev in revs:
            blob = _git("show", f"{rev}:{name}")
            if blob is None:
                _warn_row(name, rev, "unreadable: git show failed "
                                     "(renamed or missing at this revision)")
                continue
            try:
                snapshots.append((rev, json.loads(blob)))
            except ValueError as e:
                _warn_row(name, rev, f"malformed JSON: {e}")
                continue
        try:
            with open(path) as f:
                worktree = json.load(f)
        except (OSError, ValueError) as e:
            _warn_row(name, "worktree", f"unreadable working-tree file: {e}")
            worktree = None
        if worktree is not None:
            if snapshots and snapshots[-1][1] == worktree:
                pass  # tree matches HEAD's snapshot; don't duplicate the row
            else:
                snapshots.append(("worktree", worktree))
        for rev, snap in snapshots:
            try:
                metrics = sorted(_flatten(snap).items())
            except Exception as e:  # a snapshot no current _flatten handles
                _warn_row(name, rev, f"unflattenable snapshot: {e}")
                continue
            if not metrics:
                _warn_row(name, rev, "no numeric metrics in snapshot")
                continue
            for metric, value in metrics:
                print(f"trajectory,{name},{rev},{metric},{value:g}")


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")] or list(SUITES)
    fast = os.environ.get("FAST", "0") not in ("0", "")
    rounds = 10 if fast else None

    def run(name, fn, **kw):
        t0 = time.monotonic()
        print(f"# === {name} ===", flush=True)
        try:
            fn(**kw)
        except Exception as e:  # keep the suite alive
            import traceback
            print(f"{name},ERROR,{e}")
            traceback.print_exc()
        print(f"# === {name} done in {time.monotonic()-t0:.1f}s ===", flush=True)

    if "comm" in want:
        from benchmarks import comm_table
        run("comm_table", comm_table.main)
    if "kernels" in want:
        from benchmarks import kernels_bench
        run("kernels_bench", kernels_bench.main)
    if "engine" in want:
        from benchmarks import engine_bench
        run("engine_bench", engine_bench.main,
            **({"rounds": rounds} if rounds else {}))
    if "serve" in want:
        from benchmarks import serve_bench
        run("serve_bench", serve_bench.main,
            **({"steps": 8} if fast else {}))
    if "roofline" in want:
        from benchmarks import roofline
        run("roofline", roofline.main)
    if "fig9" in want:
        from benchmarks import fig9_activations
        run("fig9_activations", fig9_activations.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig3" in want:
        from benchmarks import fig3_gradnorms
        run("fig3_gradnorms", fig3_gradnorms.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig2" in want:
        from benchmarks import fig2_convergence
        run("fig2_convergence", fig2_convergence.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig4" in want:
        from benchmarks import fig4_clients
        run("fig4_clients", fig4_clients.main,
            **({"rounds": rounds} if rounds else {}))
    if "fig8" in want:
        from benchmarks import fig8_scaling_ablation
        run("fig8_scaling_ablation", fig8_scaling_ablation.main,
            **({"rounds": rounds} if rounds else {}))
    if "tab12" in want:
        from benchmarks import tab12_accuracy
        run("tab12_accuracy", tab12_accuracy.main,
            **({"rounds": rounds} if rounds else {}))
    if "table" in want:
        run("trajectory", trajectory)


if __name__ == "__main__":
    main()
